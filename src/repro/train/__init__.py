"""Training substrate: loss, optimizer, train-step factory."""
from repro.train.loss import chunked_cross_entropy  # noqa: F401
from repro.train.optimizer import adamw_init, adamw_update  # noqa: F401
from repro.train.train_step import make_train_step  # noqa: F401
