"""Sequence-chunked cross-entropy.

The (B, S, V) logits tensor is never materialized: a scan over sequence
chunks computes logits for `chunk` positions at a time (B, chunk, V),
reduces to scalar loss terms, and lets autodiff recompute the chunk in the
backward pass. At 152k-vocab x 4k-seq x 256-batch this is the difference
between ~590 MB and ~75 GB of logits per device on the production mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pick_chunk(s: int, chunk: int) -> int:
    c = min(chunk, s)
    while s % c:
        c -= 1
    return c


def chunked_cross_entropy(hidden: jax.Array, lm_head: jax.Array,
                          labels: jax.Array, vocab: int,
                          chunk: int = 512) -> jax.Array:
    """hidden (B, S, d); lm_head (d, Vp); labels (B, S) int32 (-1 = pad).

    Vocab padding columns (>= vocab) are excluded from the logsumexp.
    """
    b, s, d = hidden.shape
    vp = lm_head.shape[1]
    c = _pick_chunk(s, chunk)
    n = s // c
    h = hidden.reshape(b, n, c, d).transpose(1, 0, 2, 3)    # (n, B, c, d)
    y = labels.reshape(b, n, c).transpose(1, 0, 2)          # (n, B, c)
    col_ok = (jnp.arange(vp) < vocab)[None, None, :]

    def body(carry, inp):
        loss_sum, cnt = carry
        h_c, y_c = inp
        logits = (h_c @ lm_head).astype(jnp.float32)
        logits = jnp.where(col_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)             # (B, c)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        valid = (y_c >= 0).astype(jnp.float32)
        loss_sum = loss_sum + jnp.sum((lse - ll) * valid)
        cnt = cnt + jnp.sum(valid)
        return (loss_sum, cnt), None

    body = jax.checkpoint(body)
    (loss_sum, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (h, y))
    return loss_sum / jnp.maximum(cnt, 1.0)
