"""Train-step factory: fwd + chunked CE + AdamW, ready for jit/pjit."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.train.loss import chunked_cross_entropy
from repro.train.optimizer import (adamw_update, clip_by_global_norm,
                                   cosine_schedule)

AUX_COEF = 0.01


def make_train_step(cfg, base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, grad_clip: float = 1.0,
                    accum_steps: int = 1):
    """accum_steps > 1: gradient accumulation over sequence-contiguous
    microbatches (scan) — divides peak activation memory by accum_steps
    at the cost of serializing microbatches. The per-device activation
    footprint of the train_4k cells (EXPERIMENTS.md §Dry-run) assumes
    accum_steps sized so boundaries fit HBM (e.g. 4 for the 7B configs).
    """
    lr_fn = cosine_schedule(base_lr, warmup, total_steps)

    def loss_fn(params, batch):
        hidden, aux = lm.forward(cfg, params, batch)
        ce = chunked_cross_entropy(hidden, params["lm_head"],
                                   batch["labels"], cfg.vocab)
        return ce + AUX_COEF * aux, (ce, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (_, (ce, aux)), grads = grad_fn(params, batch)
        else:
            b = batch["tokens"].shape[0]
            assert b % accum_steps == 0

            def micro(carry, mb):
                grads_acc, ce_acc, aux_acc = carry
                (_, (ce, aux)), g = grad_fn(params, mb)
                grads_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / accum_steps,
                    grads_acc, g)
                return (grads_acc, ce_acc + ce / accum_steps,
                        aux_acc + aux / accum_steps), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape(accum_steps, b // accum_steps,
                                    *x.shape[1:])
                if x.ndim >= 1 and x.shape[0] == b else x, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, ce, aux), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32)), micro_batches)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = adamw_update(grads, opt_state, params, lr_fn)
        metrics = {"loss": ce, "aux_loss": aux, "grad_norm": gnorm,
                   "lr": lr_fn(opt_state.step)}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        hidden, _ = lm.forward(cfg, params, batch)
        return chunked_cross_entropy(hidden, params["lm_head"],
                                     batch["labels"], cfg.vocab)
    return eval_step
