"""Hand-rolled AdamW (+ cosine schedule, global-norm clipping).

Optimizer state is a params-shaped pytree, so it inherits whatever sharding
rule params use; `zero1=True` in the sharding rules re-shards it over the
data axis (ZeRO-1) — see distributed/sharding.py.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    step: jax.Array


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * (step + 1) / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), gn


def adamw_update(grads, state: AdamWState, params, lr_fn,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    lr = lr_fn(step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(mu=new_m, nu=new_v, step=step)
