"""Zamba2-1.2B [arXiv:2411.15242].

38 Mamba2 blocks d_model=2048, ssm_state=64, plus ONE shared attention
block (32H kv=32, d_ff=8192 MLP) applied every 6 mamba blocks — the
parameter-shared hybrid. Zamba2's LoRA-projectors on the shared block and
embedding-concat re-injection are simplified away (DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_groups=1,
    shared_attn_every=6,
)
