"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) d_ff=768/expert vocab=151936,
MoE 128 experts top-8.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv=4,
    d_ff=768, vocab=151936,
    n_experts=128, moe_top_k=8,
    act="swiglu", rope_theta=1e6,
)
