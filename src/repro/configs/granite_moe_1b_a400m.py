"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155,
MoE 32 experts top-8. Granite's logit/residual multipliers are omitted
(noted in DESIGN.md — they do not change shapes or sharding).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8,
    d_ff=512, vocab=49155,
    n_experts=32, moe_top_k=8,
    act="swiglu", rope_theta=1e4,
)
