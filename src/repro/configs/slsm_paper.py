"""The paper's own tuned baseline (Section 3): mu=512, eps=0.001, R=50,
Rn=800, D=20, m=1.0 — used by benchmarks and examples."""
from repro.core.params import SLSMParams

PAPER_BASELINE = SLSMParams(R=50, Rn=800, eps=1e-3, D=20, m=1.0, mu=512,
                            max_levels=3)


def paper_params(**overrides) -> SLSMParams:
    base = dict(R=50, Rn=800, eps=1e-3, D=20, m=1.0, mu=512, max_levels=3)
    base.update(overrides)
    return SLSMParams(**base)
