"""The paper's own tuned baseline (Section 3): mu=512, eps=0.001, R=50,
Rn=800, D=20, m=1.0 — used by benchmarks and examples.

`repro.bench.scenarios.bench_params` is the CPU-scaled sibling (same
ratios, sizes that run in seconds); the BENCH_*.json trajectory and the
figure benches both measure that configuration, while `paper_params` is
the faithful full-size geometry for TPU runs.

These knobs are a *static* pick — one point in the paper's Table 1
space, chosen by hand. Since the tuner PR the engine can also pick for
itself: ``paper_params(tuning=TuningPolicy(mode="adaptive"))`` lets
`repro.engine.tuner` re-partition the memory budget (write buffer vs
per-level Bloom bits vs fence granularity) at merge boundaries as the
observed workload shifts — the README's Tuning guide and DESIGN.md §9
describe when to prefer which.
"""
from repro.core.params import SLSMParams, TuningPolicy  # noqa: F401  (re-
# exported so `paper_params(tuning=TuningPolicy(...))` needs one import)

PAPER_BASELINE = SLSMParams(R=50, Rn=800, eps=1e-3, D=20, m=1.0, mu=512,
                            max_levels=3)


def paper_params(**overrides) -> SLSMParams:
    """Section 3 baseline with keyword overrides (e.g. laptop scaling:
    ``paper_params(R=8, Rn=256, D=4, mu=64)``)."""
    base = dict(R=50, Rn=800, eps=1e-3, D=20, m=1.0, mu=512, max_levels=3)
    base.update(overrides)
    return SLSMParams(**base)
