"""Gemma-7B [arXiv:2403.08295].

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000 — GeGLU,
head_dim=256 (> d_model/n_heads), sqrt(d_model) embedding scaling.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv=16,
    d_ff=24576, vocab=256000, head_dim=256,
    act="geglu", embed_scale=True, rope_theta=1e4,
)
