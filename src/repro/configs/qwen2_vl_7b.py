"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — M-RoPE, dynamic
resolution. The vision frontend is a STUB per the assignment brief:
input_specs() provides precomputed patch embeddings; the text backbone
carries M-RoPE with (t, h, w) position streams (all equal for text).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv=4,
    d_ff=18944, vocab=152064,
    act="swiglu", qkv_bias=True,        # qwen2 uses QKV bias
    rope_theta=1e6, mrope=True, mrope_sections=(16, 24, 24),
)
