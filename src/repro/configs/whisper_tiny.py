"""Whisper-tiny [arXiv:2212.04356].

4L enc + 4L dec, d_model=384 6H (kv=6) d_ff=1536 vocab=51865 — enc-dec,
conv frontend STUBBED per the brief: input_specs() provides precomputed
frame embeddings (B, 1500, d); sinusoidal positions added in-encoder.
LayerNorm + GELU (not RMS/SwiGLU), learned decoder positions (448 max).

long_500k: skipped — the decoder is bounded at 448 positions by design
(out-of-family shape; recorded in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv=6,
    d_ff=1536, vocab=51865,
    act="gelu", norm="layernorm", rope=False,
    encoder_layers=4, encoder_seq=1500,
)
