"""Mamba2-370M [arXiv:2405.21060].

48L d_model=1024, attention-free, ssm_state=128 — SSD (state-space
duality). d_inner = 2*d_model, head_dim 64 -> 32 SSD heads.

sLSM-KV applicability: NONE — there is no KV cache to tier; decode state
is O(1). Recorded in DESIGN.md §Arch-applicability. long_500k runs
natively (state decode).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_groups=1,
)
