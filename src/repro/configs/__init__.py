"""Assigned-architecture registry: --arch <id> selects one of these.

Every config cites its public source; shapes are the exact assigned ones.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen2_vl_7b",
    "granite_moe_1b_a400m",
    "qwen3_moe_30b_a3b",
    "mamba2_370m",
    "phi4_mini_3_8b",
    "qwen1_5_4b",
    "deepseek_7b",
    "gemma_7b",
    "whisper_tiny",
    "zamba2_1_2b",
]

# canonical ids as assigned (hyphens) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "qwen2-vl-7b": "qwen2_vl_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-370m": "mamba2_370m",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen1.5-4b": "qwen1_5_4b",
    "deepseek-7b": "deepseek_7b",
    "gemma-7b": "gemma_7b",
    "whisper-tiny": "whisper_tiny",
    "zamba2-1.2b": "zamba2_1_2b",
})


def get_config(arch: str):
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_arch_ids() -> list[str]:
    return [a.replace("_", "-") for a in ARCHS]
