"""Roofline table generator: reads dry-run records, emits the §Roofline
markdown table + per-cell bottleneck analysis."""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

FIX_HINTS = {
    "compute": "already compute-bound: raise MXU utilization (larger tiles,"
               " fewer remat recomputes)",
    "memory": "fuse/limit HBM traffic: bigger per-layer tiles, bf16 "
              "master-weight reads, fewer remat passes",
    "collective": "re-shard to cut collective payloads (local expert/block "
                  "top-k, reduce-scatter instead of all-gather, overlap)",
}


def load(mesh: str = "pod16x16") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    return f"{x:.2e}"


def table(mesh: str = "pod16x16") -> str:
    rows = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
        "| bottleneck | MODEL_FLOPs/HLO | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                        f"| - | SKIP: {r['skipped'][:60]}... |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                        f"| - | ERROR |")
            continue
        note = r.get("decode_kind") or ""
        if note == "lsm":
            note = "sLSM-KV tiered decode"
        frac = r.get("roofline_fraction")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute'])} | "
            f"{fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} | "
            f"{r['bottleneck']} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{frac:.3f} | {note} |")
    return "\n".join(rows)


def pick_hillclimb(mesh: str = "pod16x16") -> dict:
    recs = [r for r in load(mesh) if "t_compute" in r]
    worst = min(recs, key=lambda r: r.get("roofline_fraction") or 1)
    coll = max(recs, key=lambda r: (r["t_collective"] /
                                    max(max(r["t_compute"], r["t_memory"]),
                                        1e-30)))
    lsm = [r for r in recs if r.get("decode_kind") == "lsm"]
    rep = max(lsm, key=lambda r: r["t_collective"]) if lsm else None
    return {"worst_fraction": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    print(table(args.mesh))
    print()
    picks = pick_hillclimb(args.mesh)
    for why, r in picks.items():
        if r:
            print(f"hillclimb[{why}]: {r['arch']} x {r['shape']} "
                  f"(bottleneck={r['bottleneck']}, "
                  f"frac={r.get('roofline_fraction'):.3f})")


if __name__ == "__main__":
    main()
