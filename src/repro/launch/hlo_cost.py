"""Trip-count-aware cost model over optimized (SPMD-partitioned) HLO text.

XLA's `compiled.cost_analysis()` counts a `while` body ONCE, so every
lax.scan-over-layers model under-reports flops/bytes/collectives by a
factor of n_layers (verified empirically — see EXPERIMENTS.md §Dry-run).
This walker parses the HLO text, memoizes per-computation costs, and
multiplies `while` bodies by their `known_trip_count`.

Counted:
  flops       — dot ops: 2 * prod(result dims) * prod(contracting dims),
                plus 1 flop/element for elementwise arithmetic;
  bytes       — operands + result of compute ops (fusion internals are
                register-resident and excluded — only the fusion's own
                operands/result touch HBM, which is how XLA fuses);
  collectives — result-shape bytes per op type, loop-multiplied.
`conditional` branches contribute their max (one branch executes).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|"
    r"f8e5m2|c64|c128)\[([0-9,]*)\]")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "power", "tanh", "exponential",
    "log", "rsqrt", "sqrt", "maximum", "minimum", "negate", "abs", "floor",
    "cosine", "sine", "logistic", "compare", "select", "and", "or", "xor",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dims(dim_str: str) -> list[int]:
    return [int(d) for d in dim_str.split(",")] if dim_str else []


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in _dims(dims):
            n *= d
        total += n
    return total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for c in _COLLECTIVES:
            self.coll[c] += other.coll[c] * mult

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^(ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(r"(?:calls=|body=|condition=|branch_computations=\{|"
                     r"to_apply=)%?([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_OPNAME = re.compile(r"([\w\-]+)\(")


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.strip()
            m = _COMP_HEADER.match(line)
            if m and line.endswith("{"):
                cur = m.group(2)
                self.comps[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if line == "}":
                cur = None
                continue
            if cur is not None and line:
                self.comps[cur].append(line)

    # -- per-computation symbol table (name -> shape text) ------------------
    def _symbols(self, comp: str) -> dict[str, str]:
        syms: dict[str, str] = {}
        for line in self.comps[comp]:
            m = _INSTR.match(line)
            if not m:
                continue
            name, rhs = m.group(2), m.group(3)
            opm = _OPNAME.search(rhs)
            shape_txt = rhs[:opm.start()] if opm else rhs
            syms[name] = shape_txt
            if "parameter(" in rhs:
                syms[name] = shape_txt
        return syms

    def _dot_flops(self, rhs: str, syms: dict[str, str]) -> float:
        # result shape precedes 'dot('
        m = re.search(r"\bdot\(", rhs)
        result = rhs[:m.start()]
        out_elems = _shape_elems(result)
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
        contract = _dims(cm.group(1)) if cm else []
        # lhs operand name
        args = rhs[m.end():].split(")")[0]
        lhs_name = args.split(",")[0].strip().lstrip("%")
        lhs_shape = syms.get(lhs_name, "")
        sm = _SHAPE_RE.search(lhs_shape)
        k = 1
        if sm:
            ld = _dims(sm.group(2))
            for c in contract:
                if c < len(ld):
                    k *= ld[c]
        return 2.0 * out_elems * k

    def cost(self, comp: str | None = None) -> Cost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total  # breaks cycles (none expected)
        syms = self._symbols(comp)
        for line in self.comps[comp]:
            m = _INSTR.match(line)
            if not m:
                continue
            rhs = m.group(3)
            opm = _OPNAME.search(rhs)
            if not opm:
                continue
            op = opm.group(1)
            result_txt = rhs[:opm.start()]

            if op == "while":
                trip = 1
                tm = _TRIP.search(rhs)
                if tm:
                    trip = int(tm.group(1))
                called = _CALLED.findall(rhs)
                for c in called:
                    if c in self.comps:
                        total.add(self.cost(c), mult=trip)
                continue
            if op == "conditional":
                branches = [c for c in _CALLED.findall(rhs)
                            if c in self.comps]
                if branches:
                    worst = max((self.cost(c) for c in branches),
                                key=lambda x: (x.flops, x.bytes))
                    total.add(worst)
                total.bytes += _shape_bytes(result_txt)
                continue
            if op in ("fusion", "call"):
                for c in _CALLED.findall(rhs):
                    if c in self.comps:
                        sub = self.cost(c)
                        # flops from inside; bytes only at the boundary
                        total.flops += sub.flops
                        for cc in _COLLECTIVES:
                            total.coll[cc] += sub.coll[cc]
                total.bytes += _shape_bytes(rhs)
                continue

            is_coll = False
            for c in _COLLECTIVES:
                if op == c or op == f"{c}-start":
                    total.coll[c] += _shape_bytes(result_txt)
                    total.bytes += _shape_bytes(result_txt)
                    is_coll = True
                    break
            if is_coll:
                continue
            if op == "dot":
                total.flops += self._dot_flops(rhs, syms)
                total.bytes += _shape_bytes(rhs)
                continue
            if op in _ELEMWISE:
                total.flops += _shape_elems(result_txt)
                total.bytes += _shape_bytes(result_txt)
                continue
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast"):
                continue
            # data-movement ops (copy, slice, gather, scatter, reduce, ...)
            total.bytes += _shape_bytes(result_txt)
        self._memo[comp] = total
        return total


def analyze(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    c = model.cost()
    return {"flops": c.flops, "bytes": c.bytes,
            "collective_bytes": c.coll_total,
            "collectives": dict(c.coll)}
