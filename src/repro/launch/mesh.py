"""Production mesh construction.

Single pod : (data=16, model=16)            — 256 chips (one v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     — 512 chips

`pod` is an outer data-parallel axis: gradients all-reduce over
("pod", "data"); model parallelism never crosses the pod boundary (DCN
between pods is ~25x slower than ICI, so only gradient/optimizer traffic
may ride it — the standard multi-pod recipe).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run pins XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small meshes for tests (subprocesses with forced host devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (includes 'pod' when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, *names) -> int:
    n = 1
    for a in names:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
