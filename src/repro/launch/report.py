"""EXPERIMENTS.md generator: §Dry-run, §Roofline, §Perf from the dry-run
result dirs (baseline snapshot + optimized)."""
from __future__ import annotations

import glob
import json
import os

BASE = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results")
OUT = os.path.join(os.path.dirname(__file__), "../../../EXPERIMENTS.md")


def load(d, mesh=None):
    recs = {}
    for f in sorted(glob.glob(os.path.join(BASE, d, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def _fmt(x, digits=2):
    if x is None:
        return "-"
    return f"{x:.{digits}e}"


def roofline_table(recs: dict, mesh: str) -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | "
            "bottleneck | useful-FLOP ratio | roofline frac | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if "skipped" in r:
            rows.append(f"| {arch} | {shape} | - | - | - | - | - | - | "
                        f"SKIP (documented) |")
            continue
        if "error" in r:
            rows.append(f"| {arch} | {shape} | - | - | - | - | - | - | "
                        f"ERROR |")
            continue
        note = "sLSM-KV decode" if r.get("decode_kind") == "lsm" else ""
        rows.append(
            f"| {arch} | {shape} | {_fmt(r['t_compute'])} | "
            f"{_fmt(r['t_memory'])} | {_fmt(r['t_collective'])} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
            f"{r.get('roofline_fraction', 0):.3f} | {note} |")
    return "\n".join(rows)


def before_after(base: dict, opt: dict, cells) -> str:
    rows = ["| cell | metric | baseline | optimized | delta |",
            "|---|---|---|---|---|"]
    for arch, shape in cells:
        b = base.get((arch, shape, "pod16x16"), {})
        o = opt.get((arch, shape, "pod16x16"), {})
        if not b or not o or "t_compute" not in b or "t_compute" not in o:
            continue
        for key, label in (("t_collective", "t_collective (s)"),
                           ("t_memory", "t_memory (s)"),
                           ("t_compute", "t_compute (s)")):
            bb, oo = b[key], o[key]
            delta = (f"{bb/oo:,.0f}x lower" if oo and bb > oo * 1.05 else
                     (f"{oo/bb:.2f}x higher" if bb and oo > bb * 1.05
                      else "~same"))
            rows.append(f"| {arch} x {shape} | {label} | {_fmt(bb)} | "
                        f"{_fmt(oo)} | {delta} |")
        bd = max(b["t_compute"], b["t_memory"], b["t_collective"])
        od = max(o["t_compute"], o["t_memory"], o["t_collective"])
        rows.append(f"| {arch} x {shape} | **step-time bound (s)** | "
                    f"{_fmt(bd)} | {_fmt(od)} | **{bd/od:,.1f}x faster** |")
    return "\n".join(rows)


def dryrun_summary(opt: dict) -> str:
    ok = sum(1 for r in opt.values()
             if "error" not in r and "skipped" not in r)
    skip = sum(1 for r in opt.values() if "skipped" in r)
    fail = sum(1 for r in opt.values() if "error" in r)
    heavy = sorted((r for r in opt.values() if "memory" in r),
                   key=lambda r: -r["memory"].get("temp_size_in_bytes", 0))
    lines = [f"- cells: **{ok} compiled ok**, {skip} documented skips, "
             f"{fail} failures, across meshes (16,16) and (2,16,16).",
             "- heaviest per-device temp footprints (optimized):"]
    for r in heavy[:5]:
        t = r["memory"]["temp_size_in_bytes"] / 1e9
        lines.append(f"  - {r['arch']} x {r['shape']} ({r['mesh']}): "
                     f"temp {t:.1f} GB/device, args "
                     f"{r['memory']['argument_size_in_bytes']/1e9:.1f} GB")
    comp = sorted((r for r in opt.values() if "compile_s" in r),
                  key=lambda r: -r["compile_s"])[:3]
    lines.append("- slowest compiles: " + ", ".join(
        f"{r['arch']}x{r['shape']} {r['compile_s']:.0f}s" for r in comp))
    return "\n".join(lines)
