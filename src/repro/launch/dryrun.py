"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes, extract memory / cost / collective stats.

MUST be the first two lines before ANY other import (jax locks the device
count at first init):
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import all_arch_ids, get_config
from repro.distributed import sharding as SH
from repro.launch import hlo_cost
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import lm
from repro.train import adamw_init, make_train_step

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="long", seq=524288, batch=1),
}

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # B/s
ICI_BW = 50e9            # B/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the optimized
    (SPMD-partitioned, i.e. per-device) HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    count = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        for c in _COLLECTIVES:
            m = re.search(rf"\b{c}(-start)?\(", rhs)
            if m:
                # result shape precedes the op name on the RHS
                out[c] += _shape_bytes(rhs[:m.start()])
                count[c] += 1
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = count
    return out


def model_flops(cfg, kind: str, batch: int, seq: int,
                params_tree) -> float:
    """6*N*D (train) / 2*N*D (inference), N_active for MoE."""
    n_total = sum(p.size for p in jax.tree_util.tree_leaves(params_tree))
    n_embed = params_tree["embed"].size + params_tree["lm_head"].size
    n = n_total - n_embed
    if cfg.n_experts:
        expert = sum(params_tree["layers"]["moe"][k].size
                     for k in ("w_gate", "w_up", "w_down"))
        n = n - expert + expert * cfg.moe_top_k / cfg.n_experts
    tokens = {"train": batch * seq, "prefill": batch * seq,
              "decode": batch, "long": batch}[kind]
    mult = 6 if kind == "train" else 2
    return float(mult * n * tokens)


# --------------------------------------------------------------------------
# cell construction
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_batch_specs(cfg, batch: int, seq: int) -> dict:
    out = {"tokens": _sds((batch, seq), jnp.int32),
           "labels": _sds((batch, seq), jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model),
                             jnp.dtype(cfg.dtype))
    if cfg.mrope:
        out["positions3"] = _sds((3, batch, seq), jnp.int32)
    return out


def input_specs(cfg, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    spec = SHAPES[shape_name]
    b, s = spec["batch"], spec["seq"]
    params = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    if spec["kind"] == "train":
        opt = jax.eval_shape(adamw_init, params)
        return {"params": params, "opt": opt,
                "batch": make_batch_specs(cfg, b, s)}
    if spec["kind"] == "prefill":
        batch = make_batch_specs(cfg, b, s)
        batch.pop("labels")
        return {"params": params, "batch": batch}
    # decode / long
    kind = decode_kind(cfg, shape_name)
    caches = jax.eval_shape(
        lambda: lm.init_decode_caches(cfg, b, s, kind=kind))
    return {"params": params, "token": _sds((b,), jnp.int32),
            "caches": caches}


def decode_kind(cfg, shape_name: str) -> str:
    if shape_name == "long_500k" and cfg.family in ("dense", "vlm", "moe",
                                                    "hybrid"):
        return "lsm"  # the paper's technique makes this cell lowerable
    return "dense"


def cell_skip_reason(cfg, shape_name: str) -> str | None:
    if shape_name == "long_500k" and cfg.family == "encdec":
        return ("whisper decoder is bounded at 448 positions by design; "
                "524k decode is out-of-family (DESIGN.md §4)")
    return None


def build_cell(cfg, shape_name: str, mesh):
    from dataclasses import replace

    from repro.distributed import runtime as RT
    from repro.launch.mesh import axis_size
    RT.set_axes(dp_axes(mesh), "model", mesh)
    # §Perf iters 2-3: shard-local MoE routing / sLSM block selection.
    dpn = axis_size(mesh, *dp_axes(mesh))
    if cfg.n_experts:
        cfg = replace(cfg, moe_dp_groups=dpn)
    # NOTE lsm_dp_groups stays 1: §Perf iter 3 REFUTED the hierarchical
    # block-selection hypothesis on this partitioner (the (G, NBl) grouped
    # gather triggers involuntary full rematerialization, 16x worse); the
    # baseline top-k + uniform-position writes is already shard-local.
    spec = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    params_ns = SH.named(mesh, SH.param_pspecs(cfg, specs["params"], mesh))

    if spec["kind"] == "train":
        step = make_train_step(cfg)
        opt_ns = SH.named(mesh, SH.zero1_pspecs(cfg, specs["opt"], mesh))
        batch_ns = SH.named(mesh, SH.batch_pspecs(cfg, specs["batch"], mesh))
        fn = jax.jit(step, in_shardings=(params_ns, opt_ns, batch_ns),
                     out_shardings=(params_ns, opt_ns, None),
                     donate_argnums=(0, 1))
        args = (specs["params"], specs["opt"], specs["batch"])
        return fn, args

    if spec["kind"] == "prefill":
        batch_ns = SH.named(mesh, SH.batch_pspecs(cfg, specs["batch"], mesh))
        fn = jax.jit(partial(lm.prefill_step, cfg),
                     in_shardings=(params_ns, batch_ns))
        return fn, (specs["params"], specs["batch"])

    kind = decode_kind(cfg, shape_name)
    caches_ns = SH.named(mesh, SH.cache_pspecs(cfg, specs["caches"], mesh))
    b = specs["token"].shape[0]
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = dp_axes(mesh)
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    tok_spec = P(dp if len(dp) > 1 else dp[0]) if b % dpn == 0 else P()
    tok_ns = NamedSharding(mesh, tok_spec)
    fn = jax.jit(partial(lm.decode_step, cfg, kind=kind),
                 in_shardings=(params_ns, tok_ns, caches_ns),
                 out_shardings=(None, caches_ns),
                 donate_argnums=(2,))
    return fn, (specs["params"], specs["token"], specs["caches"])


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, verbose: bool = True) -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            cached = json.load(f)
        if "error" not in cached:
            return cached

    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "chips": 512 if multi_pod else 256}
    skip = cell_skip_reason(cfg, shape_name)
    if skip:
        rec["skipped"] = skip
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            fn, args = build_cell(cfg, shape_name, mesh)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        if verbose:
            print(f"== {arch} {shape_name} {mesh_tag} ==")
            print(mem)
        cost = compiled.cost_analysis()
        if verbose:
            print({k: cost.get(k) for k in
                   ("flops", "bytes accessed", "utilization")
                   if k in cost})
        hlo_txt = compiled.as_text()
        coll = collective_bytes(hlo_txt)
        # trip-count-aware walk: XLA's cost_analysis counts while bodies
        # once, under-reporting scan-over-layers models by ~n_layers x
        tc = hlo_cost.analyze(hlo_txt)

        chips = rec["chips"]
        spec = SHAPES[shape_name]
        mf = model_flops(cfg, spec["kind"], spec["batch"], spec["seq"],
                         input_specs(cfg, shape_name)["params"])
        # cost_analysis / as_text are on the SPMD-partitioned module, i.e.
        # PER-DEVICE flops / bytes / collective payloads. The tc (trip-
        # count-aware) numbers are authoritative; xla_* kept for reference.
        hlo_flops = float(tc["flops"])
        hlo_bytes = float(tc["bytes"])
        rec.update({
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "hlo_flops_per_dev": hlo_flops, "hlo_bytes_per_dev": hlo_bytes,
            "collective_bytes_per_dev": float(tc["collective_bytes"]),
            "collectives": tc["collectives"],
            "xla_flops_per_dev": float(cost.get("flops", 0.0)),
            "xla_bytes_per_dev": float(cost.get("bytes accessed", 0.0)),
            "collectives_static": {k: v for k, v in coll.items()
                                   if k not in ("total",)},
            "model_flops": mf,
            "memory": {
                k: int(getattr(mem, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)},
            # roofline terms, seconds (per-device work / per-chip rate)
            "t_compute": hlo_flops / PEAK_FLOPS,
            "t_memory": hlo_bytes / HBM_BW,
            "t_collective": float(tc["collective_bytes"]) / ICI_BW,
            "useful_flops_ratio": (mf / (hlo_flops * chips))
                                  if hlo_flops else None,
            "decode_kind": (decode_kind(cfg, shape_name)
                            if spec["kind"] in ("decode", "long") else None),
        })
        terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
                 "collective": rec["t_collective"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        rec["roofline_fraction"] = (
            max(terms.values()) and terms["compute"] / max(terms.values()))
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"FAILED {arch} {shape_name} {mesh_tag}: {rec['error']}")

    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    choices=["all"] + list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = all_arch_ids() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    t0 = time.time()
    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, force=args.force)
                if "error" in rec:
                    n_fail += 1
                elif "skipped" in rec:
                    n_skip += 1
                else:
                    n_ok += 1
                print(f"[{time.time()-t0:7.1f}s] {arch:24s} {shape:12s} "
                      f"{'2x16x16' if mp else '16x16':8s} "
                      f"{'SKIP' if 'skipped' in rec else ('FAIL' if 'error' in rec else 'ok')}")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
