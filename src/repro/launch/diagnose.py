"""Per-op collective breakdown for one dry-run cell (hillclimb tooling)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import re
from collections import defaultdict

import jax

from repro.configs import get_config
from repro.launch.dryrun import SHAPES, build_cell
from repro.launch.hlo_cost import (_COLLECTIVES, _OPNAME, _SHAPE_RE,
                                   HloCostModel, _shape_bytes)
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=False)
    with mesh:
        fn, cell_args = build_cell(cfg, args.shape, mesh)
        compiled = fn.lower(*cell_args).compile()
    txt = compiled.as_text()
    model = HloCostModel(txt)

    # trip-count multipliers per computation (1-level approximation: find
    # whiles in entry & bodies)
    mult = defaultdict(lambda: 1)
    trip_re = re.compile(r'known_trip_count[^0-9]*(\d+)')
    called_re = re.compile(r"(?:body=|condition=)%?([\w\.\-]+)")
    for comp, lines in model.comps.items():
        for line in lines:
            if " while(" in line:
                t = trip_re.search(line)
                trip = int(t.group(1)) if t else 1
                for c in called_re.findall(line):
                    mult[c] = mult[comp] * trip

    rows = []
    for comp, lines in model.comps.items():
        m = mult[comp]
        for line in lines:
            rhs = line.split("=", 1)[1] if "=" in line else ""
            opm = _OPNAME.search(rhs)
            if not opm:
                continue
            op = opm.group(1)
            if any(op == c or op == f"{c}-start" for c in _COLLECTIVES):
                b = _shape_bytes(rhs[:opm.start()])
                meta = re.search(r'op_name="([^"]+)"', line)
                rows.append((b * m, b, m, op,
                             (meta.group(1) if meta else "?")[:110]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total collective bytes/dev (trip-adjusted): {total/1e9:.2f} GB")
    for tb, b, m, op, name in rows[:args.top]:
        print(f"{tb/1e9:9.3f} GB  ({b/1e6:8.1f} MB x{m:4d})  {op:20s} {name}")


if __name__ == "__main__":
    main()
