from repro.data.pipeline import (KVWorkload, TokenStream,  # noqa: F401
                                 make_kv_workload)
