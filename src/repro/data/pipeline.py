"""Data pipeline.

Two workload kinds:

1. `TokenStream` — deterministic synthetic LM batches (seeded, shardable
   by (host_id, n_hosts): each host draws only its slice — no cross-host
   data motion, the standard MaxText-style input pipeline contract).

2. `KVWorkload` — the paper's benchmark workloads (Section 3): uniform
   random 32-bit integer keys, normal insert skew with variable variance
   (3.9.1), clustered lookup skew (3.9.2), update:lookup ratio mixes
   (3.8), zipf for good measure. All host-side numpy: the benches measure
   engine throughput, not generator throughput.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


class TokenStream:
    """Deterministic sharded synthetic token batches."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        assert batch % n_hosts == 0
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.local_batch = batch // n_hosts
        self.host_id, self.n_hosts = host_id, n_hosts
        self.seed = seed
        self.step = 0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng(
            (self.seed, self.step, self.host_id))
        toks = rng.integers(0, self.vocab,
                            size=(self.local_batch, self.seq + 1),
                            dtype=np.int32)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class KVWorkload:
    keys: np.ndarray      # insert keys, int32
    vals: np.ndarray      # insert values, int32
    lookups: np.ndarray   # lookup keys, int32
    name: str


def make_kv_workload(kind: str, n: int, seed: int = 0, *,
                     variance: float = 1e6, lookup_variance: float = 1e6,
                     lookup_frac: float = 0.5, zipf_a: float = 1.2,
                     key_space: int = 2**31 - 2) -> KVWorkload:
    """Paper Section 3 workload generators.

    kind: uniform | normal | zipf | cluster-lookup
    """
    rng = np.random.default_rng(seed)
    n_lookup = int(n * lookup_frac)
    if kind == "uniform":
        keys = rng.integers(0, key_space, n, dtype=np.int64)
        lookups = rng.integers(0, key_space, n_lookup, dtype=np.int64)
    elif kind == "normal":
        keys = np.rint(rng.normal(0.0, np.sqrt(variance), n)).astype(np.int64)
        lookups = np.rint(
            rng.normal(0.0, np.sqrt(lookup_variance), n_lookup)).astype(np.int64)
    elif kind == "zipf":
        keys = rng.zipf(zipf_a, n).astype(np.int64) % key_space
        lookups = rng.zipf(zipf_a, n_lookup).astype(np.int64) % key_space
    elif kind == "cluster-lookup":
        keys = rng.integers(0, key_space, n, dtype=np.int64)
        centre = rng.integers(0, key_space, dtype=np.int64)
        lookups = (centre + np.rint(
            rng.normal(0.0, np.sqrt(lookup_variance), n_lookup)
        ).astype(np.int64))
    else:
        raise ValueError(kind)
    clip = 2**31 - 2
    keys = np.clip(keys, -clip, clip).astype(np.int32)
    lookups = np.clip(lookups, -clip, clip).astype(np.int32)
    vals = rng.integers(-2**30, 2**30, n, dtype=np.int32)
    return KVWorkload(keys=keys, vals=vals, lookups=lookups,
                      name=f"{kind}-n{n}")
