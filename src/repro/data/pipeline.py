"""Data pipeline.

Two workload kinds:

1. `TokenStream` — deterministic synthetic LM batches (seeded, shardable
   by (host_id, n_hosts): each host draws only its slice — no cross-host
   data motion, the standard MaxText-style input pipeline contract).

2. `KVWorkload` — the paper's benchmark workloads (Section 3), now owned
   by `repro.bench.workloads` (alongside the named workload families the
   BENCH_*.json scenarios use) and re-exported here for back-compat.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


class TokenStream:
    """Deterministic sharded synthetic token batches."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        assert batch % n_hosts == 0
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.local_batch = batch // n_hosts
        self.host_id, self.n_hosts = host_id, n_hosts
        self.seed = seed
        self.step = 0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng(
            (self.seed, self.step, self.host_id))
        toks = rng.integers(0, self.vocab,
                            size=(self.local_batch, self.seq + 1),
                            dtype=np.int32)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# The KV workload generators moved to `repro.bench.workloads` (the
# benchmark subsystem owns workload definitions now); re-exported here
# for back-compat with existing imports.
from repro.bench.workloads import KVWorkload, make_kv_workload  # noqa: E402,F401
