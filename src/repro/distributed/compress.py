"""Gradient compression for the DP all-reduce: block-wise int8 with error
feedback.

Motivation at 1000+ nodes: the pod axis rides DCN (~25x slower than ICI),
so gradient bytes dominate step time there. int8 + per-block scales cuts
all-reduce bytes 4x (bf16) / 8x (f32); error feedback keeps convergence
(the quantization residual is carried into the next step, so the *sum* of
applied updates is unbiased — Karimireddy et al. 2019).

Usage: wrap grads between value_and_grad and the optimizer:
    grads, residual = ef_compress_grads(grads, residual)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad))
    return flat.reshape(-1, BLOCK), n


def quantize_int8(x: jax.Array):
    """x (any shape) -> (q int8 (nblk, BLOCK), scales f32 (nblk,), n)."""
    blocks, n = _pad_to_block(x)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_int8(q, scale, n, shape):
    out = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return out.reshape(shape)


def compress_roundtrip(x: jax.Array) -> jax.Array:
    """What the wire sees: quantize + dequantize (the all-reduce happens on
    the int8 payload; XLA emits it when this wraps the psum operand)."""
    q, s, n = quantize_int8(x)
    return dequantize_int8(q, s, n, x.shape)


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_grads(grads, residual):
    """Error-feedback compression over a grad pytree.

    Returns (compressed grads to feed the optimizer, new residual).
    Invariant (tested): sum_t applied_t == sum_t grad_t - residual_T.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        applied = compress_roundtrip(corrected)
        return applied, corrected - applied
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compression_ratio(params, from_dtype=jnp.bfloat16) -> float:
    """Wire-byte ratio vs uncompressed all-reduce (scales included)."""
    total_in = sum(p.size * jnp.dtype(from_dtype).itemsize
                   for p in jax.tree_util.tree_leaves(params))
    total_out = sum(p.size * 1 + (p.size // BLOCK + 1) * 4
                    for p in jax.tree_util.tree_leaves(params))
    return total_out / total_in
