"""Ambient logical-axis registry for in-model sharding constraints.

Model code cannot know mesh axis names (smoke tests run on 1 device, the
dry-run on (data, model) or (pod, data, model)). The launcher registers
the logical->physical axis mapping here; `constrain` becomes a no-op when
nothing is registered, so model code stays mesh-agnostic.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map

_DP: tuple[str, ...] | None = None
_MODEL: str | None = None
_MESH = None


def set_axes(dp: tuple[str, ...] | None, model: str | None,
             mesh=None) -> None:
    global _DP, _MODEL, _MESH
    _DP, _MODEL, _MESH = dp, model, mesh


def clear() -> None:
    set_axes(None, None, None)


def mesh():
    return _MESH


def dp_axes() -> tuple[str, ...] | None:
    return _DP


def model_axis() -> str | None:
    return _MODEL


def dp_size() -> int:
    if _MESH is None or not _DP:
        return 1
    n = 1
    for a in _DP:
        n *= _MESH.shape[a]
    return n


def model_size() -> int:
    if _MESH is None or not _MODEL:
        return 1
    return _MESH.shape[_MODEL]


def data_size() -> int:
    if _MESH is None or "data" not in (_MESH.axis_names or ()):
        return 1
    return _MESH.shape["data"]


def constrain(x, *dims: str | None):
    """dims entries: 'dp' | 'model' | None per array axis."""
    if _DP is None and _MODEL is None:
        return x
    spec = []
    for d in dims:
        if d == "dp":
            spec.append(_DP if _DP and len(_DP) > 1 else
                        (_DP[0] if _DP else None))
        elif d == "model":
            spec.append(_MODEL)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
