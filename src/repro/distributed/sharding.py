"""Sharding rules: DP / TP / EP / SP over the production mesh.

Philosophy: GSPMD makes sharding a *layout* choice, not a semantics choice
— every rule here is safe; the rules choose layouts that keep the big
GEMMs local and push collectives onto activations:

  * TP (model axis): attention QKVO, FFN in/out, vocab/embedding, and the
    MoE expert axis (EP == experts over the model axis);
  * DP (pod+data axes): batch; ZeRO-1 re-shards optimizer moments over DP;
  * SP (data axis): sequence/KV-block axis when batch cannot fill DP
    (long_500k batch=1, prefill_32k batch < |DP|).

Dims that don't divide their axis stay replicated (e.g. kv=4 heads on a
16-way model axis — KV projections replicate, the standard GQA-TP rule).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, dp_axes


def _div(n: int, d: int) -> bool:
    return d > 0 and n % d == 0


def _path_names(path) -> list[str]:
    """Key names along a pytree path (dicts, namedtuples, sequences)."""
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return out


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

_COL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "conv_w"}
_ROW = {"wo", "w_down", "out_proj"}


def _param_spec(path_keys: list[str], shape: tuple[int, ...], tp: int):
    name = path_keys[-1]
    stacked = path_keys[0] in ("layers", "enc_layers")  # leading L axis
    off = 1 if stacked else 0
    pre = (None,) * off

    def col(ix):  # shard output/column dim
        if _div(shape[ix + off], tp):
            return P(*pre, *(None,) * ix, "model")
        return P()

    if name == "embed":
        return P("model", None) if _div(shape[0], tp) else P()
    if name == "lm_head":
        return P(None, "model") if _div(shape[1], tp) else P()
    if name == "dec_pos":
        return P()
    if name == "router":
        return P()
    if name in ("w_gate", "w_up", "w_down") and len(shape) - off == 3:
        # MoE expert stacks (E, d, f): expert-parallel over model axis
        if _div(shape[off], tp):
            return P(*pre, "model", None, None)
        return P()
    if name in _COL:
        return col(len(shape) - off - 1)
    if name in _ROW:
        if _div(shape[off], tp):
            return P(*pre, "model", *(None,) * (len(shape) - off - 1))
        return P()
    return P()  # norms, biases, scalars: replicated


def param_pspecs(cfg, params_tree, mesh):
    """Pytree of PartitionSpec matching params (shapes or arrays)."""
    tp = axis_size(mesh, "model")

    def rule(path, leaf):
        keys = _path_names(path)
        return _param_spec(keys, leaf.shape, tp)
    return jax.tree_util.tree_map_with_path(rule, params_tree)


def zero1_pspecs(cfg, params_tree, mesh):
    """ZeRO-1: optimizer moments additionally sharded over DP on the first
    axis that divides (usually the stacked-layer axis or d_model)."""
    dp = dp_axes(mesh)
    dpn = axis_size(mesh, *dp)
    tp = axis_size(mesh, "model")

    def rule(path, leaf):
        keys = _path_names(path)
        base = _param_spec(keys, leaf.shape, tp)
        spec = list(base) + [None] * (len(leaf.shape) - len(base))
        for i, s in enumerate(leaf.shape):
            if spec[i] is None and _div(s, dpn):
                spec[i] = dp if len(dp) > 1 else dp[0]
                break
        return P(*spec)
    return jax.tree_util.tree_map_with_path(rule, params_tree)


# --------------------------------------------------------------------------
# batches / caches
# --------------------------------------------------------------------------

def batch_pspecs(cfg, batch_tree, mesh):
    """Shard batch dim over DP when divisible; else sequence over data."""
    dp = dp_axes(mesh)
    dpn = axis_size(mesh, *dp)
    dp_s = dp if len(dp) > 1 else dp[0]

    def rule(path, leaf):
        keys = _path_names(path)
        name = keys[-1]
        shape = leaf.shape
        if name == "positions3":  # (3, B, S)
            if _div(shape[1], dpn):
                return P(None, dp_s, None)
            return (P(None, None, "data")
                    if _div(shape[2], axis_size(mesh, "data")) else P())
        if len(shape) >= 1 and _div(shape[0], dpn):
            return P(dp_s, *(None,) * (len(shape) - 1))
        if len(shape) >= 2 and _div(shape[1], axis_size(mesh, "data")):
            return P(None, "data", *(None,) * (len(shape) - 2))
        return P()
    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_pspecs(cfg, cache_tree, mesh):
    """Decode caches: batch over DP when it divides; otherwise shard the
    long axis (sequence / block-count / heads) — SP for decode."""
    dp = dp_axes(mesh)
    dpn = axis_size(mesh, *dp)
    dp_s = dp if len(dp) > 1 else dp[0]
    data_n = axis_size(mesh, "data")

    def rule(path, leaf):
        keys = _path_names(path)
        name = keys[-1]
        shape = leaf.shape
        if name == "pos" or len(shape) <= 1:
            return P()
        if name in ("hot_len", "n_blocks"):
            return P()
        # stacked (L, B, ...) leaves
        b_ix = 1
        tp_n = axis_size(mesh, "model")
        if _div(shape[b_ix], dpn):
            # dense/enc KV caches: also shard kv heads over model when they
            # divide (aligns with model-sharded q, attention stays local);
            # otherwise shard the sequence axis over model — scores/output
            # reduce over s with tiny stat all-reduces, and the cache
            # never replicates across the model axis (a kv=20 32k cache
            # replicated 16x would be >300 GB/device).
            if name in ("k", "v", "enc_k", "enc_v", "hot_k", "hot_v") \
                    and len(shape) == 5:
                if _div(shape[3], tp_n):
                    return P(None, dp_s, None, "model", None)
                if _div(shape[2], tp_n):
                    return P(None, dp_s, "model", None, None)
            if name in ("blk_k", "blk_v") and len(shape) == 6:
                if _div(shape[4], tp_n):
                    return P(None, dp_s, None, None, "model", None)
                if _div(shape[3], tp_n):
                    return P(None, dp_s, None, "model", None, None)
            return P(None, dp_s, *(None,) * (len(shape) - 2))
        # batch too small: shard the long axis over data (+ kv heads over
        # model when they divide — halves the per-device KV footprint again)
        tp = axis_size(mesh, "model")
        if name in ("k", "v", "hot_k", "hot_v") and _div(shape[2], data_n):
            kv_ax = "model" if _div(shape[3], tp) else None
            return P(None, None, "data", kv_ax,
                     *(None,) * (len(shape) - 4))
        if name in ("blk_k", "blk_v") and _div(shape[2], data_n):
            kv_ax = "model" if _div(shape[4], tp) else None
            return P(None, None, "data", None, kv_ax,
                     *(None,) * (len(shape) - 5))
        if name == "summ" and _div(shape[2], data_n):
            return P(None, None, "data", *(None,) * (len(shape) - 3))
        if name in ("enc_k", "enc_v") and _div(shape[2], data_n):
            return P(None, None, "data", *(None,) * (len(shape) - 3))
        if name == "ssm" and _div(shape[2], tp):
            return P(None, None, "model", *(None,) * (len(shape) - 3))
        if name == "conv" and _div(shape[-1], tp):
            return P(*(None,) * (len(shape) - 1), "model")
        return P()
    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
