"""Distributed runtime: sharding rules, compression, pipeline, elasticity."""
