"""Pipeline parallelism: GPipe schedule under shard_map + ppermute.

Stage s holds the params for layers [s*L/P, (s+1)*L/P); microbatches flow
stage-to-stage over `jax.lax.ppermute` (ICI neighbour hops on a TPU torus).
The schedule is the classic GPipe trapezoid: T = n_micro + n_stages - 1
ticks, bubble fraction (P-1)/(M+P-1).

This is the optional PP axis for depth-dominated configs; the dry-run
meshes use DP x TP (pipelining across pods would put activations on DCN).
Tested on host-device meshes in tests/test_distributed.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import runtime as RT


def gpipe_forward(stage_fn, stage_params, x_micro, mesh, axis: str = "pipe"):
    """Run a GPipe forward pass.

    stage_fn: (stage_params_slice, x (mb, ...)) -> y (mb, ...)
    stage_params: pytree with leading axis == n_stages (sharded over `axis`)
    x_micro: (n_micro, mb, ...) microbatched input (replicated)
    Returns (n_micro, mb, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def spmd(params_local, xs):
        # params_local leaves have leading dim 1 (this stage's slice)
        pl = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        # carries become device-varying after the first ppermute; mark them
        # varying from the start so the loop carry type is stable (pcast
        # exists only on newer jax; older shard_map needs no marking)
        pcast = getattr(jax.lax, "pcast", None)
        varying = ((lambda v: pcast(v, axis, to="varying")) if pcast
                   else (lambda v: v))
        buf = varying(jnp.zeros_like(xs[0]))
        outs = varying(jnp.zeros_like(xs))

        def tick(t, carry):
            buf, outs = carry
            mb = t - stage
            active = (mb >= 0) & (mb < n_micro)
            mbc = jnp.clip(mb, 0, n_micro - 1)
            x_in = jnp.where(stage == 0, xs[mbc], buf)
            y = stage_fn(pl, x_in)
            y = jnp.where(active, y, buf)
            is_last = stage == n_stages - 1
            outs = jnp.where(
                active & is_last, outs.at[mbc].set(y), outs)
            buf_next = jax.lax.ppermute(y, axis, fwd_perm)
            return buf_next, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast via psum
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return RT.shard_map(
        spmd, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(),
    )(stage_params, x_micro)


def split_layers_into_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""
    def resh(p):
        l = p.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return p.reshape(n_stages, l // n_stages, *p.shape[1:])
    return jax.tree.map(resh, stacked_params)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
