"""Elastic scaling + straggler mitigation.

Elastic: checkpoints are mesh-agnostic (host numpy per leaf); on restore,
`make_elastic_mesh` factors whatever device count survived into
(data, model) preserving the TP degree when possible, and `reshard` lays
the tree out under the new mesh. Losing a pod (512 -> 256) or growing one
is a restore, not a retrain.

Stragglers: `StragglerMonitor` tracks per-step wall times; a step beyond
`k x` the rolling median marks its host as suspect. Policy hooks: `skip`
(drop the step, standard for synchronous SGD with grad accumulation
slack) or `quarantine` (exclude the host at the next elastic re-mesh).
The detection logic is pure and unit-tested; the actuation is the restore
path above.
"""
from __future__ import annotations

import collections
import statistics
from dataclasses import dataclass, field

import jax
import numpy as np


def factor_devices(n_devices: int, prefer_model: int = 16) -> tuple[int, int]:
    """(data, model) factoring of an arbitrary surviving device count,
    preserving the preferred TP degree when it divides."""
    model = min(prefer_model, n_devices)
    while n_devices % model:
        model -= 1
    return n_devices // model, model


def make_elastic_mesh(n_devices: int, prefer_model: int = 16):
    """Factor an arbitrary surviving device count into a usable mesh."""
    data, model = factor_devices(n_devices, prefer_model)
    return jax.make_mesh((data, model), ("data", "model"))


def reshard(host_tree, mesh, pspec_tree):
    """Host numpy pytree -> device arrays under `mesh` with `pspec_tree`."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(np.asarray(x), NamedSharding(mesh, spec))
    return jax.tree.map(put, host_tree, pspec_tree)


@dataclass
class StragglerMonitor:
    threshold: float = 2.0       # x rolling median
    window: int = 32
    min_samples: int = 8
    times: collections.deque = field(default_factory=lambda:
                                     collections.deque(maxlen=256))
    suspects: collections.Counter = field(default_factory=collections.Counter)
    quarantine_after: int = 3

    def record(self, host_id: int, step_time: float) -> str:
        """Returns action: 'ok' | 'skip' | 'quarantine'."""
        recent = list(self.times)[-self.window:]
        self.times.append(step_time)
        if len(recent) < self.min_samples:
            return "ok"
        med = statistics.median(recent)
        if step_time <= self.threshold * med:
            return "ok"
        self.suspects[host_id] += 1
        if self.suspects[host_id] >= self.quarantine_after:
            return "quarantine"
        return "skip"

    def healthy_hosts(self, all_hosts: list[int]) -> list[int]:
        return [h for h in all_hosts
                if self.suspects[h] < self.quarantine_after]
