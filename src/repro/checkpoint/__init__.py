from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.checkpoint.lsm_store import LSMCheckpointStore  # noqa: F401
