"""Model-checkpoint facade over the repo's ONE serialization path.

`CheckpointManager` keeps the seed module's ``step_<n>/`` layout and
save/restore API (used by ``examples/train_lm.py``) but is now a thin
wrapper over `repro.engine.wal`'s snapshot codec — the same atomic
``.tmp-<pid>`` + rename publish, per-leaf ``.npy`` + sha256
verification, and ml_dtypes bit-view shim the sLSM durability layer
uses for its device-pytree snapshots (DESIGN.md §12). There is no
second serialization implementation to drift.

The old incremental ``LSMCheckpointStore`` is retired: logging deltas
is the engine WAL's job now (`repro.engine.wal.Durability`), with
CRC framing, seqno watermarks, and crash-exact `restore()` the ad-hoc
store never had.
"""
from __future__ import annotations

import os
import threading

import jax
import numpy as np

from repro.engine.wal import (SnapshotError, gc_tmp_snapshots,  # noqa: F401
                              list_snapshots, read_snapshot,
                              write_snapshot)

_PREFIX = "step_"


class CheckpointManager:
    """Numbered model checkpoints: atomic, hash-verified, mesh-agnostic.

    Layout per step (written by `wal.write_snapshot` with the ``step_``
    prefix):

        <dir>/step_<n>.tmp-<pid>/   (in progress — ignored, GC'd)
        <dir>/step_<n>/             (atomic rename on completion)
            meta.json               shapes, dtypes, sha256 per leaf
            leaf_<i>.npy            one file per pytree leaf

    A crash mid-save leaves only a ``.tmp`` dir; `latest_step` only
    ever sees complete checkpoints; every leaf is sha256-verified on
    restore. Leaves are host numpy, so a checkpoint restores onto any
    mesh (elastic.reshard)."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        gc_tmp_snapshots(directory)
        self._async_thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True) -> str:
        """Write checkpoint `step` (device leaves fetched here, so the
        caller's pytree may keep training). ``blocking=False`` hands the
        file I/O to a background thread (one in flight at a time — a
        second async save first `wait`s out the previous one); the
        published path is returned either way."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        meta = {"step": step, "treedef": str(treedef)}
        if blocking:
            return str(self._write(step, host_leaves, meta))
        self.wait()
        self._async_thread = threading.Thread(
            target=self._write, args=(step, host_leaves, meta))
        self._async_thread.start()
        return os.path.join(self.dir, f"{_PREFIX}{step}")

    def wait(self) -> None:
        """Join the in-flight async save, if any (idempotent)."""
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, step: int, host_leaves, meta) -> str:
        return str(write_snapshot(self.dir, step, host_leaves, meta,
                                  keep_last=self.keep_last, prefix=_PREFIX))

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> int | None:
        """Highest fully published checkpoint step (None when empty)."""
        steps = list_snapshots(self.dir, prefix=_PREFIX)
        return steps[-1][0] if steps else None

    def restore(self, template_tree, step: int | None = None):
        """-> (host numpy pytree shaped like `template_tree`, step).

        Defaults to the latest step. Raises `FileNotFoundError` when no
        checkpoint exists and `wal.SnapshotError` on corruption (a leaf
        whose sha256 does not match what was written)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"{_PREFIX}{step}")
        leaves, _meta = read_snapshot(path)
        _, treedef = jax.tree_util.tree_flatten(template_tree)
        return jax.tree_util.tree_unflatten(treedef, leaves), step
