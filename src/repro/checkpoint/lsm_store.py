"""Incremental checkpointing on the sLSM — the paper's engine as the
version index of a chunked parameter store.

Mapping:
  * the parameter tree is serialized into fixed-size chunks;
  * each save writes ONLY changed chunks: blob bytes append to a log file,
    and (chunk_id -> blob_offset) is *inserted into the sLSM* — newest-wins
    gives "latest version of every chunk" for free;
  * restore = range-query the whole key space (the newest offset per
    chunk), read those blob segments, reassemble;
  * dropping history = the engine's tombstone/merge machinery.

Write cost per step is O(changed bytes) instead of O(model bytes) — the
LSM deferred-write economics, applied to fault tolerance.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro.core import SLSM, SLSMParams

CHUNK = 1 << 16  # 64 KiB


class LSMCheckpointStore:
    def __init__(self, directory: str, params: SLSMParams | None = None):
        os.makedirs(directory, exist_ok=True)
        self.blob_path = os.path.join(directory, "chunks.blob")
        self.index = SLSM(params or SLSMParams(
            R=8, Rn=1024, eps=1e-3, D=8, m=1.0, mu=64, max_levels=3,
            max_range=1 << 20))
        self._last_hashes: dict[int, int] = {}
        open(self.blob_path, "ab").close()

    # -- serialization ------------------------------------------------------
    @staticmethod
    def _to_bytes(tree) -> bytes:
        leaves = [np.asarray(jax.device_get(x))
                  for x in jax.tree_util.tree_leaves(tree)]
        return b"".join(x.tobytes() for x in leaves)

    @staticmethod
    def _from_bytes(buf: bytes, template):
        leaves, treedef = jax.tree_util.tree_flatten(template)
        out, off = [], 0
        for leaf in leaves:
            leaf = np.asarray(leaf)
            nbytes = leaf.nbytes
            arr = np.frombuffer(buf[off:off + nbytes],
                                dtype=leaf.dtype).reshape(leaf.shape)
            out.append(arr.copy())
            off += nbytes
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- save / restore -------------------------------------------------------
    def save_delta(self, tree) -> dict:
        """Append changed chunks; index them in the sLSM. Returns stats."""
        data = self._to_bytes(tree)
        n_chunks = (len(data) + CHUNK - 1) // CHUNK
        changed_ids, offsets = [], []
        with open(self.blob_path, "ab") as blob:
            for cid in range(n_chunks):
                seg = data[cid * CHUNK:(cid + 1) * CHUNK]
                h = hash(seg)
                if self._last_hashes.get(cid) == h:
                    continue
                self._last_hashes[cid] = h
                offset = blob.tell() // CHUNK
                blob.write(seg.ljust(CHUNK, b"\0"))
                changed_ids.append(cid)
                offsets.append(offset)
        if changed_ids:
            self.index.insert(np.asarray(changed_ids, np.int32),
                              np.asarray(offsets, np.int32))
        return {"total_chunks": n_chunks, "written_chunks": len(changed_ids),
                "write_bytes": len(changed_ids) * CHUNK,
                "full_bytes": len(data)}

    def restore(self, template):
        """Reassemble the newest version of every chunk via the sLSM."""
        data = self._to_bytes(template)          # sizing only
        n_chunks = (len(data) + CHUNK - 1) // CHUNK
        ids = np.arange(n_chunks, dtype=np.int32)
        offsets, found = self.index.lookup(ids)
        if not found.all():
            missing = ids[~found]
            raise IOError(f"LSM checkpoint missing chunks {missing[:8]}...")
        buf = bytearray(n_chunks * CHUNK)
        with open(self.blob_path, "rb") as blob:
            for cid, off in zip(ids.tolist(), offsets.tolist()):
                blob.seek(off * CHUNK)
                buf[cid * CHUNK:(cid + 1) * CHUNK] = blob.read(CHUNK)
        return self._from_bytes(bytes(buf[:len(data)]), template)
