"""Fault-tolerant checkpointing: atomic, hash-verified, mesh-agnostic.

Layout per step:
    <dir>/step_<n>.tmp-<pid>/   (written)
    <dir>/step_<n>/             (atomic rename on completion)
        meta.json               tree structure, shapes, dtypes, sha256
        leaf_<i>.npy            one file per pytree leaf (host numpy)

Restart-safety: a crash mid-save leaves only a .tmp dir (ignored and
garbage-collected); `latest_step` only ever sees complete checkpoints.
Corruption-safety: every leaf is sha256-verified on restore. Elasticity:
leaves are host numpy — restore onto any mesh via elastic.reshard.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

# numpy can't natively save/compare ml_dtypes types; store bit-views
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _encode_leaf(leaf: np.ndarray) -> tuple[np.ndarray, str]:
    name = leaf.dtype.name
    if name in _EXOTIC:
        return leaf.view(_EXOTIC[name][1]), name
    return leaf, name


def _decode_leaf(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][0])
    return arr


def _tree_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._gc_tmp()
        self._async_thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True) -> str:
        leaves, treedef = _tree_paths(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        if blocking:
            return self._write(step, host_leaves, treedef)
        self.wait()
        self._async_thread = threading.Thread(
            target=self._write, args=(step, host_leaves, treedef))
        self._async_thread.start()
        return os.path.join(self.dir, f"step_{step}")

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, step: int, host_leaves, treedef) -> str:
        final = os.path.join(self.dir, f"step_{step}")
        tmp = f"{final}.tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        meta = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(host_leaves):
            fn = f"leaf_{i}.npy"
            enc, dt_name = _encode_leaf(leaf)
            np.save(os.path.join(tmp, fn), enc)
            with open(os.path.join(tmp, fn), "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            meta["leaves"].append({
                "file": fn, "shape": list(leaf.shape),
                "dtype": dt_name, "sha256": digest})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        self._gc_old()
        return final

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_") and ".tmp" not in d]
        return max(steps) if steps else None

    def restore(self, template_tree, step: int | None = None):
        """-> (host numpy pytree shaped like template, step)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        leaves = []
        for entry in meta["leaves"]:
            fp = os.path.join(path, entry["file"])
            with open(fp, "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != entry["sha256"]:
                    raise IOError(f"checkpoint corruption detected: {fp}")
            leaves.append(_decode_leaf(np.load(fp), entry["dtype"]))
        _, treedef = jax.tree_util.tree_flatten(template_tree)
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    # -- housekeeping -----------------------------------------------------------
    def _gc_old(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and ".tmp" not in d)
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def _gc_tmp(self):
        for d in os.listdir(self.dir):
            if ".tmp-" in d:
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
