"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Expert-parallel-friendly: tokens are routed by a sorted permutation (no
per-expert dynamic shapes), each expert runs a dense (E, C, d) x (E, d, f)
batch GEMM whose expert axis shards over the model axis, and results
scatter-add back through the same permutation. FLOPs scale with *active*
tokens (C ≈ T*top_k/E * capacity_factor), so roofline numbers reflect the
MoE's real compute, not a dense-over-experts upper bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import runtime as RT
from repro.models.layers import dtype_of


def init_moe(cfg, key: jax.Array) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "router": (jax.random.normal(k1, (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d, f)) * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(k3, (e, d, f)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(k4, (e, f, d)) * f ** -0.5).astype(dt),
    }


def moe_capacity(cfg, tokens: int) -> int:
    cap = int(tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, ((cap + 7) // 8) * 8)


def _dispatch_local(cfg, p, xt, c):
    """Route one token group (T_local, d). Returns (y, aux)."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.moe_top_k

    logits = xt.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                 # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    # sort token-expert pairs by expert
    flat_e = top_e.reshape(-1).astype(jnp.int32)           # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    # per-expert contiguous slots (capacity C, overflow dropped)
    bounds_lo = jnp.searchsorted(se, jnp.arange(e, dtype=jnp.int32))
    bounds_hi = jnp.searchsorted(se, jnp.arange(e, dtype=jnp.int32),
                                 side="right")
    slot = bounds_lo[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    valid = slot < bounds_hi[:, None]                      # (E, C)
    slot_c = jnp.clip(slot, 0, t * k - 1)
    tok = jnp.where(valid, st[slot_c], 0)                  # (E, C)
    wgt = jnp.where(valid, sw[slot_c], 0.0)                # (E, C)

    xe = xt[tok] * valid[..., None].astype(xt.dtype)       # (E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])        # (E, C, d)

    y = jnp.zeros((t, d), jnp.float32).at[tok.reshape(-1)].add(
        (ye.astype(jnp.float32) * wgt[..., None]).reshape(-1, d))
    return y, aux


def _moe_shard_map(cfg, p, x):
    """Explicit-collective MoE (EXPERIMENTS.md §Perf iter 2b).

    shard_map over the full mesh: routing, sort, gather, expert GEMM and
    combine are all shard-local by construction; the ONLY collective is
    the expert-output partial-sum all-reduce over the model axis (each
    expert shard contributes its experts' outputs for the local tokens).
    Router work is replicated across the model axis — negligible next to
    the GSPMD alternative, which re-gathered every token for the expert
    weight gradients (85.9 GB x 48 layers/step on qwen3-moe train_4k).
    """
    from repro.distributed import runtime as RT
    from jax.sharding import PartitionSpec as P

    mesh = RT.mesh()
    dp = RT.dp_axes()
    model = RT.model_axis()
    dp_s = dp if len(dp) > 1 else dp[0]
    b, s, d = x.shape
    t_local = (b // RT.dp_size()) * s
    c = moe_capacity(cfg, t_local)
    e, e_local = cfg.n_experts, cfg.n_experts // RT.model_size()

    def body(x_blk, router, w_gate, w_up, w_down):
        bl, sl, _ = x_blk.shape
        xt = x_blk.reshape(bl * sl, d)
        tl = xt.shape[0]
        k = cfg.moe_top_k

        logits = xt.astype(jnp.float32) @ router            # (Tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
            1.0 / (tl * k))
        aux = e * jnp.sum(me * ce)

        flat_e = top_e.reshape(-1).astype(jnp.int32)
        flat_t = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)
        flat_w = top_p.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]

        # slots for the LOCAL experts only (my model-shard's slice)
        e0 = jax.lax.axis_index(model) * e_local
        eid = e0 + jnp.arange(e_local, dtype=jnp.int32)
        lo = jnp.searchsorted(se, eid)
        hi = jnp.searchsorted(se, eid, side="right")
        slot = lo[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        valid = slot < hi[:, None]                           # (El, C)
        slot_c = jnp.clip(slot, 0, tl * k - 1)
        tok = jnp.where(valid, st[slot_c], 0)
        wgt = jnp.where(valid, sw[slot_c], 0.0)

        xe = xt[tok] * valid[..., None].astype(xt.dtype)     # (El, C, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
        h = h * jnp.einsum("ecd,edf->ecf", xe, w_up)
        ye = jnp.einsum("ecf,efd->ecd", h, w_down)           # (El, C, d)

        y = jnp.zeros((tl, d), jnp.float32).at[tok.reshape(-1)].add(
            (ye.astype(jnp.float32) * wgt[..., None]).reshape(-1, d))
        y = jax.lax.psum(y, model)          # combine expert shards
        aux = jax.lax.pmean(aux, dp)
        return y.reshape(bl, sl, d).astype(x_blk.dtype), aux

    y, aux = RT.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_s, None, None), P(), P(model, None, None),
                  P(model, None, None), P(model, None, None)),
        out_specs=(P(dp_s, None, None), P()),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux


def moe_ffn(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    Two paths:
      * shard_map (launcher-registered mesh, batch divisible by DP): all
        dispatch data motion is local by construction — see _moe_shard_map;
      * vmap over `cfg.moe_dp_groups` token groups (G=1 == the plain
        global routing used by single-device tests/benches).
    Capacity is per group/shard (C_local = C_global / G) — the same
    accounting real EP systems use, since tokens never leave their DP
    shard. With no overflow the paths are bit-identical (tested).
    """
    from repro.distributed import runtime as RT

    b, s, d = x.shape
    if (RT.mesh() is not None and b % RT.dp_size() == 0
            and cfg.n_experts % RT.model_size() == 0):
        return _moe_shard_map(cfg, p, x)

    t = b * s
    g = max(1, min(cfg.moe_dp_groups, b))     # cannot split below 1 batch row
    c = moe_capacity(cfg, t // g)
    xg = x.reshape(g, t // g, d)
    y, aux = jax.vmap(lambda xt: _dispatch_local(cfg, p, xt, c))(xg)
    return y.reshape(b, s, d).astype(x.dtype), aux.mean()
