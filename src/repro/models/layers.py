"""Shared layers: norms, rotary embeddings (RoPE / M-RoPE), gated MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def apply_norm(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


def init_norm(cfg, d: int) -> dict:
    p = {"w": jnp.ones((d,), dtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((d,), dtype_of(cfg))
    return p


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, hd); positions (..., S) -> rotated x (half-split form)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple[int, ...]) -> jax.Array:
    """Qwen2-VL M-RoPE: the hd/2 frequency lanes are split into (t, h, w)
    sections, each rotated by its own position stream.

    x (B, S, H, hd); positions3 (3, B, S). For text, all three streams are
    equal and M-RoPE reduces to RoPE (tested).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # pick which position stream drives each frequency lane
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=hd // 2)
    pos = positions3[sec_id, :, :]                      # (hd/2, B, S)
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_mlp(cfg, key: jax.Array) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    dt = dtype_of(cfg)
    p = {"w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dt),
         "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dt)}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k1, (d, f)) * s_in).astype(dt)
    return p


def mlp(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    else:  # gelu (whisper)
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    return h @ p["w_down"]
