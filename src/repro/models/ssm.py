"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in chunked
matmul form, plus the O(1) single-token decode step.

The chunked SSD algorithm turns the linear recurrence
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,   y_t = C_t h_t + D x_t
into (1) intra-chunk "attention" with a causal decay kernel, (2) per-chunk
state summaries, (3) an inter-chunk scan, (4) state-to-output corrections
— all dense matmuls except the tiny chunk-level scan, i.e. MXU-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of, rmsnorm

CHUNK = 256


def init_mamba2(cfg, key: jax.Array) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * g * n
    d_in_proj = 2 * din + 2 * g * n + h
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "in_proj": (jax.random.normal(k1, (d, d_in_proj)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_ch))
                   * cfg.ssm_conv ** -0.5).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.ones((din,), dt),
        "out_proj": (jax.random.normal(k4, (din, d)) * din ** -0.5).astype(dt),
    }


def _split_proj(cfg, zxbcdt):
    din, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, xbc, dt_raw = jnp.split(zxbcdt, [din, 2 * din + 2 * g * n], axis=-1)
    return z, xbc, dt_raw  # xbc = [x, B, C] pre-conv channels


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over time. xbc (B, S, Ch); w (K, Ch)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum(a):
    """a (..., L) -> (..., L, L): sum_{j<i..} with -inf above diagonal."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int = CHUNK, h0=None):
    """Chunked SSD scan.

    x (B, S, H, P); dt (B, S, H); a (H,) negative; b, c (B, S, G, N).
    Returns (y (B, S, H, P), h_final (B, H, P, N)).
    """
    bs, s, nh, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = nh // g

    xz = (x * dt[..., None]).reshape(bs, nc, chunk, nh, p)   # dt-scaled input
    adt = (dt * a).reshape(bs, nc, chunk, nh)                # (B,C,L,H)
    bz = jnp.broadcast_to(
        b.reshape(bs, nc, chunk, g, 1, n),
        (bs, nc, chunk, g, rep, n)).reshape(bs, nc, chunk, nh, n)
    cz = jnp.broadcast_to(
        c.reshape(bs, nc, chunk, g, 1, n),
        (bs, nc, chunk, g, rep, n)).reshape(bs, nc, chunk, nh, n)

    a_perm = jnp.moveaxis(adt, -1, -2)                       # (B,C,H,L)
    a_cum = jnp.cumsum(a_perm, axis=-1)                      # (B,C,H,L)

    # (1) intra-chunk
    ll = jnp.exp(_segsum(a_perm))                            # (B,C,H,L,L)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", cz, bz, ll, xz)

    # (2) chunk state summaries
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)          # (B,C,H,L)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", bz, decay_states, xz)

    # (3) inter-chunk recurrence (tiny scan over chunk count)
    chunk_decay = jnp.exp(a_cum[..., -1])                    # (B,C,H)

    def scan_fn(h_prev, inp):
        st, dec = inp                                        # (B,H,P,N), (B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h_init = (jnp.zeros((bs, nh, p, n), x.dtype) if h0 is None else h0)
    h_last, h_prevs = jax.lax.scan(
        scan_fn, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(h_prevs, 0, 1)                # (B,C,H,P,N)

    # (4) state -> output
    state_decay = jnp.exp(a_cum)                             # (B,C,H,L)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", cz, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bs, s, nh, p)
    return y, h_last


def mamba2_forward(cfg, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence Mamba-2 mixer. x (B, S, d) -> (B, S, d)."""
    bs, s, _ = x.shape
    g, n, nh, pd = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt_raw = _split_proj(cfg, x @ p["in_proj"])
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, b, c = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    xh = xs.reshape(bs, s, nh, pd).astype(jnp.float32)
    y, _ = ssd_chunked(xh, dt, a,
                       b.reshape(bs, s, g, n).astype(jnp.float32),
                       c.reshape(bs, s, g, n).astype(jnp.float32),
                       chunk=min(CHUNK, s))
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(bs, s, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba2_prefill(cfg, p: dict, x: jax.Array):
    """Full-sequence forward that also returns decode-ready state.

    -> (y (B, S, d), {"ssm": (B, H, P, N), "conv": (B, K-1, Ch)})
    """
    bs, s, _ = x.shape
    g, n, nh, pd = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc_raw, dt_raw = _split_proj(cfg, x @ p["in_proj"])
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs, b, c = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    xh = xs.reshape(bs, s, nh, pd).astype(jnp.float32)
    y, h_last = ssd_chunked(xh, dt, a,
                            b.reshape(bs, s, g, n).astype(jnp.float32),
                            c.reshape(bs, s, g, n).astype(jnp.float32),
                            chunk=min(CHUNK, s))
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(bs, s, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    k = cfg.ssm_conv
    conv_state = xbc_raw[:, -(k - 1):, :].astype(jnp.dtype(cfg.dtype))
    return y @ p["out_proj"], {"ssm": h_last.astype(jnp.float32),
                               "conv": conv_state}


def mamba2_decode_state_shapes(cfg, batch: int):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return dict(
        ssm=((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
             jnp.float32),
        conv=((batch, cfg.ssm_conv - 1, conv_ch), jnp.dtype(cfg.dtype)),
    )


def mamba2_decode(cfg, p: dict, x1: jax.Array, state: dict):
    """O(1) decode step. x1 (B, 1, d); state {ssm, conv}."""
    bs = x1.shape[0]
    g, n, nh, pd = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt_raw = _split_proj(cfg, x1 @ p["in_proj"])
    # rolling conv state
    hist = jnp.concatenate([state["conv"], xbc.astype(state["conv"].dtype)],
                           axis=1)                           # (B, K, Ch)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None, :].astype(x1.dtype)
    new_conv = hist[:, 1:, :]

    xs, b, c = jnp.split(xbc1, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])                                 # (H,)
    xh = xs[:, 0].reshape(bs, nh, pd).astype(jnp.float32)    # (B,H,P)
    rep = nh // g
    bh = jnp.broadcast_to(b[:, 0].reshape(bs, g, 1, n),
                          (bs, g, rep, n)).reshape(bs, nh, n)
    ch = jnp.broadcast_to(c[:, 0].reshape(bs, g, 1, n),
                          (bs, g, rep, n)).reshape(bs, nh, n)

    decay = jnp.exp(dt * a)                                  # (B,H)
    h_new = (state["ssm"] * decay[..., None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt, xh, bh))
    y = jnp.einsum("bhpn,bhn->bhp", h_new, ch) + xh * p["D"][None, :, None]
    y = y.reshape(bs, 1, cfg.d_inner).astype(x1.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"], dict(ssm=h_new, conv=new_conv)
