"""GQA attention: chunked-flash training path, cached decode path, and the
sLSM-tiered decode path (the paper's technique applied to the KV cache).

All paths are pure jnp (pjit/shard_map-friendly for the multi-pod dry-run);
the Pallas kernels in repro.kernels.lsm_attention are the TPU drop-ins for
the decode paths and are validated against these in tests.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed import runtime as RT
from repro.models.layers import apply_mrope, apply_rope, dtype_of

NEG_INF = -1e30


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init_attention(cfg, key: jax.Array, d_kv_src: int | None = None) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    dkv = d_kv_src or d
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p = {
        "wq": (jax.random.normal(k1, (d, h * hd)) * d ** -0.5).astype(dt),
        "wk": (jax.random.normal(k2, (dkv, kv * hd)) * dkv ** -0.5).astype(dt),
        "wv": (jax.random.normal(k3, (dkv, kv * hd)) * dkv ** -0.5).astype(dt),
        "wo": (jax.random.normal(k4, (h * hd, d)) * (h * hd) ** -0.5).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def _project_q(cfg, p, x):
    b, s, _ = x.shape
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    return q.reshape(b, s, cfg.n_heads, cfg.hd)


def _project_kv(cfg, p, x):
    b, s, _ = x.shape
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(b, s, cfg.n_kv, cfg.hd),
            v.reshape(b, s, cfg.n_kv, cfg.hd))


def _expand_kv(x: jax.Array, h: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by broadcasting kv groups."""
    b, s, kv, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, h // kv, hd))
    return x.reshape(b, s, h, hd)


# --------------------------------------------------------------------------
# training / prefill path: chunked flash attention (pure jnp)
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool, q_chunk: int = 1024,
                    k_chunk: int = 1024, q_offset: int = 0):
    """Memory-bounded attention: online softmax over KV chunks.

    q (B, Sq, H, hd); k, v (B, Sk, H, hd) — KV already group-expanded.
    Never materializes an (Sq, Sk) score matrix: peak extra memory is
    (B, H, q_chunk, k_chunk), which keeps 32k-token prefill lowerable on
    the production mesh.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5

    def fit(s, c):  # largest divisor of s that is <= c
        c = min(c, s)
        while s % c:
            c -= 1
        return c

    q_chunk = fit(sq, q_chunk)
    k_chunk = fit(sk, k_chunk)
    n_q, n_k = sq // q_chunk, sk // k_chunk

    qf = q.astype(jnp.float32).reshape(b, n_q, q_chunk, h, hd)
    kf = k.astype(jnp.float32).reshape(b, n_k, k_chunk, h, hd)
    vf = v.astype(jnp.float32).reshape(b, n_k, k_chunk, h, hd)

    def q_block(qi, qb):                                  # qb (B, qc, H, hd)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kb, vb = inp
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb) * scale
            if causal:
                k_pos = ki * k_chunk + jnp.arange(k_chunk)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
            return (m_new, l, acc), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(n_k), jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B, H, qc, hd)
        return out.transpose(0, 2, 1, 3)                  # (B, qc, H, hd)

    out = jax.lax.map(lambda t: q_block(t[0], t[1]),
                      (jnp.arange(n_q), jnp.moveaxis(qf, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def self_attention(cfg, p, x, positions, *, causal: bool = True,
                   positions3=None):
    """Full-sequence self-attention (train / prefill)."""
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    if cfg.mrope and positions3 is not None:
        q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    out = flash_attention(q, k, v, causal=causal)
    b, s, _, _ = out.shape
    return out.reshape(b, s, -1) @ p["wo"]


def cross_attention(cfg, p, x, enc_k, enc_v):
    """Decoder cross-attention; enc_k/v (B, T, KV, hd) precomputed."""
    q = _project_q(cfg, p, x)                              # no RoPE (whisper)
    k = _expand_kv(enc_k, cfg.n_heads)
    v = _expand_kv(enc_v, cfg.n_heads)
    out = flash_attention(q, k, v, causal=False,
                          q_chunk=min(1024, q.shape[1]),
                          k_chunk=min(1024, k.shape[1]))
    b, s, _, _ = out.shape
    return out.reshape(b, s, -1) @ p["wo"]


def project_enc_kv(cfg, p, enc_h):
    """Precompute encoder K/V for cross-attention caching."""
    return _project_kv(cfg, p, enc_h)


# --------------------------------------------------------------------------
# decode path: dense ragged cache
# --------------------------------------------------------------------------

def decode_self_attention(cfg, p, x1, cache_k, cache_v, pos):
    """One-token decode with a dense KV cache.

    x1 (B, 1, d); cache_k/v (B, Smax, KV, hd); pos (B,) current lengths.
    Returns (out (B, 1, d), new_cache_k, new_cache_v).

    Cache writes use a *uniform position* (pos[0]) — static batching.
    Perf note (EXPERIMENTS.md §Perf iter 1): a per-batch ragged scatter
    (vmap of dynamic_update_slice) defeats the SPMD partitioner and forces
    the whole cache to replicate (2 x 128.8 GB all-gathers/step on the
    deepseek decode_32k cell); a scalar-start dynamic_update_slice is
    trivially partitionable on batch and kv axes. Continuous batching
    would reintroduce raggedness via a paged/block layout instead.
    """
    b = x1.shape[0]
    q = _project_q(cfg, p, x1)                             # (B, 1, H, hd)
    k1, v1 = _project_kv(cfg, p, x1)                       # (B, 1, KV, hd)
    if cfg.mrope:
        pos3 = jnp.broadcast_to(pos[None, :, None], (3, b, 1))
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k1 = apply_mrope(k1, pos3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k1 = apply_rope(k1, pos[:, None], cfg.rope_theta)

    def upd(c, new):
        return jax.lax.dynamic_update_slice(
            c, new.astype(c.dtype), (0, pos[0], 0, 0))

    cache_k = upd(cache_k, k1)
    cache_v = upd(cache_v, v1)

    # Perf (EXPERIMENTS.md §Perf iter 1): contract in the cache dtype with
    # f32 accumulation — an astype(f32) here materializes an f32 copy of
    # the ENTIRE cache; and pin the q layout so the kv-head axis (not hd)
    # carries the model sharding, keeping attention shard-local.
    group = cfg.n_heads // cfg.n_kv
    qg = q[:, 0].reshape(b, cfg.n_kv, group, cfg.hd).astype(cache_k.dtype)
    if cfg.n_kv % max(RT.model_size(), 1) == 0:
        qg = RT.constrain(qg, "dp", "model", None, None)
    else:
        qg = RT.constrain(qg, "dp", None, None, None)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32) * cfg.hd ** -0.5
    smax = cache_k.shape[1]
    mask = jnp.arange(smax)[None, :] <= pos[:, None]       # includes new token
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p_att = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p_att.astype(cache_v.dtype),
                     cache_v, preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd).astype(x1.dtype)
    return out @ p["wo"], cache_k, cache_v


def _lsm_cold_stats_shardmap(cfg, qg, blk_k, blk_v, ids, sel_ok,
                             scale: float):
    """Cold-block attention stats, computed where the blocks live.

    blk_k/v (B, NB, mu, KV, hd) — NB sharded over 'data', KV over 'model'.
    Each (data, model) shard attends its local selected blocks for its
    local kv heads; per-shard online-softmax stats merge with a pmax +
    two psums over 'data' (O(KV*g*hd) bytes — not block payloads).
    Returns (m, l, acc) shaped like the hot-path stats.
    """
    from jax.sharding import PartitionSpec as P

    mesh = RT.mesh()
    b, nb, mu, kv, hd = blk_k.shape
    group = cfg.n_heads // kv
    topk = ids.shape[-1]

    def body(qg_l, bk_l, bv_l, ids_l, ok_l):
        # qg_l (B, KVl, g, hd); bk_l (B, NBl, mu, KVl, hd);
        # ids_l/ok_l (B, KVl, topk) — global block ids
        nbl = bk_l.shape[1]
        kvl = bk_l.shape[3]
        base = jax.lax.axis_index("data") * nbl
        loc = ids_l - base
        mine = (loc >= 0) & (loc < nbl) & ok_l               # (B, KVl, topk)
        locc = jnp.clip(loc, 0, nbl - 1)

        def gather_b(blk, idb):                              # per batch
            def per_kv(kvi):
                return blk[idb[kvi], :, kvi, :]              # (topk, mu, hd)
            return jax.vmap(per_kv)(jnp.arange(kvl))
        sel_k = jax.vmap(gather_b)(bk_l, locc)               # (B,KVl,topk,mu,hd)
        sel_v = jax.vmap(gather_b)(bv_l, locc)

        s = jnp.einsum("bkgd,bktmd->bkgtm", qg_l, sel_k,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(mine[:, :, None, :, None], s, NEG_INF)
        s = s.reshape(b, kvl, group, topk * mu)
        m_p = s.max(-1)                                      # (B,KVl,g)
        p_att = jnp.exp(s - m_p[..., None])
        p_att = jnp.where(jnp.isfinite(s), p_att, 0.0)
        l_p = p_att.sum(-1)
        acc_p = jnp.einsum(
            "bkgs,bksd->bkgd", p_att.astype(sel_v.dtype),
            sel_v.reshape(b, kvl, topk * mu, hd),
            preferred_element_type=jnp.float32)
        # merge across data shards: stats only
        m_g = jax.lax.pmax(m_p, "data")
        corr = jnp.exp(m_p - m_g)
        l_g = jax.lax.psum(l_p * corr, "data")
        acc_g = jax.lax.psum(acc_p * corr[..., None], "data")
        return m_g, l_g, acc_g

    return RT.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "model", None, None),
                  P(None, "data", None, "model", None),
                  P(None, "data", None, "model", None),
                  P(None, "model", None), P(None, "model", None)),
        out_specs=(P(None, "model", None), P(None, "model", None),
                   P(None, "model", None, None)),
    )(qg, blk_k, blk_v, ids, sel_ok)


# --------------------------------------------------------------------------
# decode path: sLSM-tiered cache (hot window + summary-gated cold blocks)
# --------------------------------------------------------------------------

def lsm_cache_shapes(cfg, batch: int, max_len: int):
    """Shape spec for one layer's tiered cache.

    The block axis is padded to a multiple of 32 so it shards cleanly over
    the data axis when batch=1 (SP for long-context decode)."""
    w, mu = cfg.lsm_hot_window, cfg.lsm_block
    nb = max(1, math.ceil(max(0, max_len - w) / mu) + 1)
    nb = ((nb + 31) // 32) * 32
    kv, hd = cfg.n_kv, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    return dict(
        hot_k=((batch, w, kv, hd), dt), hot_v=((batch, w, kv, hd), dt),
        blk_k=((batch, nb, mu, kv, hd), dt), blk_v=((batch, nb, mu, kv, hd), dt),
        summ=((batch, nb, kv, hd), dt),
        hot_len=((batch,), jnp.int32), n_blocks=((batch,), jnp.int32),
    )


def lsm_decode_self_attention(cfg, p, x1, cache: dict, pos):
    """One-token decode against the tiered cache.

    The hot window is the sLSM memory buffer (always searched); cold
    blocks are immutable mu-token runs whose summary vector gates access
    (Bloom/fence analogue): only the top-k scoring blocks are read.
    Sealing (hot -> new cold block) happens when the hot window fills —
    the memory-buffer merge, handled in serving/kv_cache.py.
    """
    b = x1.shape[0]
    q = _project_q(cfg, p, x1)
    k1, v1 = _project_kv(cfg, p, x1)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k1 = apply_rope(k1, pos[:, None], cfg.rope_theta)

    # append to hot window (ring semantics handled by seal in kv_cache).
    # Uniform-position write — see decode_self_attention perf note.
    def upd(c, new):
        return jax.lax.dynamic_update_slice(
            c, new.astype(c.dtype), (0, cache["hot_len"][0], 0, 0))

    hot_k = upd(cache["hot_k"], k1)
    hot_v = upd(cache["hot_v"], v1)
    hot_len = cache["hot_len"] + 1

    # --- block selection (the filter probe) ---
    kv, hd = cfg.n_kv, cfg.hd
    group = cfg.n_heads // kv
    nb = cache["blk_k"].shape[1]
    mu = cache["blk_k"].shape[2]
    topk = min(cfg.lsm_topk, nb)
    qh = q[:, 0]                                            # (B, H, hd)
    dt = cache["blk_k"].dtype
    qg = qh.reshape(b, kv, group, hd).astype(dt)
    score = jnp.einsum("bkgd,bnkd->bkgn", qg, cache["summ"],
                       preferred_element_type=jnp.float32).max(axis=2)
    blk_ok = jnp.arange(nb)[None, :] < cache["n_blocks"][:, None]
    score = jnp.where(blk_ok[:, None, :], score, -jnp.inf)

    # §Perf iter 4: compute-at-data cold attention. Each data shard owns
    # NB/|data| blocks and each model shard kv/|model| heads; attention
    # over the selected blocks runs where the blocks live, and only the
    # online-softmax stats (m, l, acc — O(KV*g*hd)) cross shards, instead
    # of the 268 MB x layers selected-block payload all-reduce.
    use_stats = (RT.mesh() is not None and b == 1
                 and nb % max(RT.data_size(), 1) == 0
                 and kv % max(RT.model_size(), 1) == 0
                 and cfg.lsm_dp_groups == 1)
    if use_stats:
        top_s, ids = jax.lax.top_k(score, topk)             # (B, KV, topk)
        sel_ok = jnp.isfinite(top_s)
        m_c, l_c, acc_c = _lsm_cold_stats_shardmap(
            cfg, qg, cache["blk_k"], cache["blk_v"], ids, sel_ok,
            hd ** -0.5)
        # hot part as stats
        w = hot_k.shape[1]
        sf = jnp.einsum("bkgd,bskd->bkgs", qg, hot_k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
        hot_mask = jnp.arange(w)[None, :] < hot_len[:, None]
        sf = jnp.where(hot_mask[:, None, None, :], sf, NEG_INF)
        m_h = sf.max(-1)
        p_h = jnp.exp(sf - m_h[..., None])
        l_h = p_h.sum(-1)
        acc_h = jnp.einsum("bkgs,bksd->bkgd", p_h.astype(hot_v.dtype),
                           jnp.moveaxis(hot_v, 2, 1),
                           preferred_element_type=jnp.float32)
        m = jnp.maximum(m_h, m_c)
        ch = jnp.exp(m_h - m)[..., None]
        cc = jnp.exp(m_c - m)[..., None]
        num = acc_h * ch + acc_c * cc
        den = l_h * jnp.exp(m_h - m) + l_c * jnp.exp(m_c - m)
        out = num / jnp.maximum(den, 1e-30)[..., None]
        out = out.reshape(b, 1, cfg.n_heads * cfg.hd).astype(x1.dtype)
        new_cache = dict(cache, hot_k=hot_k, hot_v=hot_v, hot_len=hot_len)
        return out @ p["wo"], new_cache

    gsel = max(1, min(cfg.lsm_dp_groups, nb))
    if gsel > 1 and nb % gsel == 0 and topk <= nb // gsel:
        # §Perf iter 3 — hierarchical selection: per-shard local top-k,
        # then a global re-rank over the G*topk candidates. Every block
        # gather stays inside its shard (the group axis carries the data
        # sharding); only O(G*topk) scalar scores cross shards. Exact:
        # the global top-k is a subset of the union of local top-ks, and
        # the re-rank mask admits precisely the global winners.
        nbl = nb // gsel
        sg = score.reshape(b, kv, gsel, nbl)
        loc_s, loc_i = jax.lax.top_k(sg, topk)              # (B,KV,G,topk)
        flat_s = loc_s.reshape(b, kv, gsel * topk)
        kth = jax.lax.top_k(flat_s, topk)[0][..., -1:]      # global threshold
        sel_ok = jnp.isfinite(flat_s) & (flat_s >= kth)     # (B,KV,G*topk)

        blk_kg = cache["blk_k"].reshape(b, gsel, nbl, mu, kv, hd)
        blk_vg = cache["blk_v"].reshape(b, gsel, nbl, mu, kv, hd)

        def gather_bg(blk, idb):                            # blk (G,NBl,mu,KV,hd)
            # idb (KV, G, topk) -> per-group layout (G, KV, topk)
            def per_g(blk_g, id_g):                         # (NBl,mu,KV,hd),(KV,topk)
                def per_kv(kvi):
                    return blk_g[id_g[kvi], :, kvi, :]      # (topk, mu, hd)
                return jax.vmap(per_kv)(jnp.arange(kv))     # (KV,topk,mu,hd)
            return jax.vmap(per_g)(blk, jnp.moveaxis(idb, 1, 0))
        sel_k = jax.vmap(gather_bg)(blk_kg, loc_i)          # (B,G,KV,topk,mu,hd)
        sel_v = jax.vmap(gather_bg)(blk_vg, loc_i)
        sel_k = jnp.moveaxis(sel_k, 1, 2).reshape(b, kv, gsel * topk, mu, hd)
        sel_v = jnp.moveaxis(sel_v, 1, 2).reshape(b, kv, gsel * topk, mu, hd)
        n_cand = gsel * topk
    else:
        top_s, ids = jax.lax.top_k(score, topk)             # (B, KV, topk)
        sel_ok = jnp.isfinite(top_s)

        def gather_b(blk, idb):                             # per batch
            def per_kv(kvi):
                return blk[idb[kvi], :, kvi, :]             # (topk, mu, hd)
            return jax.vmap(per_kv)(jnp.arange(kv))         # (KV,topk,mu,hd)

        sel_k = jax.vmap(gather_b)(cache["blk_k"], ids)     # (B,KV,topk,mu,hd)
        sel_v = jax.vmap(gather_b)(cache["blk_v"], ids)
        n_cand = topk

    # --- fused attention over [hot | selected blocks] ---
    w = hot_k.shape[1]
    sf = jnp.einsum("bkgd,bskd->bkgs", qg, hot_k,
                    preferred_element_type=jnp.float32)
    hot_mask = jnp.arange(w)[None, :] < hot_len[:, None]
    sf = jnp.where(hot_mask[:, None, None, :], sf, NEG_INF)
    sc = jnp.einsum("bkgd,bktmd->bkgtm", qg, sel_k,
                    preferred_element_type=jnp.float32)
    sc = jnp.where(sel_ok[:, :, None, :, None], sc, NEG_INF)
    scale = hd ** -0.5
    s_all = jnp.concatenate(
        [sf.reshape(b, kv, group, w), sc.reshape(b, kv, group, n_cand * mu)],
        axis=-1) * scale
    p_att = jax.nn.softmax(s_all, axis=-1)
    v_all = jnp.concatenate(
        [jnp.moveaxis(hot_v, 2, 1).reshape(b, kv, w, hd),
         sel_v.reshape(b, kv, n_cand * mu, hd)], axis=2)
    out = jnp.einsum("bkgs,bksd->bkgd", p_att.astype(v_all.dtype), v_all,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd).astype(x1.dtype)

    new_cache = dict(cache, hot_k=hot_k, hot_v=hot_v, hot_len=hot_len)
    return out @ p["wo"], new_cache
