"""Model assembly: init / forward / prefill / decode for every family.

One flexible stack covers the 10 assigned architectures:
  dense | vlm  — GQA transformer (RoPE or M-RoPE, SwiGLU/GeGLU, opt. bias)
  moe          — same + sort-dispatch MoE FFN
  ssm          — Mamba-2 (SSD) mixer stack, attention-free
  hybrid       — Mamba-2 backbone + one *shared* attention block applied
                 every `shared_attn_every` layers (Zamba2)
  encdec       — Whisper: bidir encoder over stubbed frame embeddings +
                 causal decoder with cross-attention

Layers are stacked (leading L axis) and driven by lax.scan so the HLO is
O(1) in depth — essential for 80 dry-run compiles on the production mesh.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as ATT
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import (apply_norm, dtype_of, init_mlp, init_norm,
                                 mlp)


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------

def _init_attn_block(cfg, key):
    k1, k2 = jax.random.split(key)
    p = {"ln1": init_norm(cfg, cfg.d_model),
         "attn": ATT.init_attention(cfg, k1),
         "ln2": init_norm(cfg, cfg.d_model),
         "mlp": init_mlp(cfg, k2)}
    return p


def _init_moe_block(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_norm(cfg, cfg.d_model),
            "attn": ATT.init_attention(cfg, k1),
            "ln2": init_norm(cfg, cfg.d_model),
            "moe": MOE.init_moe(cfg, k2)}


def _init_ssm_block(cfg, key):
    return {"ln1": init_norm(cfg, cfg.d_model),
            "mixer": SSM.init_mamba2(cfg, key)}


def _init_dec_block(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg, cfg.d_model),
            "attn": ATT.init_attention(cfg, k1),
            "ln2": init_norm(cfg, cfg.d_model),
            "cross": ATT.init_attention(cfg, k2),
            "ln3": init_norm(cfg, cfg.d_model),
            "mlp": init_mlp(cfg, k3)}


def init_params(cfg, key: jax.Array) -> dict:
    dt = dtype_of(cfg)
    keys = jax.random.split(key, 8)
    vp, d = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": (jax.random.normal(keys[0], (vp, d)) * 0.02).astype(dt),
        "final_norm": init_norm(cfg, d),
        "lm_head": (jax.random.normal(keys[1], (d, vp)) * d ** -0.5).astype(dt),
    }
    lkeys = jax.random.split(keys[2], cfg.n_layers)
    if cfg.family in ("dense", "vlm"):
        params["layers"] = jax.vmap(partial(_init_attn_block, cfg))(lkeys)
    elif cfg.family == "moe":
        params["layers"] = jax.vmap(partial(_init_moe_block, cfg))(lkeys)
    elif cfg.family == "ssm":
        params["layers"] = jax.vmap(partial(_init_ssm_block, cfg))(lkeys)
    elif cfg.family == "hybrid":
        params["layers"] = jax.vmap(partial(_init_ssm_block, cfg))(lkeys)
        params["shared"] = _init_attn_block(cfg, keys[3])
    elif cfg.family == "encdec":
        ekeys = jax.random.split(keys[4], cfg.encoder_layers)
        params["enc_layers"] = jax.vmap(partial(_init_attn_block, cfg))(ekeys)
        params["enc_norm"] = init_norm(cfg, d)
        params["layers"] = jax.vmap(partial(_init_dec_block, cfg))(lkeys)
        params["dec_pos"] = (jax.random.normal(keys[5], (448, d)) * 0.01).astype(dt)
    else:
        raise ValueError(cfg.family)
    return params


def param_count(params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------

def _dec_positions(cfg, params, s: int) -> jax.Array:
    """Whisper learned decoder positions; sinusoidal extension past the
    448-entry table for out-of-family assigned shapes (32k decode cells)."""
    table = params["dec_pos"]
    if s <= table.shape[0]:
        return table[:s][None, :, :]
    ext = _sinusoid(s - table.shape[0], cfg.d_model).astype(table.dtype)
    return jnp.concatenate([table, ext], axis=0)[None, :, :]


def _sinusoid(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed(cfg, params, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _attn_block_fwd(cfg, lp, x, positions, positions3=None, causal=True):
    h = apply_norm(cfg, lp["ln1"], x)
    x = x + ATT.self_attention(cfg, lp["attn"], h, positions,
                               causal=causal, positions3=positions3)
    h = apply_norm(cfg, lp["ln2"], x)
    if "moe" in lp:
        y, aux = MOE.moe_ffn(cfg, lp["moe"], h)
        return x + y, aux
    return x + mlp(cfg, lp["mlp"], h), jnp.zeros((), jnp.float32)


def _ssm_block_fwd(cfg, lp, x):
    h = apply_norm(cfg, lp["ln1"], x)
    return x + SSM.mamba2_forward(cfg, lp["mixer"], h)


# --------------------------------------------------------------------------
# forward (training / prefill logits)
# --------------------------------------------------------------------------

def forward(cfg, params: dict, batch: dict):
    """-> (hidden (B, S, d), aux_loss). Logits live in the loss (chunked)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = batch.get("positions",
                          jnp.broadcast_to(jnp.arange(s)[None, :], (b, s)))
    positions3 = batch.get("positions3")
    x = _embed(cfg, params, tokens)
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm", "moe"):
        @jax.checkpoint
        def body(carry, lp):
            x, aux = carry
            x, a = _attn_block_fwd(cfg, lp, x, positions, positions3)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["layers"])

    elif cfg.family == "ssm":
        @jax.checkpoint
        def body(x, lp):
            return _ssm_block_fwd(cfg, lp, x), None
        x, _ = jax.lax.scan(body, x, params["layers"])

    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every

        @jax.checkpoint
        def body(carry, inp):
            x, = carry
            li, lp = inp
            x = _ssm_block_fwd(cfg, lp, x)
            x = jax.lax.cond(
                (li % every) == every - 1,
                lambda x: _attn_block_fwd(cfg, params["shared"], x,
                                          positions)[0],
                lambda x: x, x)
            return (x,), None
        (x,), _ = jax.lax.scan(
            body, (x,), (jnp.arange(cfg.n_layers), params["layers"]))

    elif cfg.family == "encdec":
        enc_h = _encode(cfg, params, batch["frames"])
        x = x + _dec_positions(cfg, params, s)

        @jax.checkpoint
        def body(carry, lp):
            x, aux = carry
            h = apply_norm(cfg, lp["ln1"], x)
            x = x + ATT.self_attention(cfg, lp["attn"], h, None, causal=True)
            h = apply_norm(cfg, lp["ln2"], x)
            ek, ev = ATT.project_enc_kv(cfg, lp["cross"], enc_h)
            x = x + ATT.cross_attention(cfg, lp["cross"], h, ek, ev)
            h = apply_norm(cfg, lp["ln3"], x)
            x = x + mlp(cfg, lp["mlp"], h)
            return (x, aux), None
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["layers"])
    else:
        raise ValueError(cfg.family)

    return apply_norm(cfg, params["final_norm"], x), aux


def _encode(cfg, params, frames):
    """Whisper encoder over stubbed frame embeddings (B, T, d)."""
    b, t, _ = frames.shape
    x = frames + _sinusoid(t, cfg.d_model)[None].astype(frames.dtype)

    @jax.checkpoint
    def body(x, lp):
        x, _ = _attn_block_fwd(cfg, lp, x, None, causal=False)
        return x, None
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg, params["enc_norm"], x)


def logits_full(cfg, params, batch):
    """Small-model convenience: full (B, S, V) logits."""
    h, aux = forward(cfg, params, batch)
    logits = h @ params["lm_head"]
    return logits[..., :cfg.vocab], aux


# --------------------------------------------------------------------------
# prefill: forward + decode-ready caches
# --------------------------------------------------------------------------

def _attn_kv_for_cache(cfg, lp, x, positions, positions3=None):
    """Recompute the rope'd K/V a block contributes to the cache."""
    h = apply_norm(cfg, lp["ln1"], x)
    k, v = ATT._project_kv(cfg, lp["attn"], h)
    if cfg.mrope and positions3 is not None:
        k = ATT.apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
    elif positions is not None:
        k = ATT.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def forward_collect(cfg, params: dict, batch: dict):
    """Prefill: -> (hidden, caches) with caches ready for decode_step.

    Cache length == prompt length; serving/kv_cache.py grows/reshapes it
    for generation (dense) or seals it into the tiered layout (lsm).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = batch.get("positions",
                          jnp.broadcast_to(jnp.arange(s)[None, :], (b, s)))
    positions3 = batch.get("positions3")
    x = _embed(cfg, params, tokens)
    pos_after = jnp.full((b,), s, jnp.int32)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(carry, lp):
            x, aux = carry
            k, v = _attn_kv_for_cache(cfg, lp, x, positions, positions3)
            x, a = _attn_block_fwd(cfg, lp, x, positions, positions3)
            return (x, aux + a), (k, v)
        (x, _), (ks, vs) = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["layers"])
        caches = {"k": ks, "v": vs, "pos": pos_after}

    elif cfg.family == "ssm":
        def body(x, lp):
            h = apply_norm(cfg, lp["ln1"], x)
            y, st = SSM.mamba2_prefill(cfg, lp["mixer"], h)
            return x + y, st
        x, st = jax.lax.scan(body, x, params["layers"])
        caches = {"ssm": st["ssm"], "conv": st["conv"], "pos": pos_after}

    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every

        def body(carry, inp):
            x, = carry
            li, lp = inp
            h = apply_norm(cfg, lp["ln1"], x)
            y, st = SSM.mamba2_prefill(cfg, lp["mixer"], h)
            x = x + y
            shared_k, shared_v = _attn_kv_for_cache(
                cfg, params["shared"], x, positions)
            is_shared = (li % every) == every - 1
            x = jax.lax.cond(
                is_shared,
                lambda x: _attn_block_fwd(cfg, params["shared"], x,
                                          positions)[0],
                lambda x: x, x)
            return (x,), (st["ssm"], st["conv"], shared_k, shared_v)
        (x,), (ssm_st, conv_st, sk, sv) = jax.lax.scan(
            body, (x,), (jnp.arange(cfg.n_layers), params["layers"]))
        app_idx = [i * every + every - 1 for i in
                   range(max(1, cfg.n_layers // every))]
        caches = {"ssm": ssm_st, "conv": conv_st,
                  "shared": {"k": sk[jnp.asarray(app_idx)],
                             "v": sv[jnp.asarray(app_idx)]},
                  "pos": pos_after}

    elif cfg.family == "encdec":
        enc_h = _encode(cfg, params, batch["frames"])
        x = x + _dec_positions(cfg, params, s)

        def body(x, lp):
            h = apply_norm(cfg, lp["ln1"], x)
            k, v = ATT._project_kv(cfg, lp["attn"], h)
            x = x + ATT.self_attention(cfg, lp["attn"], h, None, causal=True)
            h = apply_norm(cfg, lp["ln2"], x)
            ek, ev = ATT.project_enc_kv(cfg, lp["cross"], enc_h)
            x = x + ATT.cross_attention(cfg, lp["cross"], h, ek, ev)
            h = apply_norm(cfg, lp["ln3"], x)
            x = x + mlp(cfg, lp["mlp"], h)
            return x, (k, v, ek, ev)
        x, (ks, vs, eks, evs) = jax.lax.scan(body, x, params["layers"])
        caches = {"k": ks, "v": vs, "enc_k": eks, "enc_v": evs,
                  "pos": pos_after}
    else:
        raise ValueError(cfg.family)

    return apply_norm(cfg, params["final_norm"], x), caches


def prefill_step(cfg, params: dict, batch: dict):
    """-> (last-token logits (B, vocab), caches)."""
    hidden, caches = forward_collect(cfg, params, batch)
    logits = (hidden[:, -1, :] @ params["lm_head"])[..., :cfg.vocab]
    return logits, caches


# --------------------------------------------------------------------------
# decode caches
# --------------------------------------------------------------------------

def init_decode_caches(cfg, batch: int, max_len: int, kind: str = "dense"):
    """ShapeDtype pytree of decode state. kind: dense | lsm."""
    dt = jnp.dtype(cfg.dtype)
    l, kv, hd = cfg.n_layers, cfg.n_kv, cfg.hd

    def zeros(shape, d=dt):
        return jnp.zeros(shape, d)

    if cfg.family == "ssm":
        sh = SSM.mamba2_decode_state_shapes(cfg, batch)
        return {"ssm": zeros((l,) + sh["ssm"][0], sh["ssm"][1]),
                "conv": zeros((l,) + sh["conv"][0], sh["conv"][1]),
                "pos": jnp.zeros((batch,), jnp.int32)}

    if cfg.family == "hybrid":
        sh = SSM.mamba2_decode_state_shapes(cfg, batch)
        n_apps = max(1, cfg.n_layers // cfg.shared_attn_every)
        out = {"ssm": zeros((l,) + sh["ssm"][0], sh["ssm"][1]),
               "conv": zeros((l,) + sh["conv"][0], sh["conv"][1]),
               "pos": jnp.zeros((batch,), jnp.int32)}
        if kind == "lsm":
            shapes = ATT.lsm_cache_shapes(cfg, batch, max_len)
            out["shared"] = {k: zeros((n_apps,) + s, d)
                             for k, (s, d) in shapes.items()}
        else:
            out["shared"] = {
                "k": zeros((n_apps, batch, max_len, kv, hd)),
                "v": zeros((n_apps, batch, max_len, kv, hd))}
        return out

    if cfg.family == "encdec":
        return {"k": zeros((l, batch, max_len, kv, hd)),
                "v": zeros((l, batch, max_len, kv, hd)),
                "enc_k": zeros((l, batch, cfg.encoder_seq, kv, hd)),
                "enc_v": zeros((l, batch, cfg.encoder_seq, kv, hd)),
                "pos": jnp.zeros((batch,), jnp.int32)}

    if kind == "lsm":
        shapes = ATT.lsm_cache_shapes(cfg, batch, max_len)
        out = {k: zeros((l,) + s, d) for k, (s, d) in shapes.items()}
        out["pos"] = jnp.zeros((batch,), jnp.int32)
        return out

    return {"k": zeros((l, batch, max_len, kv, hd)),
            "v": zeros((l, batch, max_len, kv, hd)),
            "pos": jnp.zeros((batch,), jnp.int32)}


# --------------------------------------------------------------------------
# decode step (one token)
# --------------------------------------------------------------------------

def decode_step(cfg, params: dict, token: jax.Array, caches: dict,
                kind: str = "dense"):
    """token (B,) int32 -> (logits (B, vocab), new caches)."""
    b = token.shape[0]
    pos = caches["pos"]
    x = _embed(cfg, params, token)[:, None, :]              # (B, 1, d)

    if cfg.family in ("dense", "vlm", "moe"):
        if kind == "lsm":
            x, caches = _decode_lsm_stack(cfg, params, x, caches)
        else:
            x, caches = _decode_dense_stack(cfg, params, x, caches)

    elif cfg.family == "ssm":
        def body(x, per):
            lp, s_ssm, s_conv = per
            h = apply_norm(cfg, lp["ln1"], x)
            y, ns = SSM.mamba2_decode(cfg, lp["mixer"], h,
                                      {"ssm": s_ssm, "conv": s_conv})
            return x + y, (ns["ssm"], ns["conv"])
        x, (new_ssm, new_conv) = jax.lax.scan(
            body, x, (params["layers"], caches["ssm"], caches["conv"]))
        caches = dict(caches, ssm=new_ssm, conv=new_conv)

    elif cfg.family == "hybrid":
        x, caches = _decode_hybrid(cfg, params, x, caches, kind)

    elif cfg.family == "encdec":
        pos_c = jnp.minimum(pos, params["dec_pos"].shape[0] - 1)
        x = x + params["dec_pos"][pos_c][:, None, :]

        def body(carry, per):
            x, = carry
            lp, ck, cv, ek, ev = per
            h = apply_norm(cfg, lp["ln1"], x)
            a, ck, cv = ATT.decode_self_attention(cfg, lp["attn"], h, ck, cv,
                                                  pos)
            x = x + a
            h = apply_norm(cfg, lp["ln2"], x)
            x = x + ATT.cross_attention(cfg, lp["cross"], h, ek, ev)
            h = apply_norm(cfg, lp["ln3"], x)
            x = x + mlp(cfg, lp["mlp"], h)
            return (x,), (ck, cv)
        (x,), (nk, nv) = jax.lax.scan(
            body, (x,), (params["layers"], caches["k"], caches["v"],
                         caches["enc_k"], caches["enc_v"]))
        caches = dict(caches, k=nk, v=nv)
    else:
        raise ValueError(cfg.family)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = (x[:, 0, :] @ params["lm_head"])[..., :cfg.vocab]
    caches = dict(caches, pos=pos + 1)
    return logits, caches


def _decode_dense_stack(cfg, params, x, caches):
    pos = caches["pos"]

    def body(x, per):
        lp, ck, cv = per
        h = apply_norm(cfg, lp["ln1"], x)
        a, ck, cv = ATT.decode_self_attention(cfg, lp["attn"], h, ck, cv, pos)
        x = x + a
        h = apply_norm(cfg, lp["ln2"], x)
        if "moe" in lp:
            y, _ = MOE.moe_ffn(cfg, lp["moe"], h)
            x = x + y
        else:
            x = x + mlp(cfg, lp["mlp"], h)
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(body, x,
                               (params["layers"], caches["k"], caches["v"]))
    return x, dict(caches, k=nk, v=nv)


def _decode_lsm_stack(cfg, params, x, caches):
    pos = caches["pos"]
    cache_keys = ("hot_k", "hot_v", "blk_k", "blk_v", "summ", "hot_len",
                  "n_blocks")

    def body(x, per):
        lp = per[0]
        lcache = dict(zip(cache_keys, per[1:]))
        h = apply_norm(cfg, lp["ln1"], x)
        a, lcache = ATT.lsm_decode_self_attention(cfg, lp["attn"], h,
                                                  lcache, pos)
        x = x + a
        h = apply_norm(cfg, lp["ln2"], x)
        if "moe" in lp:
            y, _ = MOE.moe_ffn(cfg, lp["moe"], h)
            x = x + y
        else:
            x = x + mlp(cfg, lp["mlp"], h)
        return x, tuple(lcache[k] for k in cache_keys)

    x, new = jax.lax.scan(
        body, x, (params["layers"],) + tuple(caches[k] for k in cache_keys))
    return x, dict(caches, **dict(zip(cache_keys, new)))


def _decode_hybrid(cfg, params, x, caches, kind):
    pos = caches["pos"]
    every = cfg.shared_attn_every
    shared = caches["shared"]

    def apply_shared(x, shared, app_idx):
        h = apply_norm(cfg, params["shared"]["ln1"], x)
        if kind == "lsm":
            keys = ("hot_k", "hot_v", "blk_k", "blk_v", "summ", "hot_len",
                    "n_blocks")
            lc = {k: jax.lax.dynamic_index_in_dim(shared[k], app_idx, 0,
                                                  keepdims=False)
                  for k in keys}
            a, lc = ATT.lsm_decode_self_attention(
                cfg, params["shared"]["attn"], h, lc, pos)
            shared = {k: jax.lax.dynamic_update_index_in_dim(
                shared[k], lc[k].astype(shared[k].dtype), app_idx, 0)
                for k in keys}
        else:
            ck = jax.lax.dynamic_index_in_dim(shared["k"], app_idx, 0, False)
            cv = jax.lax.dynamic_index_in_dim(shared["v"], app_idx, 0, False)
            a, ck, cv = ATT.decode_self_attention(
                cfg, params["shared"]["attn"], h, ck, cv, pos)
            shared = {
                "k": jax.lax.dynamic_update_index_in_dim(shared["k"], ck,
                                                         app_idx, 0),
                "v": jax.lax.dynamic_update_index_in_dim(shared["v"], cv,
                                                         app_idx, 0)}
        x = x + a
        h = apply_norm(cfg, params["shared"]["ln2"], x)
        return x + mlp(cfg, params["shared"]["mlp"], h), shared

    def body(carry, per):
        x, shared = carry
        li, lp, s_ssm, s_conv = per
        h = apply_norm(cfg, lp["ln1"], x)
        y, ns = SSM.mamba2_decode(cfg, lp["mixer"], h,
                                  {"ssm": s_ssm, "conv": s_conv})
        x = x + y
        x, shared = jax.lax.cond(
            (li % every) == every - 1,
            lambda x, sh: apply_shared(x, sh, li // every),
            lambda x, sh: (x, sh), x, shared)
        return (x, shared), (ns["ssm"], ns["conv"])

    (x, shared), (new_ssm, new_conv) = jax.lax.scan(
        body, (x, shared),
        (jnp.arange(cfg.n_layers), params["layers"], caches["ssm"],
         caches["conv"]))
    return x, dict(caches, ssm=new_ssm, conv=new_conv, shared=shared)
