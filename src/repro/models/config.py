"""Model configuration shared by every assigned architecture."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Megatron-style vocab padding for clean TP sharding."""
    return ((v + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str            # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0      # 0 -> d_model // n_heads (gemma overrides to 256)
    act: str = "swiglu"    # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False
    rope: bool = True       # whisper uses learned absolute positions instead
    rope_theta: float = 1e4
    mrope: bool = False    # qwen2-vl M-RoPE (t/h/w sections)
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    moe_dp_groups: int = 1   # routing groups; launcher sets to DP degree
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1
    # hybrid (zamba2): one *shared* attention block applied every N blocks
    shared_attn_every: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500   # precomputed frame embeddings (stub frontend)
    # serving / sLSM-KV cache
    lsm_hot_window: int = 4096
    lsm_block: int = 1024     # mu for the KV tier (tokens per cold block)
    lsm_topk: int = 16
    lsm_dp_groups: int = 1    # block-selection groups; launcher sets to |data|
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return replace(
            self, n_layers=4 if self.shared_attn_every else 2, d_model=64,
            n_heads=4, n_kv=max(1, min(self.n_kv, 2)),
            d_ff=128, vocab=512, head_dim=16 if self.head_dim else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            # no capacity drops at smoke scale: keeps prefill==decode exact
            capacity_factor=4.0 if self.n_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=32 if self.encoder_layers else 1500,
            shared_attn_every=2 if self.shared_attn_every else 0,
            mrope_sections=(4, 2, 2) if self.mrope else self.mrope_sections,
            lsm_hot_window=64, lsm_block=16, lsm_topk=2,
            dtype="float32",
        )
