"""Model zoo: one flexible LM stack covering all assigned architectures."""
from repro.models.config import ModelConfig  # noqa: F401
