"""Pure-jnp oracle for range_merge: per-row (key, seq) sort + the same
newest-wins / tombstone-drop mask, computed after the fact. This is also
the jnp backend's production range-merge path (backend.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.params import KEY_EMPTY, TOMBSTONE


def range_merge_ref(keys, vals, seqs, offsets, drop_tombstones: bool):
    """Sort-based equivalent of `range_merge_op` (same output contract).

    `offsets` is accepted for interface parity and ignored: sorting each
    row by (key, seq) yields the same stream a segment merge does, since
    the rows hold the same multiset.
    """
    del offsets
    k, s, v = jax.lax.sort((keys.astype(jnp.int32), seqs.astype(jnp.int32),
                            vals.astype(jnp.int32)), num_keys=2)
    nxt = jnp.concatenate(
        [k[:, 1:], jnp.full((k.shape[0], 1), KEY_EMPTY, k.dtype)], axis=1)
    keep = (k != KEY_EMPTY) & (k != nxt)
    if drop_tombstones:
        keep &= v != TOMBSTONE
    return k, v, s, keep
