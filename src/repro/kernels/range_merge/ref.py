"""Pure-jnp oracle for range_merge: per-row (key, seq) sort + the same
weighted survivor mask, computed after the fact. This is also the jnp
backend's production range-merge path (backend.py). Payloads ride a
post-sort gather through each row's source indices — the same Ghost
shape as the kernel, so both backends agree bitwise."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.params import KEY_EMPTY


def range_merge_ref(keys, vals, wts, seqs, offsets, drop_annihilated: bool):
    """Sort-based equivalent of `range_merge_op` (same output contract).

    `offsets` is accepted for interface parity and ignored: sorting each
    row by (key, seq) yields the same stream a segment merge does, since
    the rows hold the same multiset.
    """
    del offsets
    q, cand = keys.shape
    idx = jnp.broadcast_to(jnp.arange(cand, dtype=jnp.int32), (q, cand))
    k, s, w, idx = jax.lax.sort(
        (keys.astype(jnp.int32), seqs.astype(jnp.int32),
         wts.astype(jnp.int32), idx), num_keys=2)
    nxt = jnp.concatenate(
        [k[:, 1:], jnp.full((k.shape[0], 1), KEY_EMPTY, k.dtype)], axis=1)
    keep = (k != KEY_EMPTY) & (k != nxt)
    if drop_annihilated:
        keep &= w > 0
    v = jnp.take_along_axis(vals.astype(jnp.int32), idx, axis=1)
    v = jnp.where(k == KEY_EMPTY, 0, v)
    return k, v, w, s, keep
