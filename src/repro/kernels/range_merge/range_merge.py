"""Pallas kernel: k-way range-scan merge-dedup (paper 2.9, DESIGN.md §10).

The range engine gathers each scan's candidates — the contiguous
in-window slice of every structure — into one (Q, C) row buffer holding
P sorted segments at per-query offsets. This kernel turns those rows
into a single (key, seq)-sorted stream per scan, and on the final
tournament round computes the weighted survivor keep mask *during* the
merge, replacing the read path's historical O(total-capacity * log)
global sort with O(window) merge work.

The tournament carries the (key, weight, seq) lanes plus a provenance
index — NOT the payload lane (the Ghost property, DESIGN.md §13): the
caller gathers payloads once, after the final round, through the
surviving rows' source indices.

Shape of the computation:

  * one launch merges adjacent segment pairs for all Q scans: grid
    (Q, C / OUT_TILE), the whole candidate row VMEM-resident per scan
    (constant index_map), one output tile per grid step;
  * segment boundaries are *runtime* values (each scan prunes its own
    window), so unlike `heap_merge` the merge-path binary search runs
    on dynamic (n, m) read out of the per-scan offsets vector: each
    output lane locates its segment pair with a branch-free search over
    the paired offsets, then walks the merge-path diagonal (Green et
    al.) inside the pair — all lanes in lockstep on the VPU;
  * log2(P) rounds (driven by ops.py) halve the segment count; the
    final round also emits the keep mask: an output element survives iff
    it is not padding, the next merged element carries a different key
    (newest-wins — seqnos are globally unique, so the last element of an
    equal-key block is the newest copy, and its weight is the telescoped
    per-key weight sum), and — when annihilation is requested — its
    weight is positive (a negative weight is a delete record: the key is
    absent).

Ordering is lexicographic on (key, seq), the same rule every other merge
in the engine uses.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.params import KEY_EMPTY as _KEY_EMPTY
from repro.kernels.common import upper_bound

OUT_TILE = 512


def _before(ak, as_, bk, bs):
    """(key, seq) lexicographic strict less-than."""
    return (ak < bk) | ((ak == bk) & (as_ < bs))


def _merge_path(bk, bs, a_lo, n, a_hi, m, tt, steps: int):
    """Per-lane merge-path split: i = #elements of segment a among the
    first tt outputs of the (a, b) pair. `bk`/`bs` are the whole resident
    candidate row; a = row[a_lo : a_lo+n], b = row[a_hi : a_hi+m]."""
    lo = jnp.maximum(tt - m, 0)
    hi = jnp.minimum(tt, n)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        ai = a_lo + jnp.clip(mid, 0, jnp.maximum(n - 1, 0))
        bj = a_hi + jnp.clip(tt - mid - 1, 0, jnp.maximum(m - 1, 0))
        go_right = (_before(jnp.take(bk, ai), jnp.take(bs, ai),
                            jnp.take(bk, bj), jnp.take(bs, bj))
                    | (tt - mid - 1 >= m))
        go_right &= mid < n
        active = lo < hi
        new_lo = jnp.where(go_right, mid + 1, lo)
        new_hi = jnp.where(go_right, hi, mid)
        return (jnp.where(active, new_lo, lo), jnp.where(active, new_hi, hi))

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def _pick(bk, bw, bs, bi, a_lo, n, a_hi, m, i, j):
    """Gather the pair element a merge position (i, j) emits."""
    ai = a_lo + jnp.clip(i, 0, jnp.maximum(n - 1, 0))
    bj = a_hi + jnp.clip(j, 0, jnp.maximum(m - 1, 0))
    take_a = (j >= m) | ((i < n) & _before(jnp.take(bk, ai), jnp.take(bs, ai),
                                           jnp.take(bk, bj),
                                           jnp.take(bs, bj)))
    k = jnp.where(take_a, jnp.take(bk, ai), jnp.take(bk, bj))
    w = jnp.where(take_a, jnp.take(bw, ai), jnp.take(bw, bj))
    s = jnp.where(take_a, jnp.take(bs, ai), jnp.take(bs, bj))
    ix = jnp.where(take_a, jnp.take(bi, ai), jnp.take(bi, bj))
    return k, w, s, ix, take_a


def _round_kernel(bk_ref, bw_ref, bs_ref, bi_ref, off_ref,
                  ok_ref, ow_ref, os_ref, oi_ref, *refs, n_seg: int,
                  cand: int, final: bool, drop_annihilated: bool):
    tile = ok_ref.shape[1]
    t = pl.program_id(1) * tile + jnp.arange(tile, dtype=jnp.int32)

    bk, bw, bs, bi = bk_ref[0, :], bw_ref[0, :], bs_ref[0, :], bi_ref[0, :]
    off = off_ref[0, :]                              # (n_seg + 1,)
    total = off[n_seg]

    # locate each lane's segment pair via the paired boundaries off[::2]
    paired = off[::2]                                # (n_seg // 2 + 1,)
    p = jnp.clip(upper_bound(paired, t) - 1, 0, n_seg // 2 - 1)
    a_lo = jnp.take(off, 2 * p)
    a_hi = jnp.take(off, 2 * p + 1)
    b_hi = jnp.take(off, 2 * p + 2)
    n, m = a_hi - a_lo, b_hi - a_hi
    tt = t - a_lo

    steps = max(1, math.ceil(math.log2(cand + 1)) + 1)
    i = _merge_path(bk, bs, a_lo, n, a_hi, m, tt, steps)
    j = tt - i
    k, w, s, ix, take_a = _pick(bk, bw, bs, bi, a_lo, n, a_hi, m, i, j)
    valid = t < total
    ok_ref[0, :] = jnp.where(valid, k, _KEY_EMPTY)
    ow_ref[0, :] = jnp.where(valid, w, 0)
    os_ref[0, :] = jnp.where(valid, s, 0)
    oi_ref[0, :] = jnp.where(valid, ix, 0)

    if final:
        # weighted survivor mask, computed during the merge: the element
        # at t survives iff the *next* merged element (split advanced by
        # one on the taken side) carries a different key. The final round
        # merges the last two segments, so the pair stream IS the global
        # (key, seq) order and the neighbor test is exact. The surviving
        # record's weight is the telescoped per-key weight sum; when
        # committing annihilation, a non-positive weight drops the key.
        keep_ref = refs[0]
        i2 = i + take_a.astype(jnp.int32)
        j2 = (tt + 1) - i2
        nk, _, _, _, _ = _pick(bk, bw, bs, bi, a_lo, n, a_hi, m, i2, j2)
        nk = jnp.where(t + 1 < total, nk, _KEY_EMPTY)
        keep = valid & (k != _KEY_EMPTY) & (k != nk)
        if drop_annihilated:
            keep &= w > 0
        keep_ref[0, :] = keep


def merge_round_pallas(bk, bw, bs, bi, off, *, final: bool,
                       drop_annihilated: bool, interpret: bool = True):
    """One tournament round over (Q, C) candidate rows: merge adjacent
    segment pairs (boundaries in `off`, shape (Q, n_seg+1), n_seg even).
    Lanes are (key, weight, seq, source-index). Returns the merged lanes
    and, when `final`, the keep mask."""
    q, cand = bk.shape
    n_seg = off.shape[1] - 1
    assert n_seg >= 2 and n_seg % 2 == 0, "segment count must be even >= 2"
    assert cand % OUT_TILE == 0, f"pad candidate rows to {OUT_TILE} lanes"
    grid = (q, cand // OUT_TILE)
    row = lambda width: pl.BlockSpec((1, width), lambda i, t: (i, 0))
    out_spec = pl.BlockSpec((1, OUT_TILE), lambda i, t: (i, t))
    shapes = [jax.ShapeDtypeStruct((q, cand), jnp.int32)] * 4
    out_specs = [out_spec] * 4
    if final:
        shapes.append(jax.ShapeDtypeStruct((q, cand), jnp.bool_))
        out_specs.append(out_spec)
    return pl.pallas_call(
        functools.partial(_round_kernel, n_seg=n_seg, cand=cand, final=final,
                          drop_annihilated=drop_annihilated),
        out_shape=shapes,
        grid=grid,
        in_specs=[row(cand)] * 4 + [row(n_seg + 1)],
        out_specs=out_specs,
        interpret=interpret,
        name="slsm_range_merge",
    )(bk, bw, bs, bi, off)
