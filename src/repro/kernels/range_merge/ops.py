"""Public range-merge op: log2(P) Pallas tournament rounds + dedup mask.

Matches `range_merge_ref` (the jnp sort-based form the jnp backend uses)
exactly: rows come back (key, seq)-sorted with a keep mask that applies
the weighted survivor rule (newest-wins, annihilation when requested) —
computed by the kernel during the final merge round, not by a separate
sort pass. Only the (key, weight, seq, index) lanes run the tournament;
payloads are gathered once at the end through the rows' source indices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.params import KEY_EMPTY
from repro.kernels.range_merge.range_merge import OUT_TILE, merge_round_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnums=5)
def range_merge_op(keys, vals, wts, seqs, offsets, drop_annihilated: bool):
    """Merge P sorted segments per candidate row (paper 2.9).

    keys/vals/wts/seqs: (Q, C) int32 rows, each holding P sorted-by-(key,
    seq) segments back to back; offsets: (Q, P+1) int32 exclusive
    segment boundaries (lanes past offsets[:, P] are padding). Returns
    (keys, vals, wts, seqs, keep): rows in global (key, seq) order,
    `keep` marking the newest copy of every key (negative-weight rows
    dropped when `drop_annihilated`).
    """
    q, cand = keys.shape
    n_seg = offsets.shape[1] - 1
    keys = keys.astype(jnp.int32)
    vals = vals.astype(jnp.int32)
    wts = wts.astype(jnp.int32)
    seqs = seqs.astype(jnp.int32)
    offsets = offsets.astype(jnp.int32)

    # pad rows to the kernel tile and the segment count to a power of two
    # (appended segments are empty: their boundary repeats the last one)
    cp = ((cand + OUT_TILE - 1) // OUT_TILE) * OUT_TILE
    if cp != cand:
        pk = jnp.full((q, cp - cand), KEY_EMPTY, jnp.int32)
        keys = jnp.concatenate([keys, pk], axis=1)
        vals = jnp.concatenate([vals, jnp.zeros_like(pk)], axis=1)
        wts = jnp.concatenate([wts, jnp.zeros_like(pk)], axis=1)
        seqs = jnp.concatenate([seqs, jnp.zeros_like(pk)], axis=1)
    s0 = max(2, 1 << (n_seg - 1).bit_length())
    if s0 != n_seg:
        tail = jnp.repeat(offsets[:, -1:], s0 - n_seg, axis=1)
        offsets = jnp.concatenate([offsets, tail], axis=1)

    interpret = not _on_tpu()
    idx = jnp.broadcast_to(jnp.arange(cp, dtype=jnp.int32), (q, cp))
    mk, mw, ms = keys, wts, seqs
    off = offsets
    segs = s0
    while segs > 2:
        mk, mw, ms, idx = merge_round_pallas(
            mk, mw, ms, idx, off, final=False,
            drop_annihilated=drop_annihilated, interpret=interpret)
        off = off[:, ::2]
        segs //= 2
    mk, mw, ms, idx, keep = merge_round_pallas(
        mk, mw, ms, idx, off, final=True,
        drop_annihilated=drop_annihilated, interpret=interpret)
    # payload gather — one pass, after the tournament; padding lanes
    # (KEY_EMPTY) are forced to 0 so both backends agree bitwise there
    mv = jnp.take_along_axis(vals, idx, axis=1)
    mv = jnp.where(mk == KEY_EMPTY, 0, mv)
    return (mk[:, :cand], mv[:, :cand], mw[:, :cand], ms[:, :cand],
            keep[:, :cand])
