"""Public range-merge op: log2(P) Pallas tournament rounds + dedup mask.

Matches `range_merge_ref` (the jnp sort-based form the jnp backend uses)
exactly: rows come back (key, seq)-sorted with a keep mask that applies
newest-wins dedup and (optionally) tombstone dropping — computed by the
kernel during the final merge round, not by a separate sort pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.params import KEY_EMPTY
from repro.kernels.range_merge.range_merge import OUT_TILE, merge_round_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnums=4)
def range_merge_op(keys, vals, seqs, offsets, drop_tombstones: bool):
    """Merge P sorted segments per candidate row (paper 2.9).

    keys/vals/seqs: (Q, C) int32 rows, each holding P sorted-by-(key,
    seq) segments back to back; offsets: (Q, P+1) int32 exclusive
    segment boundaries (lanes past offsets[:, P] are padding). Returns
    (keys, vals, seqs, keep): rows in global (key, seq) order, `keep`
    marking the newest live copy of every key (tombstones dropped when
    `drop_tombstones`).
    """
    q, cand = keys.shape
    n_seg = offsets.shape[1] - 1
    keys = keys.astype(jnp.int32)
    vals = vals.astype(jnp.int32)
    seqs = seqs.astype(jnp.int32)
    offsets = offsets.astype(jnp.int32)

    # pad rows to the kernel tile and the segment count to a power of two
    # (appended segments are empty: their boundary repeats the last one)
    cp = ((cand + OUT_TILE - 1) // OUT_TILE) * OUT_TILE
    if cp != cand:
        pk = jnp.full((q, cp - cand), KEY_EMPTY, jnp.int32)
        keys = jnp.concatenate([keys, pk], axis=1)
        vals = jnp.concatenate([vals, jnp.zeros_like(pk)], axis=1)
        seqs = jnp.concatenate([seqs, jnp.zeros_like(pk)], axis=1)
    s0 = max(2, 1 << (n_seg - 1).bit_length())
    if s0 != n_seg:
        tail = jnp.repeat(offsets[:, -1:], s0 - n_seg, axis=1)
        offsets = jnp.concatenate([offsets, tail], axis=1)

    interpret = not _on_tpu()
    off = offsets
    segs = s0
    while segs > 2:
        keys, vals, seqs = merge_round_pallas(
            keys, vals, seqs, off, final=False,
            drop_tombstones=drop_tombstones, interpret=interpret)
        off = off[:, ::2]
        segs //= 2
    keys, vals, seqs, keep = merge_round_pallas(
        keys, vals, seqs, off, final=True,
        drop_tombstones=drop_tombstones, interpret=interpret)
    return keys[:, :cand], vals[:, :cand], seqs[:, :cand], keep[:, :cand]
