"""Range-scan k-way merge-dedup kernel (paper 2.9, DESIGN.md §10)."""
from repro.kernels.range_merge.ops import range_merge_op  # noqa: F401
from repro.kernels.range_merge.ref import range_merge_ref  # noqa: F401
