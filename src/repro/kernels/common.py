"""Shared in-kernel primitives: vectorized binary searches.

`jnp.searchsorted` does not lower inside Pallas TPU kernels; these are
branch-free fori_loop binary searches over VMEM-resident sorted arrays,
vectorized across query lanes (every lane halves its interval in lockstep
— log2(n) dense compare/select steps on the VPU).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _bsearch(arr: jax.Array, q: jax.Array, strict: bool) -> jax.Array:
    n = arr.shape[0]
    steps = max(1, math.ceil(math.log2(n + 1)))

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        v = arr[jnp.clip(mid, 0, n - 1)]
        go_right = (v <= q) if strict else (v < q)
        active = lo < hi
        new_lo = jnp.where(go_right, mid + 1, lo)
        new_hi = jnp.where(go_right, hi, mid)
        return (jnp.where(active, new_lo, lo), jnp.where(active, new_hi, hi))

    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, n, jnp.int32)
    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def lower_bound(arr: jax.Array, q: jax.Array) -> jax.Array:
    """First index i with arr[i] >= q (searchsorted side='left')."""
    return _bsearch(arr, q, strict=False)


def upper_bound(arr: jax.Array, q: jax.Array) -> jax.Array:
    """First index i with arr[i] > q (searchsorted side='right')."""
    return _bsearch(arr, q, strict=True)
