"""Pallas TPU kernels for the sLSM hot paths.

Each subpackage holds:
  <name>.py — the pl.pallas_call kernel with explicit BlockSpec VMEM tiling
  ops.py    — the jit'd public wrapper (interpret=True off-TPU)
  ref.py    — the pure-jnp oracle the kernel is tested against

Kernels:
  bloom_probe   — batched Bloom-filter membership tests (paper 2.3)
  heap_merge    — HeapMerge (paper 2.5) as a merge-path binary-search
                  network: k-way newest-wins merge in log2(k) dense passes
  fence_lookup  — fence-pointer page search on sorted runs (paper 2.4)
  range_merge   — range-scan k-way merge-dedup (paper 2.9): per-scan
                  sorted candidate segments merged with newest-wins
                  dedup / tombstone elision applied during the merge
  lsm_attention — tiered decode attention over an sLSM KV cache (hot
                  window + summary-gated cold blocks) — the paper's
                  read path fused into attention
"""
