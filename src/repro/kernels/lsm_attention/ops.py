"""Public ops for sLSM-tiered decode attention.

`decode_attention_op`      — flash-decode over a dense (ragged) KV cache.
`lsm_decode_attention_op`  — the paper's technique: hot window (memory
    buffer) + summary-gated top-k cold blocks (Bloom/fence-pointer skip),
    then one fused attention over the ~O(W + k*mu) selected tokens instead
    of O(L). This is what makes 524k-token decode lowerable for attention
    architectures (DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lsm_attention.lsm_attention import (L_TILE,
                                                       decode_attention_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_len(x, target, axis):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnums=4)
def decode_attention_op(q, k, v, lengths, scale: float):
    """q (B, H, dh); k, v (B, L, KV, dh); lengths (B,) -> (B, H, dh)."""
    b, h, dh = q.shape
    _, l, kv, _ = k.shape
    lp = ((l + L_TILE - 1) // L_TILE) * L_TILE
    k = _pad_len(k, lp, 1)
    v = _pad_len(v, lp, 1)
    valid = (jnp.arange(lp, dtype=jnp.int32)[None, :]
             < lengths[:, None]).astype(jnp.int8)
    valid = jnp.broadcast_to(valid[:, None, :], (b, kv, lp))
    return decode_attention_pallas(q, k, v, valid, scale,
                                   interpret=not _on_tpu())


def select_blocks(q, summaries, n_blocks, topk: int):
    """Score cold blocks against the query and pick top-k per kv-head.

    The summary vector is the block's "filter": q . summary upper-bounds
    how much the block can matter; low scores are skipped without reading
    the block — exactly the paper's Bloom-gated run skip.

    q (B, H, dh); summaries (B, NB, KV, dh); n_blocks (B,)
    -> ids (B, KV, topk) int32, ok (B, KV, topk) bool
    """
    b, h, dh = q.shape
    _, nb, kv, _ = summaries.shape
    group = h // kv
    qg = q.reshape(b, kv, group, dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bnkd->bkgn", qg, summaries.astype(jnp.float32))
    score = s.max(axis=2)                                    # (B, KV, NB)
    blk_ok = jnp.arange(nb, dtype=jnp.int32)[None, :] < n_blocks[:, None]
    score = jnp.where(blk_ok[:, None, :], score, -jnp.inf)
    top_score, ids = jax.lax.top_k(score, topk)
    return ids.astype(jnp.int32), jnp.isfinite(top_score)


@functools.partial(jax.jit, static_argnums=(8, 9))
def lsm_decode_attention_op(q, hot_k, hot_v, hot_len,
                            blk_k, blk_v, summaries, n_blocks,
                            topk: int, scale: float):
    """Tiered decode attention.

    q (B, H, dh)
    hot_k/v (B, W, KV, dh), hot_len (B,)        — memory buffer
    blk_k/v (B, NB, mu, KV, dh)                 — sealed cold blocks
    summaries (B, NB, KV, dh), n_blocks (B,)    — block index (the filter)
    -> (B, H, dh)
    """
    b, h, dh = q.shape
    _, nb, mu, kv, _ = blk_k.shape
    w = hot_k.shape[1]
    ids, ok = select_blocks(q, summaries, n_blocks, topk)    # (B, KV, topk)

    # gather the selected blocks, per batch x kv-head
    def per_b(bk, bv, idb):                                  # over batch
        def per_kv(kvi):
            sel_k = bk[idb[kvi], :, kvi, :]                  # (topk, mu, dh)
            sel_v = bv[idb[kvi], :, kvi, :]
            return sel_k, sel_v
        sk, sv = jax.vmap(per_kv)(jnp.arange(kv))            # (KV, topk, mu, dh)
        return sk, sv

    sel_k, sel_v = jax.vmap(per_b)(blk_k, blk_v, ids)        # (B, KV, topk, mu, dh)
    cold_k = sel_k.reshape(b, kv, topk * mu, dh).transpose(0, 2, 1, 3)
    cold_v = sel_v.reshape(b, kv, topk * mu, dh).transpose(0, 2, 1, 3)

    k_all = jnp.concatenate([hot_k, cold_k], axis=1)         # (B, W+k*mu, KV, dh)
    v_all = jnp.concatenate([hot_v, cold_v], axis=1)

    valid_hot = (jnp.arange(w, dtype=jnp.int32)[None, :]
                 < hot_len[:, None])[:, None, :]             # (B, 1, W)
    valid_hot = jnp.broadcast_to(valid_hot, (b, kv, w))
    valid_cold = jnp.repeat(ok, mu, axis=2)                  # (B, KV, topk*mu)
    valid = jnp.concatenate([valid_hot, valid_cold], axis=2).astype(jnp.int8)

    l = k_all.shape[1]
    lp = ((l + L_TILE - 1) // L_TILE) * L_TILE
    k_all = _pad_len(k_all, lp, 1)
    v_all = _pad_len(v_all, lp, 1)
    valid = _pad_len(valid, lp, 2)
    return decode_attention_pallas(q, k_all, v_all, valid, scale,
                                   interpret=not _on_tpu())
