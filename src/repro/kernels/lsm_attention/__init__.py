from repro.kernels.lsm_attention.ops import (  # noqa: F401
    decode_attention_op, lsm_decode_attention_op, select_blocks)
from repro.kernels.lsm_attention.ref import (  # noqa: F401
    decode_attention_ref, select_blocks_ref)
