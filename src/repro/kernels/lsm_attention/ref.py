"""Pure-jnp oracles for lsm_attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, scale: float):
    """Dense masked softmax decode attention.

    q (B, H, dh); k, v (B, L, KV, dh); lengths (B,) -> (B, H, dh)
    """
    b, h, dh = q.shape
    _, l, kv, _ = k.shape
    group = h // kv
    kx = jnp.repeat(k, group, axis=2)        # (B, L, H, dh)
    vx = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bhd,blhd->bhl", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * scale
    mask = jnp.arange(l)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhl,blhd->bhd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def select_blocks_ref(q, summaries, topk: int):
    """Top-k cold blocks by summary score (the Bloom/fence analogue).

    q (B, H, dh); summaries (B, NB, KV, dh) -> (B, KV, topk) block ids.
    Scores are max over the kv-group's query heads of q . summary.
    """
    b, h, dh = q.shape
    _, nb, kv, _ = summaries.shape
    group = h // kv
    qg = q.reshape(b, kv, group, dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bnkd->bkgn", qg, summaries.astype(jnp.float32))
    score = s.max(axis=2)                     # (B, KV, NB)
    _, ids = jax.lax.top_k(score, topk)
    return ids.astype(jnp.int32)
