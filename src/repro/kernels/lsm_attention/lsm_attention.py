"""Pallas kernel: single-token (decode) attention over an sLSM-tiered KV
cache — the paper's read path fused into attention.

Mapping (DESIGN.md §3): the KV cache is managed like the sLSM —
  * hot window  == memory buffer (recent tokens, always searched),
  * cold blocks == disk runs of mu tokens each, with per-block summary
    vectors playing the Bloom-filter/fence-pointer role: a cheap test that
    rules blocks out before any of their bytes are paged in,
  * block selection (ops.py) == "skip the run on a filter miss": only the
    top-k scoring blocks are gathered; everything else is never read.

This kernel is the fused *search*: one query token attends over the
selected token set with a numerically-stable online softmax (flash-decode
schedule). Grid = (batch, q_heads, length_tiles); the length axis is the
reduction, carried in VMEM scratch (m, l, acc). GQA is folded into the
BlockSpec index_map: q-head h reads kv-head h // (H // KV) — no K/V
expansion is materialized. Masking is a per-(batch, kv-head) validity
bitmap so ragged hot windows and partially-selected block sets stay exact.

Per grid step VMEM: K,V tiles 2 x (L_TILE, dh) + q (dh,) + valid (L_TILE,)
+ scratch (dh + 2) f32 — ~0.5 MiB at L_TILE=512, dh=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

L_TILE = 512
NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, scale: float):
    lt = pl.program_id(2)

    @pl.when(lt == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)              # (dh,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (L_TILE, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)           # (L_TILE, dh)
    valid = valid_ref[0, 0, :] != 0                     # (L_TILE,)

    s = (k @ q) * scale                                  # (L_TILE,)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)        # (L_TILE,)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * corr + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[0] = m_new

    @pl.when(lt == pl.num_programs(2) - 1)
    def _fin():
        denom = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            valid: jax.Array, scale: float,
                            interpret: bool = True) -> jax.Array:
    """q (B, H, dh); k, v (B, L, KV, dh); valid (B, KV, L) int8
    -> out (B, H, dh)."""
    b, h, dh = q.shape
    _, l, kv, _ = k.shape
    assert l % L_TILE == 0, f"pad cache length to a multiple of {L_TILE}"
    assert h % kv == 0
    group = h // kv
    grid = (b, h, l // L_TILE)
    return pl.pallas_call(
        functools.partial(_decode_attn_kernel, scale=scale),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda bi, hi, li: (bi, hi, 0)),
            pl.BlockSpec((1, L_TILE, 1, dh),
                         lambda bi, hi, li: (bi, li, hi // group, 0)),
            pl.BlockSpec((1, L_TILE, 1, dh),
                         lambda bi, hi, li: (bi, li, hi // group, 0)),
            pl.BlockSpec((1, 1, L_TILE),
                         lambda bi, hi, li: (bi, hi // group, li)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda bi, hi, li: (bi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),     # running max
            pltpu.VMEM((1,), jnp.float32),     # running denominator
            pltpu.VMEM((dh,), jnp.float32),    # running numerator
        ],
        interpret=interpret,
        name="slsm_decode_attention",
    )(q, k, v, valid)
