"""Pure-jnp oracle for heap_merge: multi-operand stable sort + the same
newest-wins epilogue as the engine core."""
from __future__ import annotations

from repro.core import runs as RU


def merge_two_ref(ak, av, as_, bk, bv, bs):
    import jax.numpy as jnp
    k = jnp.concatenate([ak, bk])
    v = jnp.concatenate([av, bv])
    s = jnp.concatenate([as_, bs])
    return RU.sort_by_key_seq(k, v, s)


def heap_merge_ref(keys2d, vals2d, seqs2d, drop_tombstones: bool):
    """Full k-way merge + dedup oracle (== engine's merge_runs)."""
    return RU.merge_runs(keys2d, vals2d, seqs2d, drop_tombstones)
