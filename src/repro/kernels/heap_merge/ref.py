"""Pure-jnp oracle for heap_merge: multi-operand stable sort + the same
weighted survivor epilogue as the engine core."""
from __future__ import annotations

from repro.core import runs as RU


def merge_two_ref(ak, av, aw, as_, bk, bv, bw, bs):
    import jax.numpy as jnp
    k = jnp.concatenate([ak, bk])
    v = jnp.concatenate([av, bv])
    w = jnp.concatenate([aw, bw])
    s = jnp.concatenate([as_, bs])
    return RU.sort_records(k, v, w, s)


def heap_merge_ref(keys2d, vals2d, wts2d, seqs2d, drop_annihilated: bool):
    """Full k-way merge + dedup oracle (== engine's merge_runs)."""
    return RU.merge_runs(keys2d, vals2d, wts2d, seqs2d, drop_annihilated)
