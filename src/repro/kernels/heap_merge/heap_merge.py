"""Pallas kernel: HeapMerge (paper Algorithm 1) as a merge-path network.

The paper's min-heap pops one element per step — inherently serial, no TPU
analogue. The TPU-native equivalent keeps the O(n log k) work bound but
makes every step dense:

  * two-way merge = "merge path" (Green et al.): output position t is
    produced by exactly one (i, j = t - i) split of the two inputs; the
    split is found by a branch-free binary search on the diagonal, one
    search per output lane, all lanes in lockstep on the VPU;
  * k-way merge = a log2(k) tournament of two-way merges (ops.py);
  * weighted survivor mask (newest-wins + annihilation commit) = a
    shift-compare + weight-sign epilogue (ops.py), exactly the paper's
    "only the highest-ranked run's value is written" with deletes as
    -1-weight records (DESIGN.md §13).

The merge network carries the (key, weight, seq) lanes plus a provenance
index — NOT the payload lane. Payloads are gathered once, after the
tournament, through the surviving rows' source indices (the Ghost
property: annihilated rows never cost payload bandwidth inside the
merge).

Ordering is lexicographic on (key, seq) — the paper's run-recency rule
generalized to global seqnos.

VMEM: both inputs are grid-resident (constant index_map); each grid step
writes one OUT_TILE of the output. Inputs up to ~200K elements/side
(4 arrays x 2 sides x 4B ≈ 6 MiB) fit v5e VMEM; larger merges split at
the tournament layer in ops.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OUT_TILE = 512


def _before(ak, as_, bk, bs):
    """(key, seq) lexicographic strict less-than."""
    return (ak < bk) | ((ak == bk) & (as_ < bs))


def _merge_kernel(ak_ref, aw_ref, as_ref, ai_ref,
                  bk_ref, bw_ref, bs_ref, bi_ref,
                  ok_ref, ow_ref, os_ref, oi_ref, *, n: int, m: int):
    tile = ok_ref.shape[0]
    t = pl.program_id(0) * tile + jnp.arange(tile, dtype=jnp.int32)

    ak, aw, as_, aidx = ak_ref[...], aw_ref[...], as_ref[...], ai_ref[...]
    bk, bw, bs, bidx = bk_ref[...], bw_ref[...], bs_ref[...], bi_ref[...]

    # merge-path diagonal binary search: find i = #elements taken from a
    # among the first t outputs. Invariant: i in [max(0, t-m), min(t, n)].
    lo = jnp.maximum(t - m, 0)
    hi = jnp.minimum(t, n)
    steps = max(1, math.ceil(math.log2(max(n, m) + 1)) + 1)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        # a[mid] precedes b[t-mid-1]  =>  a[mid] is within the first t
        # outputs  =>  i > mid.
        ai = jnp.clip(mid, 0, n - 1)
        bj = jnp.clip(t - mid - 1, 0, m - 1)
        go_right = _before(ak[ai], as_[ai], bk[bj], bs[bj]) | (t - mid - 1 >= m)
        go_right &= mid < n
        active = lo < hi
        new_lo = jnp.where(go_right, mid + 1, lo)
        new_hi = jnp.where(go_right, hi, mid)
        return (jnp.where(active, new_lo, lo), jnp.where(active, new_hi, hi))

    i, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    j = t - i
    ai = jnp.clip(i, 0, n - 1)
    bj = jnp.clip(j, 0, m - 1)
    take_a = (j >= m) | ((i < n) & _before(ak[ai], as_[ai], bk[bj], bs[bj]))
    ok_ref[...] = jnp.where(take_a, ak[ai], bk[bj])
    ow_ref[...] = jnp.where(take_a, aw[ai], bw[bj])
    os_ref[...] = jnp.where(take_a, as_[ai], bs[bj])
    oi_ref[...] = jnp.where(take_a, aidx[ai], bidx[bj])


def merge_two_pallas(ak, aw, as_, aidx, bk, bw, bs, bidx,
                     interpret: bool = True):
    """Merge two (key, seq)-sorted runs into one sorted (N+M,) run.

    Lanes are (key, weight, seq, source-index); the payload never enters
    the kernel — callers gather it through the surviving indices.
    """
    n, m = ak.shape[0], bk.shape[0]
    total = n + m
    assert total % OUT_TILE == 0, f"pad inputs so N+M % {OUT_TILE} == 0"
    grid = (total // OUT_TILE,)
    resident = lambda shape: pl.BlockSpec((shape,), lambda i: (0,))
    out_spec = pl.BlockSpec((OUT_TILE,), lambda i: (i,))
    shapes = [jax.ShapeDtypeStruct((total,), jnp.int32)] * 4
    return pl.pallas_call(
        functools.partial(_merge_kernel, n=n, m=m),
        out_shape=shapes,
        grid=grid,
        in_specs=[resident(n)] * 4 + [resident(m)] * 4,
        out_specs=[out_spec] * 4,
        interpret=interpret,
        name="slsm_heap_merge",
    )(ak, aw, as_, aidx, bk, bw, bs, bidx)
