"""Public HeapMerge op: tournament of Pallas two-way merges + weighted dedup.

Matches the engine's `merge_runs` output exactly (same compaction layout)
— the engine can swap this in for the sort-based path on TPU. Only the
(key, weight, seq, source-index) lanes run the tournament; the payload
lane is gathered once at the end through the surviving rows' indices
(the Ghost property, DESIGN.md §13).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import runs as RU
from repro.core.params import KEY_EMPTY
from repro.kernels.heap_merge.heap_merge import OUT_TILE, merge_two_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(arr, total, fill):
    pad = total - arr.shape[0]
    if pad <= 0:
        return arr
    return jnp.concatenate([arr, jnp.full((pad,), fill, arr.dtype)])


@functools.partial(jax.jit, static_argnums=4)
def heap_merge_op(keys2d, vals2d, wts2d, seqs2d, drop_annihilated: bool):
    """Merge k sorted runs (k, cap) -> compacted run (k*cap,), newest wins.

    log2(k) tournament passes of the merge-path kernel over the
    (key, weight, seq, index) lanes, then the weighted survivor epilogue
    (annihilation commit when `drop_annihilated`) and one payload gather.
    Returns (keys, vals, wts, seqs, count).
    """
    k, cap = keys2d.shape
    interpret = not _on_tpu()
    runs = [(keys2d[i].astype(jnp.int32), wts2d[i].astype(jnp.int32),
             seqs2d[i].astype(jnp.int32),
             jnp.arange(cap, dtype=jnp.int32) + i * cap)
            for i in range(k)]
    while len(runs) > 1:
        nxt = []
        for i in range(0, len(runs) - 1, 2):
            (ak, aw, as_, ai), (bk, bw, bs, bi) = runs[i], runs[i + 1]
            tgt_a = ((ak.shape[0] + OUT_TILE - 1) // OUT_TILE) * OUT_TILE
            tgt_b = ((bk.shape[0] + OUT_TILE - 1) // OUT_TILE) * OUT_TILE
            ak = _pad_to(ak, tgt_a, KEY_EMPTY)
            aw, as_ = _pad_to(aw, tgt_a, 0), _pad_to(as_, tgt_a, 0)
            ai = _pad_to(ai, tgt_a, 0)
            bk = _pad_to(bk, tgt_b, KEY_EMPTY)
            bw, bs = _pad_to(bw, tgt_b, 0), _pad_to(bs, tgt_b, 0)
            bi = _pad_to(bi, tgt_b, 0)
            nxt.append(merge_two_pallas(ak, aw, as_, ai, bk, bw, bs, bi,
                                        interpret=interpret))
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    mk, mw, ms, mi = runs[0]
    valid = RU.survivor_mask(mk, mw, drop_annihilated)
    order = jnp.argsort((~valid).astype(jnp.int32), stable=True)
    ok = valid[order]
    out_k = jnp.where(ok, mk[order], KEY_EMPTY)
    out_w = jnp.where(ok, mw[order], 0)
    out_s = jnp.where(ok, ms[order], 0)
    # payload gather — survivors only (annihilated rows never touch vals)
    flat_v = vals2d.reshape(-1).astype(jnp.int32)
    out_v = jnp.where(ok, flat_v[mi[order]], 0)
    total = keys2d.shape[0] * keys2d.shape[1]
    return (out_k[:total], out_v[:total], out_w[:total], out_s[:total],
            valid.sum(dtype=jnp.int32))
