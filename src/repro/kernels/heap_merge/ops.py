"""Public HeapMerge op: tournament of Pallas two-way merges + newest-wins.

Matches the engine's `merge_runs` output exactly (same compaction layout)
— the engine can swap this in for the sort-based path on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import runs as RU
from repro.core.params import KEY_EMPTY
from repro.kernels.heap_merge.heap_merge import OUT_TILE, merge_two_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(arr, total, fill):
    pad = total - arr.shape[0]
    if pad <= 0:
        return arr
    return jnp.concatenate([arr, jnp.full((pad,), fill, arr.dtype)])


@functools.partial(jax.jit, static_argnums=3)
def heap_merge_op(keys2d, vals2d, seqs2d, drop_tombstones: bool):
    """Merge k sorted runs (k, cap) -> compacted run (k*cap,), newest wins.

    log2(k) tournament passes of the merge-path kernel, then the dedup /
    tombstone-commit epilogue. Returns (keys, vals, seqs, count).
    """
    k = keys2d.shape[0]
    runs = [(keys2d[i].astype(jnp.int32), vals2d[i].astype(jnp.int32),
             seqs2d[i].astype(jnp.int32)) for i in range(k)]
    interpret = not _on_tpu()
    while len(runs) > 1:
        nxt = []
        for i in range(0, len(runs) - 1, 2):
            (ak, av, as_), (bk, bv, bs) = runs[i], runs[i + 1]
            tgt_a = ((ak.shape[0] + OUT_TILE - 1) // OUT_TILE) * OUT_TILE
            tgt_b = ((bk.shape[0] + OUT_TILE - 1) // OUT_TILE) * OUT_TILE
            ak = _pad_to(ak, tgt_a, KEY_EMPTY)
            av, as_ = _pad_to(av, tgt_a, 0), _pad_to(as_, tgt_a, 0)
            bk = _pad_to(bk, tgt_b, KEY_EMPTY)
            bv, bs = _pad_to(bv, tgt_b, 0), _pad_to(bs, tgt_b, 0)
            nxt.append(merge_two_pallas(ak, av, as_, bk, bv, bs,
                                        interpret=interpret))
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    mk, mv, ms = runs[0]
    valid = RU.newest_wins_mask(mk, mv, drop_tombstones)
    out_k, out_v, out_s, cnt = RU.compact(mk, mv, ms, valid)
    total = keys2d.shape[0] * keys2d.shape[1]
    return out_k[:total], out_v[:total], out_s[:total], cnt
