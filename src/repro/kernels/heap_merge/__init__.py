from repro.kernels.heap_merge.ops import heap_merge_op  # noqa: F401
from repro.kernels.heap_merge.ref import heap_merge_ref, merge_two_ref  # noqa: F401
