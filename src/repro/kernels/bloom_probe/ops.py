"""Public jit'd wrapper: pads queries to the tile size, picks the backend."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bloom_probe.bloom_probe import Q_TILE, bloom_probe_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnums=(2, 3))
def bloom_probe_op(words: jax.Array, keys: jax.Array, k: int,
                   bits: int | None = None) -> jax.Array:
    """(W,) uint32, (Q,) int32 -> (Q,) bool. Tile-padded Pallas probe.

    `bits` = effective filter width (static, default the whole bitset) —
    the per-level bit allocation the adaptive tuner emits (DESIGN.md §9).
    """
    q = keys.shape[0]
    qp = ((q + Q_TILE - 1) // Q_TILE) * Q_TILE
    padded = jnp.zeros((qp,), jnp.int32).at[:q].set(keys.astype(jnp.int32))
    hit = bloom_probe_pallas(words, padded, k, bits, interpret=not _on_tpu())
    return hit[:q].astype(bool)
