"""Pallas kernel: batched Bloom-filter probes (paper 2.3).

Design (TPU): the bitset lives in VMEM for the whole grid (its BlockSpec
index_map is constant, so it is copied HBM->VMEM once and reused across
grid steps). Queries stream through in tiles of Q_TILE lanes; each lane
computes its k double-hashed probe positions (Murmur3 finalizer — pure
VPU integer ops) and gathers k words from the resident bitset. The paper's
"filter test is far cheaper than the deep search" becomes: a probe tile
touches k*Q_TILE words of VMEM instead of paging a mu-wide run window
from HBM.

VMEM budget per grid step (defaults): bitset (<= 2^20 words = 4 MiB)
+ Q_TILE=1024 queries (4 KiB) + out (1 KiB) — fits v5e VMEM (~16 MiB)
with headroom for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.bloom import SEED1, SEED2, fmix32

Q_TILE = 1024


def _probe_kernel(keys_ref, words_ref, out_ref, *, k: int, bits: int):
    keys = keys_ref[...]                                   # (Q_TILE,) int32
    words = words_ref[...]                                 # (W,) uint32
    u = jax.lax.bitcast_convert_type(keys, jnp.uint32)
    h1 = fmix32(u ^ SEED1)
    h2 = fmix32(u ^ SEED2) | np.uint32(1)
    hit = jnp.ones(keys.shape, jnp.int32)
    for i in range(k):  # unrolled: k is small (paper: k = -log2(eps))
        pos = ((h1 + np.uint32(i) * h2) % np.uint32(bits)).astype(jnp.int32)
        w = jnp.take(words, pos // 32, axis=0)
        bit = (w >> (pos % 32).astype(jnp.uint32)) & np.uint32(1)
        hit &= bit.astype(jnp.int32)
    out_ref[...] = hit


def bloom_probe_pallas(words: jax.Array, keys: jax.Array, k: int,
                       bits: int | None = None,
                       interpret: bool = True) -> jax.Array:
    """(W,) uint32 filter, (Q,) int32 keys -> (Q,) int32 {0,1} membership.

    `bits` is the effective filter size (static; default = the whole
    bitset). The adaptive tuner sizes the physical bitset for its
    densest per-level allocation and probes at the current allocation's
    smaller width — positions stay in [0, bits), the VMEM-resident tail
    words are simply never gathered."""
    q = keys.shape[0]
    assert q % Q_TILE == 0, f"pad queries to a multiple of {Q_TILE}"
    if bits is None:
        bits = words.shape[0] * 32
    assert bits <= words.shape[0] * 32
    grid = (q // Q_TILE,)
    return pl.pallas_call(
        functools.partial(_probe_kernel, k=k, bits=bits),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_TILE,), lambda i: (i,)),     # query tile
            pl.BlockSpec((words.shape[0],), lambda i: (0,)),  # resident bitset
        ],
        out_specs=pl.BlockSpec((Q_TILE,), lambda i: (i,)),
        interpret=interpret,
        name="slsm_bloom_probe",
    )(keys, words)
