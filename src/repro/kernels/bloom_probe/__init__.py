from repro.kernels.bloom_probe.ops import bloom_probe_op  # noqa: F401
from repro.kernels.bloom_probe.ref import bloom_probe_ref  # noqa: F401
