"""Pure-jnp oracle for the bloom_probe kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bloom import bloom_probe


def bloom_probe_ref(words: jax.Array, keys: jax.Array, k: int,
                    bits: int | None = None) -> jax.Array:
    return bloom_probe(words, keys, k, bits).astype(jnp.int32)
