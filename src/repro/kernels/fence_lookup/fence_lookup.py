"""Pallas kernel: fence-pointer page lookup on a sorted run (paper 2.4).

Paper read path per disk run: binary-search the fence pointers (one key per
mu-slot page), then binary-search the single page they bound. TPU form:

  * fences and the run both stay VMEM-resident across the grid (constant
    index_map) — fences are tiny, the run is the paged payload;
  * a tile of queries binary-searches the fences in lockstep (branch-free
    lane-parallel search, log2(F) steps);
  * the bounded page is then scanned with a *dense vectorized compare*
    rather than a second binary search: mu contiguous int32 lanes per query
    are a handful of VPU ops, and the gather of (Q_TILE, mu) contiguous
    windows is the TPU analogue of "load one disk page per lookup".

Output is the element index of the hit (or -1): value/seq gathers and
Bloom/min-max gating live in ops.py where they compose with the engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import upper_bound

Q_TILE = 256


def _fence_kernel(q_ref, fences_ref, keys_ref, count_ref, out_ref, *, mu: int):
    qs = q_ref[...]                       # (Q_TILE,)
    fences = fences_ref[...]              # (F,)
    keys = keys_ref[...]                  # (cap,)
    count = count_ref[0]

    f = upper_bound(fences, qs) - 1       # page index per query
    start = jnp.clip(f, 0, fences.shape[0] - 1) * mu
    # strided fence views (mu = base_mu * stride, DESIGN.md §9) can leave
    # a partial last page: pin the window inside the run (it still covers
    # the whole partial fence group; keys are globally sorted, so a
    # window that reaches back before the group stays correct)
    start = jnp.minimum(start, keys.shape[0] - mu)

    # dense page scan: gather each query's mu-window and compare
    win_idx = start[:, None] + jnp.arange(mu, dtype=jnp.int32)[None, :]
    win = jnp.take(keys, win_idx, axis=0)            # (Q_TILE, mu)
    eq = win == qs[:, None]
    off = jnp.argmax(eq, axis=1).astype(jnp.int32)
    hit = jnp.any(eq, axis=1) & (start + off < count)
    out_ref[...] = jnp.where(hit, start + off, -1)


def fence_lookup_pallas(queries: jax.Array, fences: jax.Array,
                        keys: jax.Array, count: jax.Array, mu: int,
                        interpret: bool = True) -> jax.Array:
    """(Q,) queries over one sorted run -> (Q,) hit indices (or -1)."""
    q = queries.shape[0]
    assert q % Q_TILE == 0, f"pad queries to a multiple of {Q_TILE}"
    cap, f_n = keys.shape[0], fences.shape[0]
    # exact tiling at stride 1; a strided view (mu = base_mu * stride)
    # may leave one partial last page, but the fences must cover the run
    assert f_n * mu >= cap >= mu, "fences must cover the run"
    grid = (q // Q_TILE,)
    return pl.pallas_call(
        functools.partial(_fence_kernel, mu=mu),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_TILE,), lambda i: (i,)),
            pl.BlockSpec((f_n,), lambda i: (0,)),
            pl.BlockSpec((cap,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((Q_TILE,), lambda i: (i,)),
        interpret=interpret,
        name="slsm_fence_lookup",
    )(queries, fences, keys, count)
