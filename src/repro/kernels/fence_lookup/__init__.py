from repro.kernels.fence_lookup.ops import fence_lookup_op  # noqa: F401
from repro.kernels.fence_lookup.ref import fence_lookup_ref  # noqa: F401
