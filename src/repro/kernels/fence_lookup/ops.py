"""Public jit'd wrapper for the fence_lookup kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.params import KEY_EMPTY
from repro.kernels.fence_lookup.fence_lookup import (Q_TILE,
                                                     fence_lookup_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnums=4)
def fence_lookup_op(queries, fences, keys, count, mu: int):
    """Batched fence-pointer lookup. Returns hit indices, -1 for misses."""
    q = queries.shape[0]
    qp = ((q + Q_TILE - 1) // Q_TILE) * Q_TILE
    padded = jnp.full((qp,), KEY_EMPTY, jnp.int32).at[:q].set(
        queries.astype(jnp.int32))
    idx = fence_lookup_pallas(padded, fences.astype(jnp.int32),
                              keys.astype(jnp.int32),
                              jnp.asarray(count, jnp.int32).reshape(1),
                              mu, interpret=not _on_tpu())
    return idx[:q]
