"""Pure-jnp oracle: searchsorted over the full run (index semantics only
depend on the run being sorted — the fence decomposition must not change
the answer)."""
from __future__ import annotations

import jax.numpy as jnp


def fence_lookup_ref(queries, fences, keys, count, mu: int):
    del fences, mu  # the oracle ignores the index structure entirely
    i = jnp.searchsorted(keys, queries).astype(jnp.int32)
    ic = jnp.minimum(i, keys.shape[0] - 1)
    hit = (i < count) & (keys[ic] == queries)
    return jnp.where(hit, i, -1)
