from repro.serving.kv_cache import (lsm_from_dense, seal_hot_block,  # noqa: F401
                                    generate)
