"""sLSM-tiered KV cache management (the paper's write path, for tokens).

Lifecycle per layer:
  * decode appends K/V to the *hot window* (the memory buffer);
  * when the hot window fills, `seal_hot_block` merges its oldest `mu`
    tokens into an immutable cold block + summary vector (run seal +
    index build: the summary is the Bloom-filter/fence-pointer analogue);
  * attention reads hot + top-k summary-gated cold blocks only.

The host decides *when* to seal (every mu steps), mirroring the engine's
host-orchestrated merges; the seal itself is one jitted shift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm


def _seal_one(hot_k, hot_v, blk_k, blk_v, summ, hot_len, n_blocks, mu: int):
    """Seal the oldest mu hot tokens into cold block slot n_blocks.

    Shapes (single layer, single batch): hot (W, KV, hd);
    blk (NB, mu, KV, hd); summ (NB, KV, hd).
    """
    w = hot_k.shape[0]
    new_blk_k = hot_k[:mu]
    new_blk_v = hot_v[:mu]
    new_summ = new_blk_k.mean(axis=0)
    blk_k = jax.lax.dynamic_update_index_in_dim(
        blk_k, new_blk_k.astype(blk_k.dtype), n_blocks, 0)
    blk_v = jax.lax.dynamic_update_index_in_dim(
        blk_v, new_blk_v.astype(blk_v.dtype), n_blocks, 0)
    summ = jax.lax.dynamic_update_index_in_dim(
        summ, new_summ.astype(summ.dtype), n_blocks, 0)
    hot_k = jnp.concatenate([hot_k[mu:], jnp.zeros_like(hot_k[:mu])])
    hot_v = jnp.concatenate([hot_v[mu:], jnp.zeros_like(hot_v[:mu])])
    return hot_k, hot_v, blk_k, blk_v, summ, hot_len - mu, n_blocks + 1


def seal_hot_block(cfg, caches: dict) -> dict:
    """Seal across all layers/batches (stacked (L, B, ...) leaves;
    hot_len / n_blocks are (L, B))."""
    mu = cfg.lsm_block
    f = jax.vmap(jax.vmap(  # over L, then B
        lambda hk, hv, bk, bv, sm, hl, nb: _seal_one(hk, hv, bk, bv, sm,
                                                     hl, nb, mu)))
    hk, hv, bk, bv, sm, hl, nb = f(
        caches["hot_k"], caches["hot_v"], caches["blk_k"], caches["blk_v"],
        caches["summ"], caches["hot_len"], caches["n_blocks"])
    return dict(caches, hot_k=hk, hot_v=hv, blk_k=bk, blk_v=bv, summ=sm,
                hot_len=hl, n_blocks=nb)


seal_hot_block_jit = jax.jit(seal_hot_block, static_argnums=0)


def lsm_from_dense(cfg, dense_caches: dict, max_len: int) -> dict:
    """Convert prefill (dense) caches into the tiered layout: full mu-token
    prefixes become cold blocks; the remainder lands in the hot window."""
    mu, w = cfg.lsm_block, cfg.lsm_hot_window
    k, v = dense_caches["k"], dense_caches["v"]     # (L, B, S, KV, hd)
    l, b, s, kv, hd = k.shape
    n_cold = max(0, s - 1) // mu                    # keep >=1 token hot
    hot_start = n_cold * mu
    hot_used = s - hot_start
    assert hot_used <= w, (hot_used, w)

    out = lm.init_decode_caches(cfg, b, max_len, kind="lsm")
    nb_cap = out["blk_k"].shape[2]
    assert n_cold <= nb_cap, (n_cold, nb_cap)
    if n_cold:
        cold_k = k[:, :, :hot_start].reshape(l, b, n_cold, mu, kv, hd)
        cold_v = v[:, :, :hot_start].reshape(l, b, n_cold, mu, kv, hd)
        out["blk_k"] = out["blk_k"].at[:, :, :n_cold].set(
            cold_k.astype(out["blk_k"].dtype))
        out["blk_v"] = out["blk_v"].at[:, :, :n_cold].set(
            cold_v.astype(out["blk_v"].dtype))
        out["summ"] = out["summ"].at[:, :, :n_cold].set(
            cold_k.mean(axis=3).astype(out["summ"].dtype))
    out["hot_k"] = out["hot_k"].at[:, :, :hot_used].set(
        k[:, :, hot_start:s].astype(out["hot_k"].dtype))
    out["hot_v"] = out["hot_v"].at[:, :, :hot_used].set(
        v[:, :, hot_start:s].astype(out["hot_v"].dtype))
    out["hot_len"] = jnp.full((l, b), hot_used, jnp.int32)
    out["n_blocks"] = jnp.full((l, b), n_cold, jnp.int32)
    out["pos"] = dense_caches["pos"]
    return out


def generate(cfg, params, prompt_batch: dict, steps: int,
             kind: str = "dense", max_len: int | None = None):
    """Greedy generation driver (host loop; every step jitted)."""
    b, s = prompt_batch["tokens"].shape
    max_len = max_len or (s + steps + 8)
    logits, caches = jax.jit(lm.prefill_step, static_argnums=0)(
        cfg, params, prompt_batch)
    if kind == "lsm":
        caches = lsm_from_dense(cfg, caches, max_len)
    else:
        grown = lm.init_decode_caches(cfg, b, max_len, kind="dense")
        for kk in ("k", "v"):
            if kk in caches:
                grown[kk] = grown[kk].at[:, :, :s].set(
                    caches[kk].astype(grown[kk].dtype))
        for kk in ("enc_k", "enc_v", "ssm", "conv", "shared"):
            if kk in caches:
                grown[kk] = caches[kk]
        grown["pos"] = caches["pos"]
        caches = grown

    step_fn = jax.jit(lm.decode_step, static_argnums=(0, 4))
    out_tokens = [jnp.argmax(logits, -1)]
    for _ in range(steps - 1):
        tok = out_tokens[-1].astype(jnp.int32)
        logits, caches = step_fn(cfg, params, tok, caches, kind)
        out_tokens.append(jnp.argmax(logits, -1))
        if kind == "lsm":
            # host-orchestrated seal, like the engine's merges
            if int(caches["hot_len"].reshape(-1)[0]) >= cfg.lsm_hot_window:
                caches = seal_hot_block_jit(cfg, caches)
    return jnp.stack(out_tokens, axis=1), caches
