"""Paper-faithful workload + benchmark-trajectory subsystem.

The paper's core claim (Section 3) is that the sLSM's "breadth of tuning
parameters allows broad flexibility for excellent performance across a
wide variety of workloads". This package makes that claim measurable and
*comparable across PRs*:

  workloads.py — seeded key-distribution generators (uniform, sequential,
                 zipfian-skewed, delete-heavy, range-scan mixes) — the
                 paper's Section 3 workload families as one registry.
  scenarios.py — named benchmark scenarios + parameter-sweep drivers over
                 the paper's knobs (R, Rn, D, m, eps, tiering vs leveling,
                 jnp vs pallas backend, 1 vs S shards).
  runner.py    — executes one scenario end-to-end and emits a
                 schema-versioned ``BENCH_<name>.json`` (ops/sec, p50/p99
                 latency, merge counts, measured Bloom FP rate).
  schema.py    — the BENCH_*.json schema: version constant + pure-python
                 validator (no external deps).

Entry point: ``python -m benchmarks.run --scenario all --out .``
(see README.md "Benchmarks" and DESIGN.md §7 for how to read results).
"""
from repro.bench.schema import SCHEMA_VERSION, validate  # noqa: F401
from repro.bench.workloads import (WORKLOAD_FAMILIES, Workload,  # noqa: F401
                                   make_kv_workload, make_workload)

# scenarios/runner pull in the whole engine; loaded lazily so importing
# the generators (e.g. via the repro.data back-compat re-export) does not
# drag jax state in — and cannot recurse through repro.core's facade.
_LAZY = {
    "Scenario": "scenarios", "SCENARIOS": "scenarios",
    "bench_params": "scenarios", "scenarios_for": "scenarios",
    "run_scenario": "runner",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.bench.{_LAZY[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
