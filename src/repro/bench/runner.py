"""Scenario executor: one `Scenario` in, one ``BENCH_<name>.json`` out.

Phases run in workload order — insert (merges included), delete, batched
lookups, per-query lookups, per-scan ranges, batched ranges — each timed
with ``block_until_ready`` per dispatch so the latency percentiles are
honest device-complete times, not async-dispatch times. The `shifting`
workload runs a two-phase mixed-op path instead (`_run_shifting`):
write-heavy inserts with a read trickle, then — with no drain in
between — read-heavy lookups with a write trickle, so adaptive engines
meet the flip mid-flight (DESIGN.md §9). The batched vs
per-query pair is the headline comparison: the same query stream served
by one fused multi-key dispatch per batch (`lookup_many`) vs one
dispatch per key — the speedup the batched read path exists for; the
range vs range_batched pair (`range_device` vs `range_many`, DESIGN.md
§10) is its scan-side sibling.

The `serving` workload runs a third path (`_run_serving`): the
closed-loop offered-load sweep of the continuous-batching server
(repro.serve) plus its per-request dispatch baseline, emitted as the
schema's ``metrics.serving`` block with the standard phases null
(DESIGN.md §11).

The Bloom false-positive rate is *measured*, not assumed: every disk
run's filter is probed with the workload's guaranteed-absent key stream
(inserted keys are even, probes are odd) and the admit rate is averaged
over runs — the quantity the paper's Figure 5 speedup is made of.

Documents are validated against `repro.bench.schema` before writing;
an invalid document is a bug and raises instead of polluting the
trajectory.
"""
from __future__ import annotations

import datetime
import json
import platform
import re
import struct
import sys
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench import schema as SCHEMA
from repro.bench.scenarios import PROFILES, Scenario
from repro.bench.workloads import Workload, make_workload
from repro.core import bloom as BL
from repro.engine import SLSM, LevelingPolicy, ShardedSLSM, TieringPolicy
from repro.engine import wal as WAL


def _phase(ops: int, wall_s: float, dispatch_times: List[float]) -> Dict:
    ts = np.asarray(dispatch_times if dispatch_times else [wall_s])
    return {
        "ops": int(ops),
        "wall_s": float(wall_s),
        "ops_per_s": float(ops / wall_s) if wall_s > 0 else 0.0,
        "p50_us": float(np.percentile(ts, 50) * 1e6),
        "p99_us": float(np.percentile(ts, 99) * 1e6),
        # stall telemetry (DESIGN.md §8): the tail the merge scheduler
        # flattens — p999 needs >=1000 dispatches to separate from max
        "p999_us": float(np.percentile(ts, 99.9) * 1e6),
        "max_stall_us": float(ts.max() * 1e6),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def build_engine(sc: Scenario, wal_dir: Optional[str] = None):
    """Instantiate the scenario's engine: single tree (with its compaction
    policy) or the vmapped sharded engine (tiering only, see sharded.py).
    `wal_dir` (durability scenarios) attaches a fsyncing WAL — every
    timed driver call then pays the real group-commit barrier."""
    p = sc.engine_params()
    dur = WAL.Durability(wal_dir) if wal_dir is not None else None
    if sc.n_shards > 1:
        if sc.policy != "tiering":
            raise ValueError(
                f"scenario {sc.name!r}: ShardedSLSM supports tiering only")
        return ShardedSLSM(p, n_shards=sc.n_shards, durability=dur)
    policy = {"tiering": TieringPolicy, "leveling": LevelingPolicy}[sc.policy]()
    return SLSM(p, policy=policy, durability=dur)


def _run_inserts(tree, w: Workload, chunk: int) -> Dict:
    """Chunked insert stream (merges included). `tree.warm()` has already
    precompiled the full maintenance program set (run_scenario calls it
    untimed — since the scheduler PR no merge program compiles inside the
    timed region; the old caveat about deep-level spill compiles landing
    mid-phase is gone). A prefix covering the first TWO buffer flushes
    (2*R*Rn elements) is additionally inserted untimed so the timed
    region starts with a populated tree — steady-state and comparable
    across scenarios regardless of execution order within one process.

    Returns (phase, steady_state): steady_state is False when the
    workload is too small to warm past both flushes for this geometry
    (the document is stamped so the trajectory can exclude such points).
    """
    p = tree.p
    warm_target = 2 * p.R * p.Rn + chunk
    warm = min(warm_target, 3 * len(w.keys) // 4)
    steady = warm >= warm_target
    if not steady:
        print(f"# warning: insert warmup capped at {warm} < {warm_target} "
              f"ops (R*Rn too large for n={len(w.keys)}); jit compiles "
              "land inside the timed insert phase "
              "(insert_steady_state=false)", file=sys.stderr)
    tree.insert(w.keys[:warm], w.vals[:warm])
    jax.block_until_ready(tree.state)
    times = []
    t0 = time.perf_counter()
    for off in range(warm, len(w.keys), chunk):
        times.append(_timed(lambda off=off: (
            tree.insert(w.keys[off:off + chunk], w.vals[off:off + chunk]),
            tree.state)[1]))
    return _phase(len(w.keys) - warm, time.perf_counter() - t0, times), steady


def _run_deletes(tree, w: Workload, chunk: int) -> Optional[Dict]:
    if len(w.deletes) == 0:
        return None
    times = []
    t0 = time.perf_counter()
    for off in range(0, len(w.deletes), chunk):
        times.append(_timed(lambda off=off: (
            tree.delete(w.deletes[off:off + chunk]), tree.state)[1]))
    return _phase(len(w.deletes), time.perf_counter() - t0, times)


def _run_lookups_batched(tree, lookups: np.ndarray, batch: int) -> Dict:
    # warm every padded shape the loop will hit (full batch + remainder)
    tree.lookup_many(lookups[:batch])
    tail = len(lookups) % batch
    if tail:
        tree.lookup_many(lookups[:tail])
    times = []
    t0 = time.perf_counter()
    for off in range(0, len(lookups), batch):
        times.append(_timed(
            lambda off=off: tree.lookup_many(lookups[off:off + batch])))
    return _phase(len(lookups), time.perf_counter() - t0, times)


def _run_lookups_per_query(tree, lookups: np.ndarray, sample: int) -> Dict:
    qs = lookups[:sample]
    tree.lookup(qs[:1])                        # warm the compile cache
    times = []
    t0 = time.perf_counter()
    for k in qs:
        times.append(_timed(lambda k=k: tree.lookup(np.asarray([k]))))
    return _phase(len(qs), time.perf_counter() - t0, times)


def _run_shifting(tree, w: Workload, prof: Dict) -> Tuple[Dict, Dict, bool]:
    """The two-phase shifting workload (DESIGN.md §9), no drain between.

    Phase 1 (write-heavy): the bulk insert stream in 4*Rn chunks with a
    lookup batch interleaved every few chunks — timed as the `insert`
    phase (dispatch times are the insert chunks; the read trickle rides
    inside the same wall clock, as it would in production). Phase 2
    (read-heavy): the zipf-hot lookup stream in `batch`-wide fused
    dispatches with a small insert chunk interleaved every few batches —
    timed as the `lookup_batched` phase. The engine is never drained
    between phases: an adaptive engine must detect the flip and retune
    mid-flight; a static one meets it with whatever structure it has.

    Returns (insert_phase, lookup_phase, steady) — per-query metrics are
    measured afterwards by the caller, like every other scenario.
    """
    p = tree.p
    n1 = int(w.meta["n_phase1"])
    nl1 = int(w.meta["n_lookups_phase1"])
    chunk = 4 * p.Rn
    # untimed warm prefix, as in _run_inserts (two flushes covered)
    warm_target = 2 * p.R * p.Rn + chunk
    warm = min(warm_target, 3 * n1 // 4)
    steady = warm >= warm_target
    tree.insert(w.keys[:warm], w.vals[:warm])
    jax.block_until_ready(tree.state)

    # phase 1: bulk inserts + a read trickle (every 4th chunk, one
    # `batch`-wide lookup — the same fused width phase 2 uses, so both
    # phases exercise only shapes tree.warm() precompiled)
    batch = prof["batch"]
    l1 = w.lookups[:nl1]
    li, times = 0, []
    t0 = time.perf_counter()
    for i, off in enumerate(range(warm, n1, chunk)):
        times.append(_timed(lambda off=off: (
            tree.insert(w.keys[off:off + chunk], w.vals[off:off + chunk]),
            tree.state)[1]))
        if i % 4 == 3 and li + batch <= nl1:
            tree.lookup_many(l1[li:li + batch])
            li += batch
    insert = _phase(n1 - warm, time.perf_counter() - t0, times)

    # phase 2: zipf-hot lookups + write trickle (every 8th batch, Rn keys)
    l2 = w.lookups[nl1:]
    ki, times = n1, []
    tree.lookup_many(l2[:batch])                 # warm the padded shapes
    tail = len(l2) % batch
    if tail:
        tree.lookup_many(l2[:tail])
    t0 = time.perf_counter()
    for i, off in enumerate(range(0, len(l2), batch)):
        times.append(_timed(
            lambda off=off: tree.lookup_many(l2[off:off + batch])))
        if i % 8 == 7 and ki < len(w.keys):
            tree.insert(w.keys[ki:ki + p.Rn], w.vals[ki:ki + p.Rn])
            ki += p.Rn
    lookup = _phase(len(l2), time.perf_counter() - t0, times)
    return insert, lookup, steady


# batched range scans dispatch in this many windows per fused call (the
# RANGE_BUCKETS grid covers it, so the shape is always warm)
RANGE_BATCH = 32

# the serving scenario's p99 SLO (enqueue->reply): sustained throughput
# is the best swept offered load whose p99 stays under this
SERVING_SLO_P99_US = 50_000.0


def _run_serving(sc: Scenario, w, prof: Dict) -> Tuple[Dict, Any]:
    """The closed-loop serving scenario (repro.serve, DESIGN.md §11).

    Offered-load sweep: one fresh engine + batching server per client
    count (`profile.serving_clients`), the SAME deterministic request
    stream re-partitioned across the clients, coalesced mixed-op-tape
    dispatch. Then the per-request baseline: the same stream at the top
    offered load, every request its own classic driver call. Returns
    ``(metrics.serving block, the last coalesced engine)`` — the engine
    feeds the document's maintenance/bloom sections.
    """
    from repro.serve import Server, closed_loop, sustained_at_slo

    sweep, tree, srv = [], None, None
    for c in prof["serving_clients"]:
        tree = build_engine(sc)
        srv = Server(tree)
        srv.warm()          # maintenance + read grid + tape interpreters
        sweep.append(closed_loop(srv, w.requests, c))
        srv.drain()
    coalesced = sweep[-1]
    top = prof["serving_clients"][-1]
    baseline_tree = build_engine(sc)
    baseline = Server(baseline_tree, mode="per_request")
    baseline.warm()
    per_request = closed_loop(baseline, w.requests, top)
    baseline.drain()
    gov = srv.stats()["governor"]
    block = {
        "sweep": sweep,
        "coalesced": coalesced,
        "per_request": per_request,
        "coalesced_speedup": (coalesced["ops_per_s"]
                              / max(per_request["ops_per_s"], 1e-12)),
        "slo_p99_us": SERVING_SLO_P99_US,
        "sustained_ops_at_slo": sustained_at_slo(sweep,
                                                 SERVING_SLO_P99_US),
        "governor": {"steps": int(gov["steps"]),
                     "idle_steps": int(gov["idle_steps"])},
    }
    return block, tree


def _run_ranges(tree, ranges: np.ndarray) -> Optional[Dict]:
    """Per-scan range phase: one device dispatch per window through the
    device-resident `range_device` — the timed cost is the scan engine
    itself, not a per-scan host `int(count)` round-trip (the sync the
    pre-engine driver paid on every scan)."""
    if len(ranges) == 0:
        return None
    tree.range_device(int(ranges[0, 0]), int(ranges[0, 1]))   # warm
    times = []
    t0 = time.perf_counter()
    for lo, hi in ranges:
        times.append(_timed(
            lambda lo=lo, hi=hi: tree.range_device(int(lo), int(hi))))
    return _phase(len(ranges), time.perf_counter() - t0, times)


def _run_ranges_batched(tree, ranges: np.ndarray
                        ) -> Tuple[Optional[Dict], Optional[Dict]]:
    """Batched range phase: the same windows served by fused
    `range_many` dispatches, RANGE_BATCH windows per call — the scan
    analogue of the batched-vs-per-query lookup comparison. Returns
    (phase, scan_stats) where scan_stats aggregates per-scan
    `keys_returned` and the truncated-scan count (the exactness
    telemetry of the candidate budget, DESIGN.md §10)."""
    if len(ranges) == 0:
        return None, None
    tree.range_many(ranges[:RANGE_BATCH])                     # warm
    tail = len(ranges) % RANGE_BATCH
    if tail:
        tree.range_many(ranges[:tail])
    # small profiles fit the whole window list in one fused call; repeat
    # the sweep so the phase always has a few timed dispatches (a single
    # sample would put any one-off hiccup straight into every percentile)
    n_batches = (len(ranges) + RANGE_BATCH - 1) // RANGE_BATCH
    reps = max(1, 4 // n_batches)
    times, counts, truncs = [], [], []
    t0 = time.perf_counter()
    for rep in range(reps):
        for off in range(0, len(ranges), RANGE_BATCH):
            def one(off=off, rep=rep):
                out = tree.range_many(ranges[off:off + RANGE_BATCH])
                if rep == 0:
                    counts.append(out[2])
                    truncs.append(out[3])
                return out
            times.append(_timed(one))
    phase = _phase(reps * len(ranges), time.perf_counter() - t0, times)
    counts = np.concatenate(counts)
    stats = {"keys_returned_mean": float(counts.mean()),
             "keys_returned_max": int(counts.max()),
             "scans_truncated": int(np.concatenate(truncs).sum())}
    return phase, stats


def _fresh_engine(tree, dur):
    """A fresh durable engine of the measured engine's own kind (the
    self-healing act builds its own small cluster)."""
    if isinstance(tree, ShardedSLSM):
        return ShardedSLSM(tree.p, n_shards=tree.S, durability=dur)
    return SLSM(tree.p, policy=tree.policy, durability=dur)


def _run_selfheal(tree, w: Workload) -> Dict[str, Any]:
    """The v9 self-healing keys of metrics.replication (DESIGN.md §15).

    A fresh quorum-ack cluster on the *real* clock: a segmented-WAL
    leader (`ack_mode="quorum", quorum=2`) with a short lease streams a
    write stream to two auto-promote followers, snapshots and prunes
    (``wal_pruned_bytes``), then is partitioned — not killed, its ends
    simply stop being pumped — and the measurement is the wall time
    until a follower's lease expires, the deterministic successor rule
    fires, and the automatically promoted engine answers its first read
    (``failover_auto_ms``). ``rpo_records`` counts quorum-acked writes
    the successor is missing — 0 by construction: an ack is only
    released once k followers hold the bytes."""
    from repro.engine import replication as R

    lease_s = 0.2
    with tempfile.TemporaryDirectory(prefix="bench_heal_") as td:
        d = Path(td)
        dur = WAL.Durability(d / "leader", fsync=False,
                             snapshot_every_bytes=1 << 30,
                             segment_bytes=2048)
        drv = _fresh_engine(tree, dur)
        leader = R.Leader(drv, ack_mode="quorum", quorum=2,
                          lease_s=lease_s)
        fols = [leader.add_follower(d / f"f{i}", auto_promote=True)
                for i in range(2)]
        keys = np.unique(w.keys[:1024].astype(np.int32))
        probe = keys[:256]
        for i in range(0, len(keys), 64):
            chunk = keys[i:i + 64]
            drv.insert(chunk, (chunk % 65536) * 3 + 1)
            leader.pump()
            for f in fols:
                f.pump()
        # the pruning leg: snapshot -> ack round-trip -> prune drops
        # every sealed segment below min(snapshot, follower acks)
        drv.snapshot()
        leader.pump()
        for f in fols:
            f.pump()
        leader.pump()               # drain the final acks + heartbeat
        leader.prune()
        pruned_bytes = int(dur.stats()["wal_pruned_bytes"])
        acked = int(leader.quorum_seqno())

        # partition (not kill): the leader's pump simply stops, so no
        # heartbeat renews the followers' leases — the real clock runs
        t_part = time.perf_counter()
        new_lead = None
        deadline = t_part + 60.0
        while new_lead is None and time.perf_counter() < deadline:
            for f in fols:
                f.pump()
                if f.new_leader is not None:
                    new_lead = f.new_leader
                    break
            time.sleep(lease_s / 40)
        if new_lead is None:
            raise RuntimeError("self-healing act: no automatic promotion "
                               f"within {deadline - t_part:.0f}s "
                               f"(lease_s={lease_s})")
        pv, pf = new_lead.drv.lookup_many(probe)
        jax.block_until_ready((pv, pf))
        failover_auto_ms = (time.perf_counter() - t_part) * 1e3
        rpo = max(0, acked - int(
            new_lead.drv.durability.writer.last_seqno))
        expiries = sum(f.counters["lease_expiries"] for f in fols)
        lv, lf = drv.lookup_many(probe)
        if not (np.array_equal(np.asarray(lf), np.asarray(pf))
                and np.array_equal(np.asarray(lv)[np.asarray(lf)],
                                   np.asarray(pv)[np.asarray(pf)])):
            raise RuntimeError("self-healing act: promoted successor "
                               "answers differ from the old leader's")
        for ld in (leader, new_lead):
            for h in list(ld.handles):
                ld.detach(h)
        drv.replication = None
        dur.close()
        for f in fols:
            f.drv.durability.close()
    return {"failover_auto_ms": float(failover_auto_ms),
            "rpo_records": int(rpo),
            "wal_pruned_bytes": pruned_bytes,
            "lease_expiries": int(expiries)}


def _run_replication(tree, n_followers: int, w: Workload
                     ) -> Dict[str, Any]:
    """The metrics.replication block (DESIGN.md §14).

    Attaches `n_followers` fresh in-process followers at the genesis
    cursor of the run's now-complete WAL — so the timed convergence
    loop streams the *entire* durable log through ship -> validate ->
    append-verbatim -> group-commit -> chunk-apply on every follower —
    then promotes one follower and times the failover: `promote()`
    (epoch bump, transport teardown) through its first answered read.
    Answer-exactness is checked against the leader on the workload's
    own key stream (found lanes bitwise + one range window). The v9
    self-healing keys (automatic lease failover, quorum-ack RPO, WAL
    pruning — DESIGN.md §15) come from `_run_selfheal`'s own small
    real-clock cluster and ride the same block."""
    from repro.engine import replication as R

    leader = R.Leader(tree)
    tree.durability.sync()
    # seed each follower with ONLY the leader's META header, so the
    # timed loop streams every post-genesis record over the wire (a
    # full `bootstrap` would copy the log and leave nothing to ship)
    meta_rec, _start, meta_end = WAL.record_offsets(
        tree.durability.wal_path)[0]
    header = tree.durability.wal_path.read_bytes()[:meta_end]
    ship_total = len(tree.durability.read_records()) - 1
    probe = np.unique(w.keys[:2048].astype(np.int32))
    with tempfile.TemporaryDirectory(prefix="bench_repl_") as d:
        fols = []
        for i in range(n_followers):
            fdir = Path(d) / f"f{i}"
            fdir.mkdir(parents=True)
            (fdir / "wal.log").write_bytes(header)
            link = R.QueueLink()
            fol = R.Follower(fdir, link.follower)
            fol.link = link
            leader.attach(link.leader,
                          R.Cursor(meta_end, meta_rec.seqno + 1,
                                   meta_rec.epoch))
            fols.append(fol)
        lag_peak = leader.stats()["follower_lag_records"]
        t0 = time.perf_counter()
        R.converge(leader, *fols)
        apply_wall = time.perf_counter() - t0
        st = leader.stats()
        applied = sum(f.counters["applied_records"] for f in fols)

        # failover: sever one follower's transport, promote, first read
        t0 = time.perf_counter()
        prom = fols[0].promote()
        pv, pf = prom.lookup_many(probe)
        jax.block_until_ready((pv, pf))
        failover_ms = (time.perf_counter() - t0) * 1e3
        lv, lf = tree.lookup_many(probe)
        lk, lvv = tree.range(int(probe[0]), int(probe[-1]) + 1)
        pk, pvv = prom.range(int(probe[0]), int(probe[-1]) + 1)
        f_np, pf_np = np.asarray(lf), np.asarray(pf)
        exact = bool(
            np.array_equal(f_np, pf_np)
            and np.array_equal(np.asarray(lv)[f_np], np.asarray(pv)[pf_np])
            and np.array_equal(np.asarray(lk), np.asarray(pk))
            and np.array_equal(np.asarray(lvv), np.asarray(pvv)))
        block = {
            "followers": int(n_followers),
            "shipped_records": int(st["shipped_records"]),
            "shipped_bytes": int(st["shipped_bytes"]),
            "lag_records_peak": int(lag_peak),
            "lag_records_final": int(st["follower_lag_records"]),
            "lag_bytes_final": int(st["follower_lag_bytes"]),
            "apply_ops_per_s": float(applied / max(apply_wall, 1e-12)),
            "failover_ms": float(failover_ms),
            "promoted_exact": exact,
            **_run_selfheal(tree, w),
        }
        for h in list(leader.handles):
            leader.detach(h)
        tree.replication = None
        for f in fols:
            f.drv.durability.close()
    if block["lag_records_final"] != 0 or applied < n_followers * ship_total:
        raise RuntimeError(
            f"replication did not drain: {block} (applied {applied} of "
            f"{n_followers}x{ship_total})")
    return block


def _measure_durability(tree) -> Dict[str, Any]:
    """The metrics.durability block of a WAL-on run (DESIGN.md §12).

    `restore()` is timed FIRST — before any snapshot exists — so
    restore_ms prices the worst case: a full replay-from-genesis of
    everything the run logged. Then one device-pytree snapshot is timed
    (the cost the serving governor hides in idle gaps). wal_bytes_per_op
    is log bytes per logged *element* (key+value), the durability tax
    per user write."""
    dur = tree.durability
    dur.sync()
    records = dur.read_records()
    n_elems = sum(struct.unpack_from("<I", r.payload, 0)[0]
                  for r in records if r.kind in WAL.WRITE_KINDS)
    t0 = time.perf_counter()
    restored = type(tree).restore(str(dur.dir))
    jax.block_until_ready(restored.state)
    restore_ms = (time.perf_counter() - t0) * 1e3
    replayed = int(restored.stats["replayed_records"])
    restored.durability.close()
    tree.snapshot()
    st = dur.stats()
    return {
        "wal_bytes": int(st["wal_bytes"]),
        "wal_records": int(st["wal_records"]),
        "wal_bytes_per_op": float(st["wal_bytes"] / max(1, n_elems)),
        "snapshot_ms": float(dur.last_snapshot_ms),
        "restore_ms": float(restore_ms),
        "replayed_chunks": replayed,
        "fsync": bool(dur.fsync),
    }


def measured_fp_rate(tree, absent: np.ndarray,
                     max_runs: int = 64) -> Tuple[float, int, int]:
    """Mean Bloom admit rate of the disk runs' filters on guaranteed-absent
    keys (the paper's eps, measured). Returns (rate, n_runs_probed,
    n_keys_probed); (0.0, 0, 0) when no disk runs exist yet."""
    p = getattr(tree, "p_active", tree.p)   # the live tuner allocation
    qs = jnp.asarray(absent[:2048].astype(np.int32))
    admit, runs = 0.0, 0
    for lvl, lv in enumerate(tree.state.levels):
        bits, _, kk = p.bloom_geometry(p.level_cap(lvl), p.level_eps(lvl))
        blooms, n_runs = np.asarray(lv.blooms), np.asarray(lv.n_runs)
        if blooms.ndim == 2:          # single tree: (D, words)
            blooms, n_runs = blooms[None], n_runs[None]
        for s in range(blooms.shape[0]):
            for d in range(int(n_runs[s])):
                if runs >= max_runs:
                    break
                pos = BL.bloom_probe(jnp.asarray(blooms[s, d]), qs, kk, bits)
                admit += float(np.asarray(pos).mean())
                runs += 1
    if runs == 0:
        return 0.0, 0, 0
    return admit / runs, runs, int(qs.shape[0])


def _env() -> Dict[str, str]:
    return {
        "jax": jax.__version__,
        "numpy": np.__version__,
        "python": platform.python_version(),
        "platform": jax.default_backend(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
    }


def bench_filename(name: str) -> str:
    """``BENCH_<name>.json`` with the scenario name sanitized to a safe
    filename (the stable identity the trajectory is keyed on)."""
    return f"BENCH_{re.sub(r'[^A-Za-z0-9_.-]', '_', name)}.json"


def run_scenario(sc: Scenario, out_dir: str | Path,
                 profile: str = "default") -> Tuple[Path, Dict[str, Any]]:
    """Execute one scenario end-to-end and write its BENCH document.

    Returns (path, document). Raises RuntimeError if the produced
    document does not validate against the schema.
    """
    prof = PROFILES[profile]
    wargs = dict(sc.wargs)
    if sc.workload in ("range-scan", "delete-heavy", "shifting"):
        wargs.setdefault("n_ranges", prof["n_ranges"])
    n_ops = prof["serving_ops"] if sc.workload == "serving" else prof["n"]
    w = make_workload(sc.workload, n_ops, seed=sc.seed, **wargs)
    p = sc.engine_params()
    serving = None
    if sc.durability and w.kind == "serving":
        raise ValueError(f"scenario {sc.name!r}: the serving sweep builds "
                         "one engine per point; use a phase workload for "
                         "the durability axis")
    wal_ctx = (tempfile.TemporaryDirectory(prefix="bench_wal_")
               if sc.durability else None)
    wal_dir = wal_ctx.name if wal_ctx is not None else None

    if w.kind == "serving":
        # closed-loop serving: no standard phases (the schema's nullable
        # block); engines are built per sweep point inside _run_serving
        serving, tree = _run_serving(sc, w, prof)
        insert = batched = per_query = delete = None
        ranges = ranges_batched = range_stats = None
        insert_steady = True
        n_batched_lookups = prof["n_lookups"]
    elif w.kind == "shifting":
        tree = build_engine(sc, wal_dir)
        tree.warm()   # precompile all maintenance programs (untimed)
        # phased mixed-op stream, never drained mid-run: the adaptive
        # tuner must catch the write->read flip in flight (DESIGN.md §9)
        insert, batched, insert_steady = _run_shifting(tree, w, prof)
        nl1 = int(w.meta["n_lookups_phase1"])
        per_query = _run_lookups_per_query(
            tree, w.lookups[nl1:], prof["n_per_query"])
        delete = None
        ranges = _run_ranges(tree, w.ranges)
        ranges_batched, range_stats = _run_ranges_batched(tree, w.ranges)
        n_batched_lookups = len(w.lookups) - nl1
    else:
        tree = build_engine(sc, wal_dir)
        tree.warm()   # precompile all maintenance programs (untimed)
        insert, insert_steady = _run_inserts(tree, w, chunk=4 * p.Rn)
        delete = _run_deletes(tree, w, chunk=4 * p.Rn)
        if p.merge_budget > 0:
            # merge barrier (untimed): retire the deferred maintenance
            # backlog so the read phases run against a fully-merged tree,
            # comparable with synchronous-mode documents (reads are exact
            # either way — this only removes run-count variance from the
            # lookup timings)
            tree.drain()
            jax.block_until_ready(tree.state)
        lookups = w.lookups[:prof["n_lookups"]]
        batched = _run_lookups_batched(tree, lookups, prof["batch"])
        per_query = _run_lookups_per_query(tree, lookups,
                                           prof["n_per_query"])
        ranges = _run_ranges(tree, w.ranges)
        ranges_batched, range_stats = _run_ranges_batched(tree, w.ranges)
        n_batched_lookups = len(lookups)
    fp_rate, _, n_probed = measured_fp_rate(tree, w.absent)
    if sc.replication > 0 and not sc.durability:
        raise ValueError(f"scenario {sc.name!r}: replication requires a "
                         "durable leader (set durability=True)")
    # replication streams the finished log BEFORE _measure_durability
    # snapshots it (the followers must replay from genesis, not sync
    # from a snapshot)
    replication = (_run_replication(tree, sc.replication, w)
                   if sc.replication > 0 else None)
    durability = _measure_durability(tree) if sc.durability else None
    if wal_ctx is not None:
        tree.durability.close()
        wal_ctx.cleanup()

    doc: Dict[str, Any] = {
        "schema_version": SCHEMA.SCHEMA_VERSION,
        "name": sc.name,
        "workload": {"kind": w.kind, "n": w.n, "seed": sc.seed,
                     "args": {**wargs, **{k: v for k, v in w.meta.items()
                                          if isinstance(v, (int, float, str))}}},
        "engine": {"R": p.R, "Rn": p.Rn, "eps": p.eps, "D": p.D, "m": p.m,
                   "mu": p.mu, "max_levels": p.max_levels,
                   "max_range": p.max_range, "cand_factor": p.cand_factor,
                   "range_cand": 0 if p.range_cand is None else p.range_cand,
                   "backend": p.backend, "policy": sc.policy,
                   "n_shards": sc.n_shards, "merge_budget": p.merge_budget,
                   "tuning_mode": p.tuning.mode},
        "profile": {"name": profile, "batch": prof["batch"],
                    "n_lookups": n_batched_lookups,
                    "n_per_query": prof["n_per_query"],
                    "insert_steady_state": insert_steady},
        "metrics": {
            "insert": insert,
            "lookup_batched": batched,
            "lookup_per_query": per_query,
            "delete": delete,
            "range": ranges,
            "range_batched": ranges_batched,
            "range_stats": range_stats,
            "serving": serving,
            "batched_speedup": (None if batched is None else
                                batched["ops_per_s"]
                                / max(per_query["ops_per_s"], 1e-12)),
            "zset": {k: int(tree.stats[k]) for k in
                     ("rows_merged_in", "rows_merged_out",
                      "rows_annihilated", "ghost_payload_bytes_skipped")},
            "maintenance": {k: int(tree.stats[k]) for k in
                            ("seals", "flushes", "spills", "compactions",
                             "backlog_peak", "retunes")},
            "tuner": ({"active": tree.tuner.active,
                       "read_frac": float(tree.tuner.read_frac),
                       "budget_bytes": int(tree.tuner.budget_bytes),
                       "level_fp_observed": [
                           float(x) for x in tree.tuner.level_fp_observed]}
                      if tree.tuner.enabled else None),
            "bloom": {"eps_configured": p.eps,
                      "fp_rate_measured": fp_rate,
                      "n_probed": n_probed},
            "durability": durability,
            "replication": replication,
        },
        "env": _env(),
    }
    errs = SCHEMA.validate(doc)
    if errs:
        raise RuntimeError(
            f"scenario {sc.name!r} produced an invalid BENCH document:\n  "
            + "\n  ".join(errs))
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / bench_filename(sc.name)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return path, doc
