"""The ``BENCH_<name>.json`` schema: one machine-readable perf point.

Every scenario run emits one document; the set of documents across PRs
is the repo's performance *trajectory* — comparable because the schema
is versioned and each document pins the workload (family + seed + size)
and the full engine configuration that produced it.

Pure-python structural validator (no jsonschema dependency): `validate`
returns a list of human-readable problems (empty == valid). The runner
validates before writing; CI re-validates the emitted files
(``python -m benchmarks.run --check --out DIR``).

Document shape (SCHEMA_VERSION 7):

  schema_version  int     in COMPAT_VERSIONS (v5/v6 documents predate
                          the durability / zset blocks and stay valid
                          as committed)
  name            str     scenario name (file is BENCH_<sanitized name>.json)
  workload        {kind, n, seed, args{...}}
  engine          {R, Rn, eps, D, m, mu, max_levels, max_range,
                   cand_factor, range_cand, backend, policy, n_shards,
                   merge_budget, tuning_mode}
                   range_cand = the scan engine's per-scan candidate
                   budget (0 = unbounded, DESIGN.md §10)
  profile         {name, batch, n_lookups, n_per_query,
                   insert_steady_state}  sizing profile that produced the
                   numbers — p50/p99 and batched_speedup shift with
                   dispatch width, so documents are only comparable at
                   the same profile; insert_steady_state=false marks a
                   point whose insert warmup could not cover the first
                   two buffer flushes (jit compiles inside the timing)
  metrics
    insert            phase    chunked insert stream (includes merges)
    lookup_batched    phase    one fused multi-key dispatch per batch
    lookup_per_query  phase    one dispatch per key (the baseline the
                               batched path is measured against)
                               (insert/lookup_batched/lookup_per_query/
                               batched_speedup are null — and only
                               null — on serving documents, whose
                               stream has no standard phases)
    delete            phase|None   tombstone stream (delete-heavy only)
    range             phase|None   [lo,hi) scans, one device dispatch per
                               window (workloads with scan windows)
    range_batched     phase|None   the same windows in fused range_many
                               dispatches (the batched scan fast path,
                               DESIGN.md §10)
    range_stats       {keys_returned_mean, keys_returned_max,
                      scans_truncated}|None   per-scan result-size and
                      truncation telemetry of the batched range phase
                      (scans_truncated > 0 means some window overflowed
                      max_range or the range_cand budget)
    batched_speedup   float    lookup_batched.ops_per_s / lookup_per_query.ops_per_s
    zset              {rows_merged_in, rows_merged_out, rows_annihilated,
                      ghost_payload_bytes_skipped}   (v7+, required key)
                      weighted-merge telemetry (DESIGN.md §13): rows
                      entering vs. surviving every merge of the run —
                      the gap is dedup + annihilation, payload bytes the
                      Ghost gather never touched
    maintenance       {seals, flushes, spills, compactions, backlog_peak,
                      retunes}
                      merge counts + the deepest pending-merge-step
                      backlog ever observed at a chunk boundary (the
                      scheduler's pacing telemetry, DESIGN.md §8) + the
                      number of tuner allocation switches applied (§9)
    tuner             {active, read_frac, budget_bytes,
                      level_fp_observed}|None   final tuner state (None
                      unless the engine ran tuning_mode "adaptive"): the
                      allocation the run ended on, the EWMA read
                      fraction, the byte budget it managed, and the
                      sampled per-level observed-FP fractions
    serving           {sweep, coalesced, per_request, coalesced_speedup,
                      slo_p99_us, sustained_ops_at_slo, governor}|None
                      the continuous-batching serving scenario's block
                      (null on every other scenario): ``sweep`` is the
                      closed-loop offered-load sweep (one serving-point
                      per client count), ``coalesced`` its top-load
                      point, ``per_request`` the same stream at the same
                      offered load dispatched one classic driver call
                      per request, ``coalesced_speedup`` their ops/s
                      ratio (the dispatch-coalescing win the mixed-op
                      tape exists for, DESIGN.md §11),
                      ``sustained_ops_at_slo`` the best swept ops/s
                      whose p99 enqueue->reply latency meets
                      ``slo_p99_us``, and ``governor`` the maintenance
                      steps spent at window boundaries / idle gaps
    bloom             {eps_configured, fp_rate_measured, n_probed}
    durability        {wal_bytes, wal_records, wal_bytes_per_op,
                      snapshot_ms, restore_ms, replayed_chunks,
                      fsync}|None   (v6+, required key) the durability
                      tax and recovery cost of a WAL-on run (DESIGN.md
                      §12): total log size and record count, log bytes
                      per logged element, one timed device-pytree
                      snapshot, one timed `restore()` of the full run's
                      WAL (measured BEFORE the snapshot exists, so it
                      prices the worst-case replay-from-genesis), the
                      WRITE chunks that replay processed, and whether
                      the log fsynced at each group commit. null on
                      WAL-off runs.
    replication       {followers, shipped_records, shipped_bytes,
                      lag_records_peak, lag_records_final,
                      lag_bytes_final, apply_ops_per_s, failover_ms,
                      promoted_exact, failover_auto_ms, rpo_records,
                      wal_pruned_bytes, lease_expiries}|None   (v8+,
                      required key; the last four v9+) the
                      single-leader replication block (DESIGN.md
                      §14-§15), emitted by the `replication` scenario:
                      follower count, frames shipped over the
                      in-process wire, the worst follower lag at attach
                      (peak) and after convergence (final — 0 on a
                      healthy run), the follower-side replay throughput
                      in WAL records/s, the wall time from `promote()`
                      to the promoted engine's first answered read, and
                      whether the promoted follower's answers matched
                      the leader's bitwise on the found lanes. The v9
                      self-healing keys: ``failover_auto_ms`` the wall
                      time from leader partition to the successor's
                      lease-expiry *automatic* promotion answering its
                      first read, ``rpo_records`` the client-acked
                      writes lost by that failover (0 by construction
                      in quorum ack mode), ``wal_pruned_bytes`` the
                      sealed log bytes watermark-bounded pruning
                      reclaimed during the run, and ``lease_expiries``
                      the follower-observed lease expiries. null on
                      every other scenario.
  env               {jax, numpy, python, platform, timestamp}

  serving-point := {clients int    offered load (closed-loop clients)
                    ops, requests  int   stream size served
                    wall_s, ops_per_s, requests_per_s   float
                    p50_us, p99_us, p999_us, max_stall_us
                                   float  enqueue->reply request latency
                    windows, dispatches   int  coalescing windows served
                                   / device dispatch count}

  phase := {ops          int   ops executed
            wall_s       float total wall-clock seconds
            ops_per_s    float
            p50_us       float per-dispatch latency percentiles —
            p99_us       float   batched phases amortize many ops/dispatch
            p999_us      float 99.9th percentile (the stall tail the
                               merge scheduler exists to flatten)
            max_stall_us float slowest single dispatch — for insert, the
                               worst write stall of the whole phase}

SCHEMA_VERSION history:
  1 — PR 2 seed: phases carried p50/p99 only; no merge_budget,
      backlog_peak, p999_us, or max_stall_us.
  2 — merge-scheduler PR: stall telemetry (insert p999/max_stall,
      maintenance backlog) + engine.merge_budget became part of the
      trajectory's engine fingerprint.
  3 — adaptive-tuner PR: engine.tuning_mode and maintenance.retunes
      joined the fingerprint; optional metrics.tuner block records the
      final allocation of adaptive runs (DESIGN.md §9).
  4 — range-engine PR: engine.range_cand joined the fingerprint; the
      metrics gained the range_batched phase and the range_stats
      telemetry block; delete_heavy and shifting scenarios now carry
      range phases (DESIGN.md §10).
  5 — serving PR: optional metrics.serving block (the closed-loop
      offered-load sweep + coalesced-vs-per-request comparison of the
      continuous-batching layer, DESIGN.md §11); the standard phases
      (insert, lookup_batched, lookup_per_query, batched_speedup)
      became nullable on — and only on — serving documents.
  6 — durability PR: nullable-but-required metrics.durability block
      (WAL size/overhead, snapshot and restore wall times, replay
      chunk count — DESIGN.md §12) emitted by the sweep-durability
      family's WAL-on point; v5 documents remain valid
      (COMPAT_VERSIONS), the new key is enforced on v6 only.
  7 — Z-set merge-algebra PR: required metrics.zset block (weighted
      merge telemetry — rows in/out of every merge, annihilated rows,
      Ghost-gather payload bytes skipped, DESIGN.md §13); v5/v6
      documents remain valid, the new key is enforced on v7 only.
  8 — replication PR: required-but-nullable metrics.replication block
      (single-leader replication over the WAL — shipped frames,
      follower lag, failover wall time, answer-exactness of the
      promoted follower, DESIGN.md §14) emitted by the `replication`
      scenario; v5-v7 documents remain valid, the new key is enforced
      on v8 only.
  9 — self-healing replication PR: metrics.replication gains the
      failover_auto_ms / rpo_records / wal_pruned_bytes /
      lease_expiries keys (leases + automatic promotion, quorum acks,
      watermark-bounded WAL pruning — DESIGN.md §15); v8 documents
      remain valid, the new keys are enforced on v9 only.
"""
from __future__ import annotations

from typing import Any, Dict, List

SCHEMA_VERSION = 9
# accepted on read: the committed trajectory keeps its v5-v8 documents
COMPAT_VERSIONS = (5, 6, 7, 8, 9)

_PHASE_KEYS = {"ops": int, "wall_s": float, "ops_per_s": float,
               "p50_us": float, "p99_us": float, "p999_us": float,
               "max_stall_us": float}
_ENGINE_KEYS = {"R": int, "Rn": int, "eps": float, "D": int, "m": float,
                "mu": int, "max_levels": int, "max_range": int,
                "cand_factor": int, "range_cand": int, "backend": str,
                "policy": str, "n_shards": int, "merge_budget": int,
                "tuning_mode": str}
_MAINT_KEYS = ("seals", "flushes", "spills", "compactions", "backlog_peak",
               "retunes")


def _typed(doc: Dict[str, Any], key: str, typ, errs: List[str],
           where: str) -> Any:
    if key not in doc:
        errs.append(f"{where}: missing key {key!r}")
        return None
    v = doc[key]
    if typ is float:
        ok = isinstance(v, (int, float)) and not isinstance(v, bool)
    elif typ is bool:
        ok = isinstance(v, bool)
    else:
        ok = isinstance(v, typ) and not (typ is int and isinstance(v, bool))
    if not ok:
        errs.append(f"{where}.{key}: expected {typ.__name__}, "
                    f"got {type(v).__name__}")
        return None
    return v


def _check_phase(phase: Any, where: str, errs: List[str]) -> None:
    if not isinstance(phase, dict):
        errs.append(f"{where}: expected object, got {type(phase).__name__}")
        return
    for key, typ in _PHASE_KEYS.items():
        v = _typed(phase, key, typ, errs, where)
        if isinstance(v, (int, float)) and v < 0:
            errs.append(f"{where}.{key}: negative ({v})")
    ops = phase.get("ops")
    if isinstance(ops, int) and ops == 0:
        errs.append(f"{where}.ops: phase present but empty")


_SERVING_POINT_KEYS = {"clients": int, "ops": int, "requests": int,
                       "wall_s": float, "ops_per_s": float,
                       "requests_per_s": float, "p50_us": float,
                       "p99_us": float, "p999_us": float,
                       "max_stall_us": float, "windows": int,
                       "dispatches": int}


def _check_serving_point(pt: Any, where: str, errs: List[str]) -> None:
    """One closed-loop measurement (see module docstring serving-point)."""
    if not isinstance(pt, dict):
        errs.append(f"{where}: expected object, got {type(pt).__name__}")
        return
    for key, typ in _SERVING_POINT_KEYS.items():
        v = _typed(pt, key, typ, errs, where)
        if isinstance(v, (int, float)) and v < 0:
            errs.append(f"{where}.{key}: negative ({v})")
    for key in ("clients", "ops", "requests", "windows", "dispatches"):
        v = pt.get(key)
        if isinstance(v, int) and v <= 0:
            errs.append(f"{where}.{key}: must be positive ({v})")


def _check_serving(srv: Dict[str, Any], errs: List[str]) -> None:
    """The metrics.serving block of a serving-scenario document."""
    where = "metrics.serving"
    sweep = _typed(srv, "sweep", list, errs, where)
    if sweep is not None:
        if not sweep:
            errs.append(f"{where}.sweep: empty offered-load sweep")
        for i, pt in enumerate(sweep):
            _check_serving_point(pt, f"{where}.sweep[{i}]", errs)
    for key in ("coalesced", "per_request"):
        if key not in srv:
            errs.append(f"{where}: missing key {key!r}")
        else:
            _check_serving_point(srv[key], f"{where}.{key}", errs)
    sp = _typed(srv, "coalesced_speedup", float, errs, where)
    if isinstance(sp, (int, float)) and sp <= 0:
        errs.append(f"{where}.coalesced_speedup: must be positive ({sp})")
    slo = _typed(srv, "slo_p99_us", float, errs, where)
    if isinstance(slo, (int, float)) and slo <= 0:
        errs.append(f"{where}.slo_p99_us: must be positive ({slo})")
    sus = _typed(srv, "sustained_ops_at_slo", float, errs, where)
    if isinstance(sus, (int, float)) and sus < 0:
        errs.append(f"{where}.sustained_ops_at_slo: negative ({sus})")
    gov = _typed(srv, "governor", dict, errs, where)
    if gov is not None:
        for key in ("steps", "idle_steps"):
            v = _typed(gov, key, int, errs, f"{where}.governor")
            if isinstance(v, int) and v < 0:
                errs.append(f"{where}.governor.{key}: negative ({v})")


def validate(doc: Any) -> List[str]:
    """Structural check of one BENCH document; [] means valid."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return [f"document: expected object, got {type(doc).__name__}"]

    ver = _typed(doc, "schema_version", int, errs, "document")
    if ver is not None and ver not in COMPAT_VERSIONS:
        errs.append(f"schema_version: {ver} not in supported "
                    f"{COMPAT_VERSIONS}")
    _typed(doc, "name", str, errs, "document")

    wl = _typed(doc, "workload", dict, errs, "document")
    if wl is not None:
        _typed(wl, "kind", str, errs, "workload")
        n = _typed(wl, "n", int, errs, "workload")
        if isinstance(n, int) and n <= 0:
            errs.append(f"workload.n: must be positive ({n})")
        _typed(wl, "seed", int, errs, "workload")
        _typed(wl, "args", dict, errs, "workload")

    eng = _typed(doc, "engine", dict, errs, "document")
    if eng is not None:
        for key, typ in _ENGINE_KEYS.items():
            _typed(eng, key, typ, errs, "engine")

    prof = _typed(doc, "profile", dict, errs, "document")
    if prof is not None:
        _typed(prof, "name", str, errs, "profile")
        for key in ("batch", "n_lookups", "n_per_query"):
            v = _typed(prof, key, int, errs, "profile")
            if isinstance(v, int) and v <= 0:
                errs.append(f"profile.{key}: must be positive ({v})")
        _typed(prof, "insert_steady_state", bool, errs, "profile")

    met = _typed(doc, "metrics", dict, errs, "document")
    if met is not None:
        # the serving block gates the standard phases' nullability: a
        # serving document has no phase arrays (and must say so with
        # explicit nulls); every other document must carry them
        if "serving" not in met:
            errs.append("metrics: missing key 'serving' (use null for "
                        "non-serving scenarios)")
        serving = met.get("serving")
        if serving is not None:
            _check_serving(serving, errs)
        for req in ("insert", "lookup_batched", "lookup_per_query"):
            if met.get(req) is None:
                if serving is None:
                    errs.append(f"metrics.{req}: null is only valid on "
                                "serving documents")
                elif req not in met:
                    errs.append(f"metrics: missing key {req!r}")
            else:
                _check_phase(met.get(req), f"metrics.{req}", errs)
        for opt in ("delete", "range", "range_batched"):
            if met.get(opt) is not None:
                _check_phase(met[opt], f"metrics.{opt}", errs)
            elif opt not in met:
                errs.append(f"metrics: missing key {opt!r} (use null when "
                            "the workload has no such phase)")
        if "range_stats" not in met:
            errs.append("metrics: missing key 'range_stats' (use null when "
                        "the workload has no scan windows)")
        elif met["range_stats"] is not None:
            rs = _typed(met, "range_stats", dict, errs, "metrics")
            if rs is not None:
                km = _typed(rs, "keys_returned_mean", float, errs,
                            "metrics.range_stats")
                if isinstance(km, (int, float)) and km < 0:
                    errs.append(
                        f"metrics.range_stats.keys_returned_mean: "
                        f"negative ({km})")
                for key in ("keys_returned_max", "scans_truncated"):
                    v = _typed(rs, key, int, errs, "metrics.range_stats")
                    if isinstance(v, int) and v < 0:
                        errs.append(
                            f"metrics.range_stats.{key}: negative ({v})")
        if ((met.get("range_batched") is None)
                != (met.get("range_stats") is None)):
            errs.append("metrics: range_batched and range_stats must be "
                        "both present or both null")
        if met.get("batched_speedup") is None:
            if serving is None:
                errs.append("metrics.batched_speedup: null is only valid "
                            "on serving documents")
            elif "batched_speedup" not in met:
                errs.append("metrics: missing key 'batched_speedup'")
        else:
            sp = _typed(met, "batched_speedup", float, errs, "metrics")
            if isinstance(sp, (int, float)) and sp <= 0:
                errs.append(
                    f"metrics.batched_speedup: must be positive ({sp})")
        maint = _typed(met, "maintenance", dict, errs, "metrics")
        if maint is not None:
            for key in _MAINT_KEYS:
                v = _typed(maint, key, int, errs, "metrics.maintenance")
                if isinstance(v, int) and v < 0:
                    errs.append(f"metrics.maintenance.{key}: negative ({v})")
        if "tuner" not in met:
            errs.append("metrics: missing key 'tuner' (use null for "
                        "static-tuning engines)")
        elif met["tuner"] is not None:
            tun = _typed(met, "tuner", dict, errs, "metrics")
            if tun is not None:
                _typed(tun, "active", str, errs, "metrics.tuner")
                rf = _typed(tun, "read_frac", float, errs, "metrics.tuner")
                if isinstance(rf, (int, float)) and not 0 <= rf <= 1:
                    errs.append(
                        f"metrics.tuner.read_frac: out of [0,1] ({rf})")
                bb = _typed(tun, "budget_bytes", int, errs, "metrics.tuner")
                if isinstance(bb, int) and bb <= 0:
                    errs.append(
                        f"metrics.tuner.budget_bytes: must be positive ({bb})")
        bloom = _typed(met, "bloom", dict, errs, "metrics")
        if bloom is not None:
            eps = _typed(bloom, "eps_configured", float, errs, "metrics.bloom")
            fp = _typed(bloom, "fp_rate_measured", float, errs, "metrics.bloom")
            _typed(bloom, "n_probed", int, errs, "metrics.bloom")
            if isinstance(eps, (int, float)) and not 0 < eps < 1:
                errs.append(f"metrics.bloom.eps_configured: out of (0,1) ({eps})")
            if isinstance(fp, (int, float)) and not 0 <= fp <= 1:
                errs.append(f"metrics.bloom.fp_rate_measured: out of [0,1] ({fp})")
        # v7: the zset merge-telemetry block is required; earlier
        # documents predate the weighted algebra and are exempt
        if ver is not None and ver >= 7:
            zs = _typed(met, "zset", dict, errs, "metrics")
            if zs is not None:
                where = "metrics.zset"
                for key in ("rows_merged_in", "rows_merged_out",
                            "rows_annihilated",
                            "ghost_payload_bytes_skipped"):
                    v = _typed(zs, key, int, errs, where)
                    if isinstance(v, int) and v < 0:
                        errs.append(f"{where}.{key}: negative ({v})")
                ri, ro = zs.get("rows_merged_in"), zs.get("rows_merged_out")
                ra = zs.get("rows_annihilated")
                if (isinstance(ri, int) and isinstance(ro, int)
                        and ro > ri):
                    errs.append(f"{where}: rows_merged_out ({ro}) exceeds "
                                f"rows_merged_in ({ri})")
                if (isinstance(ri, int) and isinstance(ro, int)
                        and isinstance(ra, int) and ra != ri - ro):
                    errs.append(f"{where}: rows_annihilated ({ra}) != "
                                f"rows_merged_in - rows_merged_out "
                                f"({ri - ro})")
        # v6+: the durability block is a required (nullable) key — null on
        # WAL-off runs; v5 documents predate it and are exempt
        if ver is not None and ver >= 6:
            if "durability" not in met:
                errs.append("metrics: missing key 'durability' (use null "
                            "for WAL-off runs)")
            elif met["durability"] is not None:
                dur = _typed(met, "durability", dict, errs, "metrics")
                if dur is not None:
                    where = "metrics.durability"
                    for key, typ in (("wal_bytes", int),
                                     ("wal_records", int),
                                     ("wal_bytes_per_op", float),
                                     ("snapshot_ms", float),
                                     ("restore_ms", float),
                                     ("replayed_chunks", int)):
                        v = _typed(dur, key, typ, errs, where)
                        if isinstance(v, (int, float)) and v < 0:
                            errs.append(f"{where}.{key}: negative ({v})")
                    _typed(dur, "fsync", bool, errs, where)
                    wr = dur.get("wal_records")
                    if isinstance(wr, int) and wr <= 0:
                        errs.append(f"{where}.wal_records: a WAL-on run "
                                    f"must have logged records ({wr})")
        # v8+: the replication block is a required (nullable) key — null
        # on every non-replication scenario; earlier documents predate
        # the replication layer and are exempt
        if ver is not None and ver >= 8:
            if "replication" not in met:
                errs.append("metrics: missing key 'replication' (use null "
                            "for non-replication scenarios)")
            elif met["replication"] is not None:
                rep = _typed(met, "replication", dict, errs, "metrics")
                if rep is not None:
                    where = "metrics.replication"
                    for key, typ in (("followers", int),
                                     ("shipped_records", int),
                                     ("shipped_bytes", int),
                                     ("lag_records_peak", int),
                                     ("lag_records_final", int),
                                     ("lag_bytes_final", int),
                                     ("apply_ops_per_s", float),
                                     ("failover_ms", float)):
                        v = _typed(rep, key, typ, errs, where)
                        if isinstance(v, (int, float)) and v < 0:
                            errs.append(f"{where}.{key}: negative ({v})")
                    for key in ("followers", "shipped_records",
                                "shipped_bytes"):
                        v = rep.get(key)
                        if isinstance(v, int) and v <= 0:
                            errs.append(f"{where}.{key}: a replication "
                                        f"run must ship ({key}={v})")
                    _typed(rep, "promoted_exact", bool, errs, where)
                    # v9: the self-healing keys (leases, quorum acks,
                    # pruning); v8 documents predate them
                    if ver >= 9:
                        for key, typ in (("failover_auto_ms", float),
                                         ("rpo_records", int),
                                         ("wal_pruned_bytes", int),
                                         ("lease_expiries", int)):
                            v = _typed(rep, key, typ, errs, where)
                            if isinstance(v, (int, float)) and v < 0:
                                errs.append(f"{where}.{key}: "
                                            f"negative ({v})")
                        le = rep.get("lease_expiries")
                        if isinstance(le, int) and le <= 0:
                            errs.append(f"{where}.lease_expiries: an "
                                        "automatic failover requires an "
                                        f"observed lease expiry ({le})")

    env = _typed(doc, "env", dict, errs, "document")
    if env is not None:
        for key in ("jax", "numpy", "python", "platform", "timestamp"):
            _typed(env, key, str, errs, "env")
    return errs


def is_valid(doc: Any) -> bool:
    """True iff `validate(doc)` reports no problems."""
    return not validate(doc)
