"""Seeded workload generators — the paper's Section 3 families as data.

Every generator is a pure function of ``(n, seed, **knobs)`` returning a
`Workload`: fixed arrays for each op phase (insert, delete, point lookup,
range scan). Determinism under a fixed seed is part of the contract —
``BENCH_*.json`` trajectories are only comparable across PRs if the same
scenario name always replays the same byte-identical op stream
(tests/test_bench.py pins this).

Key-space convention: **inserted keys are always even**; ``key | 1`` is
therefore guaranteed-absent. That gives every family an exact absent-key
stream for Bloom false-positive measurement and miss-path lookups without
any membership bookkeeping.

Families (registry `WORKLOAD_FAMILIES`):
  uniform      — uniform random keys + mixed hit/miss point lookups
                 (paper 3.2-3.8: the default load for every sweep)
  sequential   — monotonically increasing keys, the LSM best case
                 (runs never overlap; cf. paper 3.9.1 low-variance limit)
  zipfian      — bounded Zipf(theta) over a shuffled key universe; the
                 YCSB-style skew the paper's update-in-place dedup (3.9.1)
                 and clustered-lookup experiments (3.9.2) are about
  delete-heavy — insert then tombstone a configured fraction (paper 2.8);
                 lookups split between deleted (must miss) and live keys
  range-scan   — uniform load + a stream of [lo, hi) scan windows
                 (paper 2.9 / 3.7: latency linear in span)
  serving      — interleaved multi-client tagged request stream (a
                 `ServingWorkload`, not phase arrays: the continuous-
                 batching serving scenario's input, DESIGN.md §11)

`make_kv_workload` (the original `repro.data` generator used by the
per-figure benches) also lives here now; `repro.data` re-exports it.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict

import numpy as np

_I32_MAX = 2**31 - 2


def _rng(family: str, seed: int) -> np.random.Generator:
    """Family-salted generator: distinct families never share a stream
    even at the same seed (crc32 is stable across platforms/runs)."""
    return np.random.default_rng((zlib.crc32(family.encode()), seed))


@dataclass
class Workload:
    """One deterministic op stream: phases are fixed arrays, not callbacks."""

    name: str
    kind: str
    seed: int
    keys: np.ndarray                 # insert keys (int32, even)
    vals: np.ndarray                 # insert values (int32)
    lookups: np.ndarray              # point-lookup keys (hits and misses)
    deletes: np.ndarray              # keys to tombstone (may be empty)
    ranges: np.ndarray               # (n_ranges, 2) [lo, hi) windows
    absent: np.ndarray               # guaranteed-absent keys (odd)
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def n(self) -> int:
        """Insert-stream length (the workload's size parameter)."""
        return len(self.keys)


def _finish(rng, kind, seed, keys, lookups_present, *, n_lookups,
            miss_frac, deletes=None, ranges=None, meta=None,
            lookups_override=None) -> Workload:
    """Shared assembly: values, hit/miss lookup mix, absent stream.
    A family with its own lookup semantics (delete-heavy's dead/live
    split) passes the stream via ``lookups_override`` instead."""
    keys = keys.astype(np.int32)
    vals = rng.integers(-2**30, 2**30, len(keys), dtype=np.int32)
    if lookups_override is not None:
        lookups = lookups_override.astype(np.int32)
    else:
        n_miss = int(n_lookups * miss_frac)
        n_hit = n_lookups - n_miss
        hits = rng.choice(lookups_present, size=n_hit, replace=True)
        misses = (rng.choice(keys, size=n_miss, replace=True) | np.int32(1))
        lookups = np.concatenate([hits, misses]).astype(np.int32)
        rng.shuffle(lookups)
    absent = (rng.choice(keys, size=min(4096, 4 * len(keys)),
                         replace=True) | np.int32(1)).astype(np.int32)
    return Workload(
        name=f"{kind}-n{len(keys)}-s{seed}", kind=kind, seed=seed,
        keys=keys, vals=vals, lookups=lookups,
        deletes=(np.zeros(0, np.int32) if deletes is None
                 else deletes.astype(np.int32)),
        ranges=(np.zeros((0, 2), np.int32) if ranges is None
                else ranges.astype(np.int32)),
        absent=absent, meta=meta or {})


def _even_uniform(rng, n, key_space) -> np.ndarray:
    return (rng.integers(0, key_space // 2, n, dtype=np.int64) * 2).astype(
        np.int32)


def make_uniform(n: int, seed: int = 0, *, key_space: int = _I32_MAX,
                 lookup_frac: float = 0.5,
                 miss_frac: float = 0.25) -> Workload:
    """Uniform random keys — the paper's default load (Section 3.2)."""
    rng = _rng("bench-uniform", seed)
    keys = _even_uniform(rng, n, key_space)
    return _finish(rng, "uniform", seed, keys, keys,
                   n_lookups=int(n * lookup_frac), miss_frac=miss_frac,
                   meta={"key_space": key_space})


def make_sequential(n: int, seed: int = 0, *, lookup_frac: float = 0.5,
                    miss_frac: float = 0.25) -> Workload:
    """Monotonically increasing keys — runs never overlap (LSM best case).

    The seeded start offset keeps distinct seeds on distinct key ranges;
    keys stay even so the `| 1` absent-stream convention holds.
    """
    rng = _rng("bench-sequential", seed)
    start = int(rng.integers(0, 2**20))
    keys = ((start + np.arange(n, dtype=np.int64)) * 2).astype(np.int32)
    return _finish(rng, "sequential", seed, keys, keys,
                   n_lookups=int(n * lookup_frac), miss_frac=miss_frac,
                   meta={"start": start})


def zipf_probs(universe: int, theta: float) -> np.ndarray:
    """Exact rank probabilities p_i ∝ 1/i^theta for a bounded Zipf."""
    w = 1.0 / np.power(np.arange(1, universe + 1, dtype=np.float64), theta)
    return w / w.sum()


def zipf_expected_top_mass(universe: int, theta: float,
                           frac: float = 0.01) -> float:
    """Probability mass the top ``frac`` of ranks receives — the analytic
    skew target tests/test_bench.py checks the sampler against."""
    top = max(1, int(universe * frac))
    return float(zipf_probs(universe, theta)[:top].sum())


def make_zipfian(n: int, seed: int = 0, *, universe: int = 20_000,
                 theta: float = 1.1, lookup_frac: float = 0.5,
                 miss_frac: float = 0.25) -> Workload:
    """Bounded Zipf(theta) via inverse-CDF over a shuffled key universe.

    Unlike ``numpy.random.zipf`` (unbounded, theta > 1 only) this draws
    ranks from the exact truncated distribution, then maps rank -> key
    through a seeded permutation so the hot keys are scattered across the
    key space (the paper's skew experiments, 3.9.1/3.9.2, are about
    *frequency* skew, not key-space clustering). Heavy duplication
    exercises the staging buffer's update-in-place dedup.
    """
    rng = _rng("bench-zipfian", seed)
    probs = zipf_probs(universe, theta)
    cdf = np.cumsum(probs)
    ranks = np.searchsorted(cdf, rng.random(n), side="right")
    ranks = np.minimum(ranks, universe - 1)
    perm = rng.permutation(universe).astype(np.int64)
    keys = (perm[ranks] * 2).astype(np.int32)
    # hit-lookup pool: zipf-weighted over the ranks actually inserted, so
    # the configured miss_frac holds exactly (an unconditional zipf draw
    # would hit never-inserted tail ranks and drift the miss rate with n)
    ins_ranks = np.unique(ranks)
    cdf_ins = np.cumsum(probs[ins_ranks])
    cdf_ins /= cdf_ins[-1]
    lookup_ranks = ins_ranks[np.minimum(
        np.searchsorted(cdf_ins, rng.random(n), side="right"),
        len(ins_ranks) - 1)]
    lookup_pool = (perm[lookup_ranks] * 2).astype(np.int32)
    return _finish(
        rng, "zipfian", seed, keys, lookup_pool,
        n_lookups=int(n * lookup_frac), miss_frac=miss_frac,
        meta={"universe": universe, "theta": theta,
              "expected_top1pct_mass": zipf_expected_top_mass(universe, theta)})


def _scan_windows(rng, keys: np.ndarray, n_ranges: int,
                  span: int) -> np.ndarray:
    """(n_ranges, 2) [lo, hi) windows centred on inserted keys, so every
    scan touches data. Drawn *after* every other phase's stream so
    enabling scans in a family leaves its insert/delete/lookup bytes
    untouched (the trajectory's determinism contract)."""
    if n_ranges <= 0:
        return np.zeros((0, 2), np.int32)
    centres = rng.choice(keys, size=n_ranges, replace=True).astype(np.int64)
    lo = np.maximum(0, centres - span // 2)
    hi = np.minimum(_I32_MAX, lo + span)
    return np.stack([lo, hi], axis=1).astype(np.int32)


def make_delete_heavy(n: int, seed: int = 0, *, delete_frac: float = 0.4,
                      key_space: int = 2**26, lookup_frac: float = 0.5,
                      miss_frac: float = 0.0, n_ranges: int = 0,
                      span: int = 2**18) -> Workload:
    """Insert then tombstone ``delete_frac`` of the distinct keys (paper
    2.8). Lookups: ``miss_frac`` absent probes; the rest split ~50/50
    between deleted keys (must miss once the tombstone is newest) and
    surviving keys. ``n_ranges`` adds post-delete scan windows (paper
    2.9) — scans over tombstone-dense data, the range engine's dedup
    stress case."""
    rng = _rng("bench-delete-heavy", seed)
    keys = _even_uniform(rng, n, key_space)
    distinct = np.unique(keys)
    n_del = max(1, int(len(distinct) * delete_frac))
    deleted = rng.choice(distinct, size=n_del, replace=False)
    live_mask = ~np.isin(distinct, deleted)
    live = distinct[live_mask] if live_mask.any() else deleted
    n_lookups = int(n * lookup_frac)
    n_absent = int(n_lookups * miss_frac)
    n_present = n_lookups - n_absent
    lk_absent = rng.choice(keys, size=n_absent, replace=True) | np.int32(1)
    lk_dead = rng.choice(deleted, size=n_present // 2, replace=True)
    lk_live = rng.choice(live, size=n_present - n_present // 2, replace=True)
    lookups = np.concatenate([lk_absent, lk_dead, lk_live]).astype(np.int32)
    rng.shuffle(lookups)
    out = _finish(rng, "delete-heavy", seed, keys, keys,
                  n_lookups=n_lookups, miss_frac=miss_frac,
                  deletes=deleted, lookups_override=lookups,
                  meta={"delete_frac": delete_frac,
                        "n_deleted": int(n_del), "span": span})
    out.ranges = _scan_windows(rng, keys, n_ranges, span)
    return out


def make_range_scan(n: int, seed: int = 0, *, key_space: int = 2**24,
                    n_ranges: int = 64, span: int = 2**16,
                    lookup_frac: float = 0.1,
                    miss_frac: float = 0.25) -> Workload:
    """Uniform load over a compact key space + [lo, hi) scan windows
    centred on inserted keys, so every scan touches data (paper 2.9/3.7:
    scan latency is linear in span)."""
    rng = _rng("bench-range-scan", seed)
    keys = _even_uniform(rng, n, key_space)
    centres = rng.choice(keys, size=n_ranges, replace=True).astype(np.int64)
    lo = np.maximum(0, centres - span // 2)
    hi = np.minimum(_I32_MAX, lo + span)
    ranges = np.stack([lo, hi], axis=1)
    return _finish(rng, "range-scan", seed, keys, keys,
                   n_lookups=max(1, int(n * lookup_frac)),
                   miss_frac=miss_frac, ranges=ranges,
                   meta={"n_ranges": n_ranges, "span": span,
                         "key_space": key_space})


def make_shifting(n: int, seed: int = 0, *, write_frac: float = 0.85,
                  key_space: int = 2**24, theta: float = 1.1,
                  lookup_frac: float = 4.0, miss_frac: float = 0.25,
                  n_ranges: int = 0, span: int = 2**16) -> Workload:
    """Mid-run workload shift: uniform write-heavy, then zipfian read-heavy.

    The adaptive tuner's proving ground (DESIGN.md §9): phase 1 is a bulk
    uniform insert stream with a trickle of lookups (the write-heavy
    regime the write-optimized allocation serves); phase 2 flips to
    Zipf(theta)-skewed lookups over the inserted data with a trickle of
    fresh inserts (the read-heavy regime; ``lookup_frac`` defaults well
    above 1 — a serving phase reads its data many times over, which is
    what makes paying an adaptation worthwhile). No drain separates the
    phases — the engine meets the shift mid-flight, exactly as a static
    configuration would.

    Phase geometry rides in ``meta``: ``n_phase1`` splits ``keys``,
    ``n_lookups_phase1`` splits ``lookups``. Keys stay even (absent
    probes are ``key | 1``, the module-wide convention). ``n_ranges``
    adds scan windows over the phase-1 data (measured after the
    read-heavy phase, like the per-query lookups).
    """
    rng = _rng("bench-shifting", seed)
    n1 = max(1, int(n * write_frac))
    n2 = max(1, n - n1)
    keys1 = _even_uniform(rng, n1, key_space)
    keys2 = _even_uniform(rng, n2, key_space)
    keys = np.concatenate([keys1, keys2])
    vals = rng.integers(-2**30, 2**30, len(keys), dtype=np.int32)
    n_lookups = max(2, int(n * lookup_frac))
    nl1 = max(1, n_lookups // 20)            # phase-1 read trickle
    nl2 = n_lookups - nl1

    def mixed(pool: np.ndarray, count: int) -> np.ndarray:
        n_miss = int(count * miss_frac)
        hits = rng.choice(pool, size=count - n_miss, replace=True)
        miss = rng.choice(keys1, size=n_miss, replace=True) | np.int32(1)
        out = np.concatenate([hits, miss]).astype(np.int32)
        rng.shuffle(out)
        return out

    l1 = mixed(keys1, nl1)
    # phase 2: zipf-skewed over the distinct phase-1 keys (hot working set)
    distinct = np.unique(keys1)
    probs = zipf_probs(len(distinct), theta)
    ranks = np.minimum(
        np.searchsorted(np.cumsum(probs), rng.random(nl2), side="right"),
        len(distinct) - 1)
    hot_perm = rng.permutation(len(distinct))
    l2 = mixed(distinct[hot_perm[ranks]], nl2)
    absent = (rng.choice(keys1, size=min(4096, 4 * n1), replace=True)
              | np.int32(1)).astype(np.int32)
    return Workload(
        name=f"shifting-n{n}-s{seed}", kind="shifting", seed=seed,
        keys=keys.astype(np.int32), vals=vals,
        lookups=np.concatenate([l1, l2]),
        deletes=np.zeros(0, np.int32),
        ranges=_scan_windows(rng, keys1, n_ranges, span),
        absent=absent,
        meta={"n_phase1": int(n1), "n_lookups_phase1": int(nl1),
              "theta": theta, "key_space": key_space,
              "write_frac": write_frac, "span": span})


@dataclass
class ServingRequest:
    """One tagged request in a serving stream: ``kind`` is insert /
    delete / lookup / range; ``keys``/``vals`` follow `repro.serve`'s
    submit convention (vals = values for inserts, hi bounds for ranges,
    unused otherwise). ``client`` tags the generating client — the
    closed-loop driver re-partitions the stream by concurrency, so the
    tag documents provenance rather than routing."""

    client: int
    kind: str
    keys: np.ndarray
    vals: np.ndarray


@dataclass
class ServingWorkload:
    """One deterministic interleaved multi-client request stream (the
    `serving` scenario's input — not phase arrays like `Workload`, but
    a stream-ordered tagged request list the batching server coalesces
    at runtime)."""

    name: str
    kind: str
    seed: int
    requests: list
    absent: np.ndarray               # guaranteed-absent keys (odd)
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def n(self) -> int:
        """Total ops across the stream (the size parameter)."""
        return int(sum(len(r.keys) for r in self.requests))


def make_serving(n: int, seed: int = 0, *, n_clients: int = 16,
                 key_space: int = 2**22, insert_frac: float = 0.50,
                 lookup_frac: float = 0.33, delete_frac: float = 0.07,
                 miss_frac: float = 0.25, max_req: int = 16,
                 span: int = 2**12) -> ServingWorkload:
    """Interleaved multi-client tagged request stream (~`n` total ops).

    Serving-shaped requests: each carries 1..`max_req` ops (scan
    requests carry 1-2 windows), kinds drawn from the configured mix
    (the remainder after insert/lookup/delete is range scans). The
    stream opens with an insert-only warm prefix (~10% of `n`) so reads
    have data to hit; lookups mix hits over the inserted-so-far prefix
    with guaranteed-absent probes (``key | 1`` — inserted keys are even,
    the module-wide convention), deletes tombstone previously inserted
    keys, and scan windows are centred on inserted keys. Deterministic
    under (family, seed), like every generator here.
    """
    rng = _rng("bench-serving", seed)
    kinds = np.array(["insert", "lookup", "delete", "range"])
    probs = np.array([insert_frac, lookup_frac, delete_frac,
                      1.0 - insert_frac - lookup_frac - delete_frac])
    if probs[-1] < 0:
        raise ValueError("serving op mix exceeds 1.0")
    requests: list = []
    inserted: list = []
    ops = 0
    warm_ops = max(max_req, n // 10)
    i = 0
    while ops < n:
        client = i % n_clients
        kind = ("insert" if ops < warm_ops or not inserted
                else str(rng.choice(kinds, p=probs)))
        if kind == "insert":
            sz = int(rng.integers(1, max_req + 1))
            ks = _even_uniform(rng, sz, key_space)
            vs = rng.integers(1, 2**30, sz, dtype=np.int32)
            inserted.append(ks)
            requests.append(ServingRequest(client, "insert", ks, vs))
        elif kind == "lookup":
            sz = int(rng.integers(1, max_req + 1))
            pool = inserted[int(rng.integers(0, len(inserted)))]
            ks = rng.choice(pool, sz, replace=True).astype(np.int32)
            miss = rng.random(sz) < miss_frac
            ks[miss] |= np.int32(1)
            requests.append(ServingRequest(
                client, "lookup", ks, np.zeros(sz, np.int32)))
        elif kind == "delete":
            sz = int(rng.integers(1, max(2, max_req // 4)))
            pool = inserted[int(rng.integers(0, len(inserted)))]
            ks = rng.choice(pool, sz, replace=True).astype(np.int32)
            requests.append(ServingRequest(
                client, "delete", ks, np.zeros(sz, np.int32)))
        else:  # range
            sz = int(rng.integers(1, 3))
            pool = inserted[int(rng.integers(0, len(inserted)))]
            centres = rng.choice(pool, sz, replace=True).astype(np.int64)
            lo = np.maximum(0, centres - span // 2).astype(np.int32)
            hi = np.minimum(_I32_MAX, lo.astype(np.int64) + span).astype(
                np.int32)
            requests.append(ServingRequest(client, "range", lo, hi))
        ops += len(requests[-1].keys)
        i += 1
    all_keys = np.concatenate(inserted)
    absent = (rng.choice(all_keys, size=min(4096, 4 * len(all_keys)),
                         replace=True) | np.int32(1)).astype(np.int32)
    return ServingWorkload(
        name=f"serving-n{n}-s{seed}", kind="serving", seed=seed,
        requests=requests, absent=absent,
        meta={"n_clients": n_clients, "key_space": key_space,
              "insert_frac": insert_frac, "lookup_frac": lookup_frac,
              "delete_frac": delete_frac, "miss_frac": miss_frac,
              "max_req": max_req, "span": span,
              "n_requests": len(requests)})


WORKLOAD_FAMILIES: Dict[str, Callable[..., Workload]] = {
    "uniform": make_uniform,
    "sequential": make_sequential,
    "zipfian": make_zipfian,
    "delete-heavy": make_delete_heavy,
    "range-scan": make_range_scan,
    "shifting": make_shifting,
    "serving": make_serving,
}


def make_workload(kind: str, n: int, seed: int = 0, **kw) -> Workload:
    """Build one workload from the family registry (see module docstring)."""
    try:
        fn = WORKLOAD_FAMILIES[kind]
    except KeyError:
        raise ValueError(f"unknown workload family {kind!r}; options: "
                         f"{sorted(WORKLOAD_FAMILIES)}") from None
    return fn(n, seed, **kw)


# --------------------------------------------------------------------------
# legacy generator (the per-figure benches + examples; paper Section 3
# parameterization by raw variance rather than named families)
# --------------------------------------------------------------------------

@dataclass
class KVWorkload:
    keys: np.ndarray      # insert keys, int32
    vals: np.ndarray      # insert values, int32
    lookups: np.ndarray   # lookup keys, int32
    name: str


def make_kv_workload(kind: str, n: int, seed: int = 0, *,
                     variance: float = 1e6, lookup_variance: float = 1e6,
                     lookup_frac: float = 0.5, zipf_a: float = 1.2,
                     key_space: int = 2**31 - 2) -> KVWorkload:
    """Paper Section 3 workload generators (figure benches).

    kind: uniform | normal | zipf | cluster-lookup
    """
    rng = np.random.default_rng(seed)
    n_lookup = int(n * lookup_frac)
    if kind == "uniform":
        keys = rng.integers(0, key_space, n, dtype=np.int64)
        lookups = rng.integers(0, key_space, n_lookup, dtype=np.int64)
    elif kind == "normal":
        keys = np.rint(rng.normal(0.0, np.sqrt(variance), n)).astype(np.int64)
        lookups = np.rint(
            rng.normal(0.0, np.sqrt(lookup_variance), n_lookup)).astype(np.int64)
    elif kind == "zipf":
        keys = rng.zipf(zipf_a, n).astype(np.int64) % key_space
        lookups = rng.zipf(zipf_a, n_lookup).astype(np.int64) % key_space
    elif kind == "cluster-lookup":
        keys = rng.integers(0, key_space, n, dtype=np.int64)
        centre = rng.integers(0, key_space, dtype=np.int64)
        lookups = (centre + np.rint(
            rng.normal(0.0, np.sqrt(lookup_variance), n_lookup)
        ).astype(np.int64))
    else:
        raise ValueError(kind)
    clip = 2**31 - 2
    keys = np.clip(keys, -clip, clip).astype(np.int32)
    lookups = np.clip(lookups, -clip, clip).astype(np.int32)
    vals = rng.integers(-2**30, 2**30, n, dtype=np.int32)
    return KVWorkload(keys=keys, vals=vals, lookups=lookups,
                      name=f"{kind}-n{n}")
