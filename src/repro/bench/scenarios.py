"""Named benchmark scenarios + parameter sweeps over the paper's knobs.

A `Scenario` = one workload family + one full engine configuration
(SLSMParams overrides, compaction policy, shard count). The canonical
eight (`--scenario all`) cover the workload taxonomy — uniform,
sequential, zipfian, delete-heavy, range-scan, the mid-run `shifting`
scenario that proves the adaptive tuner, the closed-loop `serving`
scenario that proves the continuous-batching layer, and the
`replication` scenario that prices single-leader replication over the
WAL (follower lag + failover, DESIGN.md §14) — at the CPU-scaled
paper baseline; the sweep families (`--scenario sweeps`, or one of
`sweep-R|sweep-Rn|sweep-D|sweep-m|sweep-eps|sweep-merge-budget|
sweep-policy|sweep-backend|sweep-shards|sweep-durability|sweep-tuner`)
vary exactly one knob at a time, reproducing the paper's experimental
axes (Table 1 + Section 3) plus the axes this repro adds: the ops
backend (jnp vs pallas), the shard count (1 vs S), the merge
scheduler's pacing budget (synchronous vs incremental, DESIGN.md §8),
the WAL on vs off (the durability tax, §12), and the adaptive tuner vs
every static eps on the shifting workload (DESIGN.md §9).

Scenario names are stable identifiers: `BENCH_<name>.json` files keyed
on them form the cross-PR perf trajectory, so renaming one breaks the
trajectory it anchors.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List

from repro.core.params import SLSMParams, TuningPolicy


def bench_params(**over) -> SLSMParams:
    """The paper's tuned baseline (Section 3: R=50, Rn=800, D=20, mu=512)
    scaled so every scenario runs in seconds on one CPU core, keeping the
    ratios (R/D, Rn/mu) and eps=1e-3 intact.

    merge_budget=1 paces the Do-Merge cascade one bounded step per insert
    chunk (DESIGN.md §8) — the trajectory's default since the scheduler
    PR, because a synchronous cascade buries the insert tail under the
    full flush->spill->compact chain (the seed BENCH_uniform.json
    recorded p99 = 724ms against a ~5ms p50). The sweep-merge-budget
    family keeps the synchronous point (merge_budget=0) measured.

    range_cand=512 caps every scan's candidate gather (DESIGN.md §10):
    the canonical scan windows hold ~100-250 in-window elements across
    all structures, so the budget leaves 2x+ headroom while keeping a
    scan's merge width ~1000x under the tree's total capacity — the
    range engine's whole point. Overflowing scans are flagged
    (`truncated`) and counted in the range_batched phase stats.
    """
    base = dict(R=8, Rn=256, eps=1e-3, D=4, m=1.0, mu=64, max_levels=3,
                max_range=4096, cand_factor=8, merge_budget=1,
                range_cand=512)
    base.update(over)
    return SLSMParams(**base)


# Sizing profiles: n inserts / n point lookups / per-query-path sample /
# batched dispatch width.  smoke is the CI gate (seconds); default is the
# trajectory point (`--scenario all`); full approaches the figure benches.
PROFILES: Dict[str, Dict[str, int]] = {
    # n must exceed (4/3)*(2*R*Rn + insert chunk) for the scenario's
    # engine params: the insert warmup (runner._run_inserts) has to cover
    # the first two buffer flushes — the first grows the levels pytree
    # (recompiling stage/seal), the second compiles the
    # drop_tombstones=False flush variant. smoke satisfies this only for
    # the base params (canonical five); default/full also cover the
    # largest sweep points (Rn=1024, R=32: 2*R*Rn + chunk = 20480).
    # serving_clients = the closed-loop offered-load sweep (client
    # counts, each client 1 outstanding request); serving_ops scales the
    # serving scenario's request stream separately from n (the stream is
    # ~6 ops/request, so n-sized streams would dominate the suite's wall
    # clock at per-request dispatch)
    "smoke": dict(n=7_500, n_lookups=1_024, n_per_query=24, batch=256,
                  n_ranges=8, serving_ops=2_000, serving_clients=(1, 8)),
    "default": dict(n=30_000, n_lookups=4_096, n_per_query=64, batch=1_024,
                    n_ranges=32, serving_ops=8_000,
                    serving_clients=(1, 8, 32)),
    "full": dict(n=60_000, n_lookups=8_192, n_per_query=128, batch=1_024,
                 n_ranges=64, serving_ops=16_000,
                 serving_clients=(1, 8, 32, 64)),
}


@dataclass
class Scenario:
    """One BENCH point: workload family + engine configuration."""

    name: str                                  # BENCH_<name>.json identity
    workload: str                              # WORKLOAD_FAMILIES key
    wargs: Dict[str, Any] = field(default_factory=dict)
    params: Dict[str, Any] = field(default_factory=dict)  # SLSMParams overrides
    policy: str = "tiering"                    # tiering | leveling
    n_shards: int = 1                          # 1 = single tree, >1 = ShardedSLSM
    seed: int = 0
    durability: bool = False                   # WAL + fsync on (DESIGN.md §12)
    replication: int = 0                       # followers to attach after the
                                               # phases (requires durability;
                                               # DESIGN.md §14)

    def engine_params(self) -> SLSMParams:
        """The scenario's full `SLSMParams`: the CPU-scaled paper
        baseline with this scenario's overrides applied."""
        return bench_params(**self.params)


# -- the canonical eight: one per workload family (--scenario all) ---------

# the adaptive tuner's policy for the canonical shifting point: decide
# every 512 ops so both phases see decisions even at the smoke profile
ADAPTIVE = TuningPolicy(mode="adaptive", interval=512, eps_floor=1e-4)

# every shifting scenario (tuned + static baselines) shares this
# geometry: Rn=128 halves the buffer capacity so the phase-1 bulk load
# builds real multi-level structure by the flip — the structure a static
# engine then drags through the read phase and the tuner folds away
SHIFT_PARAMS = dict(Rn=128)

CANONICAL: List[Scenario] = [
    Scenario("uniform", "uniform"),
    Scenario("sequential", "sequential"),
    Scenario("zipfian", "zipfian"),
    Scenario("delete_heavy", "delete-heavy"),
    Scenario("range_scan", "range-scan", params=dict(max_range=8192)),
    # the tuner's proving ground: write-heavy -> read-heavy mid-run, the
    # adaptive controller on; sweep-tuner holds the static comparisons
    Scenario("shifting", "shifting",
             params=dict(tuning=ADAPTIVE, **SHIFT_PARAMS)),
    # the continuous-batching serving layer (repro.serve, DESIGN.md §11):
    # closed-loop offered-load sweep, coalesced mixed-op tape dispatch vs
    # the per-request baseline at the top offered load
    Scenario("serving", "serving"),
    # single-leader replication over the WAL (DESIGN.md §14): the uniform
    # load on a fsyncing leader, then two followers stream the full log
    # (apply throughput + lag drain), and one is promoted (failover wall
    # time + answer-exactness) — the metrics.replication block
    Scenario("replication", "uniform", durability=True, replication=2),
]


def _sweep(prefix: str, axis: str, values, **extra) -> List[Scenario]:
    out = []
    for v in values:
        tag = str(v).replace(".", "p")
        out.append(Scenario(f"{prefix}_{tag}", "uniform",
                            params={axis: v}, **extra))
    return out


SWEEPS: Dict[str, List[Scenario]] = {
    # paper Table 1 knobs, one axis at a time, on the uniform load
    "sweep-R": _sweep("sweep_R", "R", (2, 8, 32)),
    "sweep-Rn": _sweep("sweep_Rn", "Rn", (64, 256, 1024)),
    "sweep-D": _sweep("sweep_D", "D", (2, 4, 8)),
    "sweep-m": _sweep("sweep_m", "m", (0.5, 1.0)),
    "sweep-eps": _sweep("sweep_eps", "eps", (0.1, 1e-3, 1e-5)),
    # this repro's own axes
    # merge pacing: 0 = the paper's synchronous cascade (the write-stall
    # baseline), >0 = steps per insert chunk (insert.p999_us /
    # max_stall_us and maintenance.backlog_peak are the axes to read)
    "sweep-merge-budget": _sweep("sweep_merge_budget", "merge_budget",
                                 (0, 1, 2, 4)),
    "sweep-policy": [
        Scenario("sweep_policy_tiering", "uniform", policy="tiering"),
        Scenario("sweep_policy_leveling", "uniform", policy="leveling"),
    ],
    "sweep-backend": [
        Scenario("sweep_backend_jnp", "uniform", params=dict(backend="jnp")),
        Scenario("sweep_backend_pallas", "uniform",
                 params=dict(backend="pallas")),
    ],
    "sweep-shards": [
        Scenario("sweep_shards_1", "uniform", n_shards=1),
        Scenario("sweep_shards_4", "uniform", n_shards=4),
    ],
    # the durability tax (DESIGN.md §12): the same uniform load with the
    # sequence-numbered WAL group-committing (fsync) at every driver call
    # vs the WAL off — insert throughput/stall deltas are the log's
    # price, and the WAL-on document's metrics.durability block carries
    # the recovery-side costs (snapshot_ms, restore_ms, replay size)
    "sweep-durability": [
        Scenario("sweep_durability_wal", "uniform", durability=True),
        Scenario("sweep_durability_off", "uniform"),
    ],
    # the adaptive tuner vs every static eps on the shifting workload
    # (DESIGN.md §9): the canonical `shifting` scenario is the tuned run;
    # these are the best-static-configuration baselines it must beat
    "sweep-tuner": [
        Scenario("sweep_tuner_eps_0p1", "shifting",
                 params=dict(eps=0.1, **SHIFT_PARAMS)),
        Scenario("sweep_tuner_eps_0p001", "shifting",
                 params=dict(eps=1e-3, **SHIFT_PARAMS)),
        Scenario("sweep_tuner_eps_1em05", "shifting",
                 params=dict(eps=1e-5, **SHIFT_PARAMS)),
    ],
}

SCENARIOS: Dict[str, Scenario] = {
    s.name: s for group in ([CANONICAL] + list(SWEEPS.values()))
    for s in group
}


def scenarios_for(selector: str) -> List[Scenario]:
    """Resolve a CLI selector: 'all' (canonical eight), 'sweeps' (every
    sweep), a sweep family ('sweep-R'), a scenario name, or a
    comma-separated mix of the above."""
    out: List[Scenario] = []
    for part in selector.split(","):
        part = part.strip()
        if part == "all":
            out.extend(CANONICAL)
        elif part == "sweeps":
            for group in SWEEPS.values():
                out.extend(group)
        elif part in SWEEPS:
            out.extend(SWEEPS[part])
        elif part in SCENARIOS:
            out.append(SCENARIOS[part])
        else:
            raise ValueError(
                f"unknown scenario selector {part!r}; options: all, sweeps, "
                f"{', '.join(sorted(SWEEPS))}, or a name from "
                f"{', '.join(sorted(SCENARIOS))}")
    seen, uniq = set(), []
    for s in out:
        if s.name not in seen:
            seen.add(s.name)
            uniq.append(s)
    return uniq
