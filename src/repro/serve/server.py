"""The continuous-batching server: windows, the governor, and accounting.

`Server` fronts one engine (`SLSM` or `ShardedSLSM`) with a
submit/pump loop:

  * `submit` enqueues one per-client tagged request (insert / delete /
    lookup / range) and returns its `Ticket` immediately;
  * `pump` closes the current coalescing window when the adaptive
    time/size policy says so (or on `force`), folds the window into
    hazard-ordered tape chunks (`repro.serve.coalescer`), executes them
    as one device dispatch (`SLSM.run_tape` — the mixed-op tape,
    DESIGN.md §11), scatters results onto the tickets, and lets the
    maintenance governor spend its accumulated merge budget;
  * `drain` is the barrier: every pending request served, every pending
    maintenance step retired.

Steady state never JITs (`warm` precompiles the tape interpreter grid)
and never syncs per-op (one device->host transfer per tape). The
``per_request`` mode is the measured baseline: the same submit/pump
loop, but every request dispatched through the classic per-op driver
calls — what the serving bench's coalesced-vs-per-request comparison is
made of.

Per-client latency accounting rides the tickets: every reply stamps
enqueue->reply seconds into the server's client ledgers, and `stats()`
folds them into p50/p99/p999/max-stall percentiles per client and
overall.

Replication roles (DESIGN.md §14): ``role="leader"`` (default) serves
the full op set with read-your-writes (log-before-ack is the window
boundary's group commit, and replication ships only durable bytes);
``role="follower"`` fronts a replica engine — write submits are
rejected at intake, reads serve the eventually-consistent applied
watermark. Either way, when the engine carries a
``repro.engine.replication`` endpoint (``tree.replication``), the pump
drives it between windows and in idle gaps: shipping on a leader,
applying on a follower.

Self-healing (DESIGN.md §15) rides the same seams: ``role`` is live —
a follower that auto-promoted on lease expiry starts accepting writes,
a fenced (deposed) leader stops; a quorum-mode leader holds each
window's write acks until k followers confirm the bytes
(`_pump_replication` releases them against ``quorum_seqno()``); and
idle gaps run watermark-bounded WAL pruning next to snapshots. A held
write never hangs forever: if the leader is deposed, the quorum stays
unreachable past ``quorum_timeout_s``, or `drain` exhausts its bounded
release attempts, the held tickets fail with a typed `QuorumAckError`
instead of leaving clients awaiting a future that never resolves.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.engine.engine import reject_reserved
from repro.engine.replication import Leader as _RepLeader
from repro.serve.coalescer import OP_OF, coalesce, scatter

KINDS = ("insert", "delete", "lookup", "range")


class QuorumAckError(RuntimeError):
    """A quorum-held write ticket cannot be client-acked: the leader
    was deposed before k followers confirmed the bytes, or the quorum
    stayed unreachable past the server's ``quorum_timeout_s``. The
    write executed and is locally durable — its fate is decided by
    whether the stream reached the successor — but the client was
    never acked, which is exactly the §14/§15 contract: an un-acked
    write may or may not survive failover; an acked one always does."""


class Ticket:
    """One submitted request: identity, payload, timing, and (after its
    window executes) the result.

    ``result`` is None for insert/delete, ``(vals, found)`` for lookup,
    ``(keys, vals, counts, truncated)`` for range — the driver-call
    shapes. ``done`` flips when the reply is stamped; ``latency_s`` is
    the enqueue->reply interval the server's accounting is built on.
    ``error`` is None on success; a quorum-held write whose ack became
    impossible carries the `QuorumAckError` here (and raises it from
    the asyncio future when the front-end attached one).
    """

    __slots__ = ("client", "kind", "keys", "vals", "t_enqueue", "t_reply",
                 "result", "future", "error")

    def __init__(self, client: str, kind: str, keys: np.ndarray,
                 vals: np.ndarray, t_enqueue: float):
        self.client = client
        self.kind = kind
        self.keys = keys
        self.vals = vals
        self.t_enqueue = t_enqueue
        self.t_reply: Optional[float] = None
        self.result: Any = None
        self.future: Any = None   # set by the asyncio front-end
        self.error: Optional[Exception] = None

    @property
    def done(self) -> bool:
        """True once the window holding this request has executed."""
        return self.t_reply is not None

    @property
    def latency_s(self) -> float:
        """Enqueue->reply seconds (raises if not yet served)."""
        if self.t_reply is None:
            raise RuntimeError("ticket not served yet")
        return self.t_reply - self.t_enqueue

    @property
    def n_ops(self) -> int:
        """Ops this request carries (keys, queries, or scan windows)."""
        return int(self.keys.size)


@dataclass
class WindowPolicy:
    """Adaptive time/size coalescing window.

    A window closes when either trigger fires: ``max_ops`` pending ops
    (size — the tape bucket grid is full enough to be worth a dispatch)
    or the oldest pending request aging past the adaptive deadline
    ``wait_s`` (time — latency floor under light load). The deadline
    adapts between ``min_wait_s`` and ``max_wait_s`` on every close:
    windows that fill on size push it up (heavier batching is free when
    load is high — requests were not waiting on the clock), windows
    that close by timeout while thin pull it down (waiting longer would
    only add latency, not batch size). ``adapt`` is the multiplicative
    step; ``fill_target`` the occupancy that leaves the deadline alone.
    """

    max_ops: int = 512
    min_wait_s: float = 1e-4
    max_wait_s: float = 5e-3
    adapt: float = 0.25
    fill_target: float = 0.5
    wait_s: float = field(default=1e-3)

    def should_close(self, pending_ops: int, oldest_age_s: float) -> bool:
        """Fire on either trigger: size (pending ops) or time (age of
        the oldest pending request vs the adaptive deadline)."""
        if pending_ops <= 0:
            return False
        return pending_ops >= self.max_ops or oldest_age_s >= self.wait_s

    def closed(self, pending_ops: int) -> None:
        """Adapt the deadline after a close at `pending_ops` occupancy
        (see class docstring for the direction of the adjustment)."""
        fill = pending_ops / max(self.max_ops, 1)
        self.wait_s *= 1.0 + self.adapt * np.clip(
            fill - self.fill_target, -1.0, 1.0)
        self.wait_s = float(np.clip(self.wait_s, self.min_wait_s,
                                    self.max_wait_s))


@dataclass
class Governor:
    """Maintenance governor: merge budget spent at window boundaries
    and in idle gaps instead of per insert chunk.

    The mixed-op tape seals in-scan but defers every other maintenance
    step (flush/spill/compact/RETUNE) to the host. The governor accrues
    the same budget the per-chunk scheduler would have granted —
    ``merge_budget`` steps per Rn write ops — and spends it through the
    drivers' uniform `voluntary_steps` after each window, where no
    request is waiting on the device. Idle pumps (nothing pending)
    additionally spend ``idle_steps`` for free: an idle gap is exactly
    when background work is invisible to clients. ``credit_cap`` bounds
    banked credits so a long write burst cannot bankroll an unbounded
    maintenance storm later.

    Idle gaps are also where durability snapshots land: when the served
    engine has a durability layer whose WAL has grown past its snapshot
    threshold (`wal.Durability.should_snapshot`), an idle pump
    serializes the device pytree (DESIGN.md §12) — snapshot cost rides
    the same no-client-is-waiting window as background merges, so the
    log-before-ack write path never absorbs a multi-ms snapshot stall.

    On a segmented WAL (`Durability(segment_bytes=...)`) idle gaps also
    run watermark-bounded pruning (DESIGN.md §15): a replicating leader
    prunes through `Leader.prune()` (which additionally floors at every
    attached follower's ack), a standalone engine through
    `Durability.prune(prune_floor())` — either way sealed segments the
    newest snapshot no longer needs are deleted, bounding log growth
    without ever touching bytes a bootstrap or replay could still want.
    """

    idle_steps: int = 1
    credit_cap: float = 16.0
    credits: float = 0.0
    steps_run: int = 0
    idle_steps_run: int = 0
    snapshots_run: int = 0
    prunes_run: int = 0
    pruned_segments: int = 0

    def window_done(self, tree, write_ops: int) -> int:
        """Accrue credit for the window's writes and spend whole steps
        (tree.voluntary_steps); returns how many ran."""
        p = tree.p_active
        self.credits = min(self.credit_cap,
                           self.credits
                           + p.merge_budget * write_ops / max(p.Rn, 1))
        budget = int(self.credits)
        if budget <= 0:
            return 0
        ran = tree.voluntary_steps(budget)
        self.credits -= ran
        self.steps_run += ran
        return ran

    def idle(self, tree) -> int:
        """Spend the idle allowance (an empty pump): background steps no
        client can observe, plus a due durability snapshot — the WAL has
        outgrown its threshold and nobody is waiting on the device.
        Returns how many maintenance steps ran."""
        dur = getattr(tree, "durability", None)
        if dur is not None and dur.should_snapshot():
            tree.snapshot()
            self.snapshots_run += 1
        if dur is not None and dur.segment_bytes is not None:
            rep = getattr(tree, "replication", None)
            if isinstance(rep, _RepLeader):
                dropped = rep.prune()
            else:
                dropped = dur.prune(dur.prune_floor())
            if dropped:
                self.prunes_run += 1
                self.pruned_segments += dropped
        if self.idle_steps <= 0:
            return 0
        ran = tree.voluntary_steps(self.idle_steps)
        self.idle_steps_run += ran
        self.steps_run += ran
        return ran


def _percentiles(lat_s: List[float]) -> Dict[str, float]:
    """Latency ledger -> the phase-style percentile block (µs)."""
    ts = np.asarray(lat_s, np.float64) * 1e6
    return {"n": int(ts.size),
            "p50_us": float(np.percentile(ts, 50)),
            "p99_us": float(np.percentile(ts, 99)),
            "p999_us": float(np.percentile(ts, 99.9)),
            "max_stall_us": float(ts.max())}


class Server:
    """Continuous-batching front-end over one engine (see module doc).

    ``mode`` selects the dispatch strategy the pump uses:
    ``"coalesced"`` (default) folds each window into mixed-op tapes;
    ``"per_request"`` serves each request with its own classic driver
    call (`insert`/`delete`/`lookup_many`/`range_many`) — the baseline
    the serving bench measures the tape against. Both modes share the
    submit/window/accounting machinery, so their latency numbers are
    directly comparable.

    ``role`` selects the replication stance (module docstring):
    ``"leader"`` accepts everything, ``"follower"`` rejects write
    submits (the stream is the only writer of a replica).
    """

    def __init__(self, tree, *, window: WindowPolicy | None = None,
                 governor: Governor | None = None, mode: str = "coalesced",
                 role: str = "leader", quorum_timeout_s: float = 30.0,
                 clock=time.perf_counter):
        if mode not in ("coalesced", "per_request"):
            raise ValueError(f"unknown serve mode {mode!r}")
        if role not in ("leader", "follower"):
            raise ValueError(f"unknown serve role {role!r}")
        self.role = role
        self.tree = tree
        self.window = window or WindowPolicy()
        self.governor = governor or Governor()
        self.mode = mode
        self.quorum_timeout_s = float(quorum_timeout_s)
        self.clock = clock
        self._pending: List[Ticket] = []
        self._pending_ops = 0
        # quorum ack mode: windows whose write tickets are executed and
        # durable but not yet client-acked —
        # [(commit watermark, tickets, hold time)]
        self._unacked: List[tuple] = []
        self._lat: Dict[str, List[float]] = collections.defaultdict(list)
        self.counters = collections.Counter(
            requests=0, ops=0, windows=0, dispatches=0,
            write_ops=0, read_ops=0, range_ops=0,
            promotions=0, demotions=0, quorum_held=0, quorum_releases=0,
            quorum_failed=0)

    # -- role tracking ------------------------------------------------------
    def _sync_role(self) -> None:
        """Track self-healing role transitions (DESIGN.md §15): a
        follower whose engine auto-promoted (its ``replication``
        endpoint became a `Leader`) starts accepting writes; a leader
        whose engine was fenced (deposed by a successor's epoch, or
        still a replica) stops. The submit gate reads ``self.role``,
        so the flip is what turns intake-level write rejection on/off."""
        rep = getattr(self.tree, "replication", None)
        if self.role == "follower":
            lead = rep if isinstance(rep, _RepLeader) else getattr(
                rep, "new_leader", None)
            # a deposed leader endpoint on a fenced engine is NOT a
            # promotion — it's the before-state of a demoted node
            if (isinstance(lead, _RepLeader) and not lead.deposed
                    and not getattr(self.tree, "fenced", False)):
                self.role = "leader"
                self.counters["promotions"] += 1
        elif self.role == "leader":
            dur = getattr(self.tree, "durability", None)
            if getattr(self.tree, "fenced", False) or (
                    dur is not None and dur.replica):
                self.role = "follower"
                self.counters["demotions"] += 1

    # -- intake -------------------------------------------------------------
    def submit(self, client: str, kind: str, keys, vals=None) -> Ticket:
        """Enqueue one tagged request; returns its `Ticket` immediately.

        ``kind``: ``insert`` (keys+vals), ``delete`` (keys), ``lookup``
        (keys), or ``range`` (keys = lo bounds, vals = hi bounds, one
        scan window per lane). Reserved-sentinel validation happens
        here, at the submitting client's call site, so a bad request
        fails fast instead of poisoning a whole window.
        """
        if kind not in KINDS:
            raise ValueError(f"unknown request kind {kind!r}; "
                             f"options: {KINDS}")
        self._sync_role()
        if self.role == "follower" and kind in ("insert", "delete"):
            raise ValueError(
                f"follower is read-only: {kind!r} must go to the leader "
                "(the replication stream is a replica's only writer)")
        keys = np.asarray(keys, np.int32).reshape(-1)
        if kind == "insert":
            vals = np.asarray(vals, np.int32).reshape(-1)
            if keys.shape != vals.shape:
                raise ValueError("insert: keys and vals must match")
            reject_reserved(keys, vals, op="serve insert")
        elif kind == "delete":
            vals = np.zeros_like(keys)
            reject_reserved(keys, op="serve delete")
        elif kind == "lookup":
            vals = np.zeros_like(keys)
            reject_reserved(keys, op="serve lookup")
        else:  # range
            vals = np.asarray(vals, np.int32).reshape(-1)
            if keys.shape != vals.shape:
                raise ValueError("range: lo and hi bounds must match")
        t = Ticket(client, kind, keys, vals, self.clock())
        self._pending.append(t)
        self._pending_ops += t.n_ops
        self.counters["requests"] += 1
        self.counters["ops"] += t.n_ops
        key = {"insert": "write_ops", "delete": "write_ops",
               "lookup": "read_ops", "range": "range_ops"}[kind]
        self.counters[key] += t.n_ops
        return t

    @property
    def pending(self) -> int:
        """Requests currently waiting for a window."""
        return len(self._pending)

    def poll(self) -> bool:
        """Would `pump()` fire a window right now? (per_request mode
        dispatches whenever anything pends — there is no window)."""
        if not self._pending:
            return False
        if self.mode == "per_request":
            return True
        age = self.clock() - self._pending[0].t_enqueue
        return self.window.should_close(self._pending_ops, age)

    # -- the pump -----------------------------------------------------------
    def pump(self, force: bool = False) -> int:
        """Serve one window if due (or `force`d); returns requests served.

        An empty pump is an idle gap: the governor spends its idle
        allowance there and 0 is returned. After a served window the
        governor spends the window's accrued merge budget — both happen
        strictly *between* device dispatches, so maintenance never rides
        inside a request's tape (DESIGN.md §11). Replication (when the
        engine carries an endpoint) is pumped in the same seams: after
        each window and in every idle gap — shipping durable frames on
        a leader, applying received ones on a follower — so it never
        rides inside a request's dispatch either.

        Under quorum acks (``Leader(ack_mode="quorum")``, DESIGN.md
        §15) a window's *write* tickets are executed and locally
        durable here but not client-acked: they are held on
        ``_unacked`` tagged with the window's commit watermark (the
        leader's durable seqno after the group commit) and released by
        `_pump_replication` once ``quorum_seqno()`` clears it — so a
        client-visible ack always means k followers hold the bytes and
        failover loses nothing (RPO 0). Reads reply immediately.
        """
        self._sync_role()
        if not self._pending:
            self.governor.idle(self.tree)
            self._pump_replication()
            return 0
        if not (force or self.poll()):
            return 0
        batch, self._pending = self._pending, []
        batch_ops, self._pending_ops = self._pending_ops, 0
        if self.mode == "coalesced":
            chunks, placements = coalesce(self.tree.p_active, batch)
            results = self.tree.run_tape(chunks)
            scatter(batch, placements, results)
            self.counters["dispatches"] += 1
        else:
            self._serve_per_request(batch)
        write_ops = sum(t.n_ops for t in batch if OP_OF[t.kind] == "write")
        release = batch
        rep = getattr(self.tree, "replication", None)
        if (isinstance(rep, _RepLeader) and rep.ack_mode == "quorum"
                and write_ops):
            held = [t for t in batch if OP_OF[t.kind] == "write"]
            release = [t for t in batch if OP_OF[t.kind] != "write"]
            watermark = int(self.tree.durability.writer.last_seqno)
            self._unacked.append((watermark, held, self.clock()))
            self.counters["quorum_held"] += len(held)
        self._reply(release)
        self.counters["windows"] += 1
        self.window.closed(batch_ops)
        self.governor.window_done(self.tree, write_ops)
        self._pump_replication()
        return len(batch)

    def _reply(self, tickets: List[Ticket]) -> None:
        """Stamp replies: reply time, the client latency ledger, and
        the asyncio future (when the front-end attached one)."""
        if not tickets:
            return
        t_reply = self.clock()
        for t in tickets:
            t.t_reply = t_reply
            self._lat[t.client].append(t_reply - t.t_enqueue)
            if t.future is not None and not t.future.done():
                t.future.set_result(t.result)

    def _fail(self, tickets: List[Ticket], msg: str) -> None:
        """Fail held tickets with a typed `QuorumAckError`: stamp the
        reply time (so `done` flips and nothing re-holds them), attach
        the error, and reject the asyncio future when one is attached —
        an awaiting client raises instead of hanging forever. Failed
        tickets stay out of the latency ledgers (they measure served
        requests)."""
        t_reply = self.clock()
        err = QuorumAckError(msg)
        for t in tickets:
            t.t_reply = t_reply
            t.error = err
            if t.future is not None and not t.future.done():
                t.future.set_exception(err)
        self.counters["quorum_failed"] += len(tickets)

    def _pump_replication(self) -> None:
        """Drive the engine's replication endpoint (no-op when absent):
        a leader ships the window's now-durable frames, a follower
        applies whatever the stream delivered. On a quorum leader, then
        release every held window whose commit watermark the quorum
        ack has cleared (in window order — acks are monotone, so a
        cleared later window implies every earlier one). Held windows
        never hang forever: deposition (the endpoint is gone, fenced,
        or demoted) fails them all immediately — the successor decides
        those writes' fate now, this node can never learn it — and a
        window still unreleased ``quorum_timeout_s`` after its hold
        fails with a quorum-unreachable error."""
        rep = getattr(self.tree, "replication", None)
        if rep is not None:
            rep.pump()
        if not self._unacked:
            return
        if (not isinstance(rep, _RepLeader) or rep.deposed
                or getattr(self.tree, "fenced", False)):
            held, self._unacked = self._unacked, []
            for _, tickets, _ in held:
                self._fail(tickets,
                           "leader deposed before quorum ack: the write "
                           "executed locally but was never client-acked; "
                           "whether it survived rides on the successor's "
                           "applied stream")
            return
        q = rep.quorum_seqno()
        while self._unacked and self._unacked[0][0] <= q:
            _, held, _ = self._unacked.pop(0)
            self._reply(held)
            self.counters["quorum_releases"] += len(held)
        now = self.clock()
        expired = [w for w in self._unacked
                   if now - w[2] > self.quorum_timeout_s]
        if expired:
            self._unacked = [w for w in self._unacked
                             if now - w[2] <= self.quorum_timeout_s]
            for _, tickets, _ in expired:
                self._fail(tickets,
                           f"quorum not reached within "
                           f"{self.quorum_timeout_s:.1f}s "
                           "(quorum loss or unpumped followers): the "
                           "write executed locally but was never "
                           "client-acked")

    def _serve_per_request(self, batch: List[Ticket]) -> None:
        """Baseline dispatch: one classic driver call per request, in
        stream order — the per-op host/device ping-pong the tape
        replaces (each read pays its own device->host sync)."""
        tree = self.tree
        for t in batch:
            if t.kind == "insert":
                tree.insert(t.keys, t.vals)
            elif t.kind == "delete":
                tree.delete(t.keys)
            elif t.kind == "lookup":
                t.result = tree.lookup_many(t.keys)
            else:
                t.result = tree.range_many(
                    np.stack([t.keys, t.vals], axis=1))
            self.counters["dispatches"] += 1

    # -- barriers / warm-up ---------------------------------------------------
    def drain(self) -> None:
        """Serve everything pending, then retire the engine's whole
        maintenance backlog (the read-equivalence barrier — after this,
        the tree answers exactly as a sequential per-op engine fed the
        same stream). Held quorum windows get a bounded release
        attempt — acks can only arrive if the followers are being
        pumped elsewhere — and whatever is still held afterwards fails
        with `QuorumAckError`: past the barrier no pump will ever run
        again, so leaving the tickets pending would strand their
        awaiting clients forever."""
        while self._pending:
            self.pump(force=True)
        for _ in range(64):
            if not self._unacked:
                break
            self._pump_replication()
        if self._unacked:
            held, self._unacked = self._unacked, []
            for _, tickets, _ in held:
                self._fail(tickets,
                           "quorum unreachable at drain: no further pump "
                           "will run; the write executed locally but was "
                           "never client-acked")
        self.tree.drain()

    def warm(self, full: bool = True) -> None:
        """Precompile the serving grid so steady state never JITs: the
        tape interpreter buckets (`warm_tape`) and — with `full` — the
        engine's maintenance + read program set (`warm`, which the
        governor's steps and per_request mode dispatch from)."""
        if full:
            self.tree.warm()
        if self.mode == "coalesced":
            self.tree.warm_tape()

    # -- accounting -----------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Serving telemetry: per-client and overall enqueue->reply
        latency percentiles (p50/p99/p999/max stall, µs), the window /
        dispatch / op counters, the governor's spend (including idle-gap
        snapshots), the window policy's current adaptive deadline, and —
        when the served engine is durable — the durability block (WAL
        bytes/records/syncs, snapshots, last snapshot ms). A restored
        engine's ``engine`` block carries its ``restore_us`` /
        ``replayed_records``, so recovery stall time is first-class
        telemetry. With replication attached, the ``replication`` block
        carries the endpoint's stats — on a leader that includes
        ``follower_lag_records`` / ``follower_lag_bytes``. ``role`` is
        live (it flips with auto-promotion / fencing, §15), and the
        quorum hold queue is visible as ``unacked_windows`` /
        ``unacked_writes``."""
        self._sync_role()
        overall: List[float] = []
        clients = {}
        for c, lat in sorted(self._lat.items()):
            clients[c] = _percentiles(lat)
            overall.extend(lat)
        dur = getattr(self.tree, "durability", None)
        rep = getattr(self.tree, "replication", None)
        return {
            "role": self.role,
            "clients": clients,
            "overall": _percentiles(overall) if overall else None,
            "counters": dict(self.counters),
            "governor": {"steps": self.governor.steps_run,
                         "idle_steps": self.governor.idle_steps_run,
                         "snapshots": self.governor.snapshots_run,
                         "prunes": self.governor.prunes_run,
                         "pruned_segments": self.governor.pruned_segments,
                         "credits": self.governor.credits},
            "unacked_windows": len(self._unacked),
            "unacked_writes": sum(len(h) for _, h, _ in self._unacked),
            "window": {"wait_s": self.window.wait_s,
                       "max_ops": self.window.max_ops},
            "engine": {k: int(v) for k, v in self.tree.stats.items()},
            "durability": dur.stats() if dur is not None else None,
            "replication": rep.stats() if rep is not None else None,
        }
