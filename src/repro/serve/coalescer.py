"""Hazard-ordered request coalescing: tickets in, tape chunks out.

The server's window holds a stream-ordered list of per-client requests
(`server.Ticket`). This module folds that stream into the mixed-op tape's
chunk form (`repro.engine.tape.TapeChunk`) under one rule — **only
adjacent same-kind ops merge**. A lookup never moves past the write
submitted before it and never behind the write submitted after it, so
executing the coalesced chunks in order through the tape's `lax.scan` is
bitwise-equivalent to executing every request sequentially through the
per-op driver calls (the oracle property tests/test_serving.py pins).

Request kinds map onto tape op kinds:

  insert -> write  (keys/vals as submitted, weight +1 lanes)
  delete -> write  (weight -1 lanes with payload 0 — the Z-set
                    retraction, DESIGN.md §13; deletes therefore
                    coalesce WITH adjacent inserts)
  lookup -> lookup
  range  -> range  (keys = lo bounds, vals = hi bounds)

Chunks are bounded by `tape.chunk_capacity` (Rn lanes for write/lookup
slots, `range_lanes` windows for range slots); a request larger than the
remaining capacity splits across chunks — order-neutral, since the
split pieces stay adjacent. `Placement` records where each ticket's ops
landed so `scatter` can route the tape's per-chunk results back to the
tickets that asked for them.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import numpy as np

from repro.core.params import SLSMParams
from repro.engine import tape as TP

# request kind -> tape op kind (deletes are weight -1 writes, so they
# coalesce with adjacent inserts into one write chunk)
OP_OF = {"insert": "write", "delete": "write",
         "lookup": "lookup", "range": "range"}


class Placement(NamedTuple):
    """Where one contiguous piece of a ticket's ops landed.

    ``chunk``/``lane``/``n`` locate the piece inside the coalesced chunk
    list; ``off`` is its offset inside the ticket's own op array (a
    ticket larger than a chunk's remaining capacity spans several
    placements)."""
    chunk: int
    lane: int
    n: int
    off: int


def coalesce(p: SLSMParams, tickets: Sequence
             ) -> Tuple[List[TP.TapeChunk], List[List[Placement]]]:
    """Fold a stream-ordered ticket list into tape chunks.

    Returns ``(chunks, placements)``: ``chunks`` is the capacity-bounded
    `TapeChunk` list (stream order preserved; only adjacent same-kind
    ops merged), ``placements[i]`` locates ticket i's ops inside it.
    """
    chunks: List[TP.TapeChunk] = []
    placements: List[List[Placement]] = []
    cur_kind: str | None = None
    cur_keys: List[np.ndarray] = []
    cur_vals: List[np.ndarray] = []
    cur_wts: List[np.ndarray] = []
    cur_len = 0

    def close() -> None:
        nonlocal cur_kind, cur_keys, cur_vals, cur_wts, cur_len
        if cur_kind is not None:
            w = (np.concatenate(cur_wts) if cur_kind == "write" else None)
            chunks.append(TP.TapeChunk(cur_kind, np.concatenate(cur_keys),
                                       np.concatenate(cur_vals), w))
            cur_kind, cur_keys, cur_vals, cur_wts, cur_len = (
                None, [], [], [], 0)

    for t in tickets:
        kind = OP_OF[t.kind]
        keys = np.asarray(t.keys, np.int32).reshape(-1)
        if t.kind == "delete":
            vals = np.zeros_like(keys)
            wts = np.full_like(keys, -1)
        elif t.kind == "lookup":
            vals = np.zeros_like(keys)
            wts = np.zeros_like(keys)
        else:
            vals = np.asarray(t.vals, np.int32).reshape(-1)
            wts = np.ones_like(keys)
        cap = TP.chunk_capacity(p, kind)
        place: List[Placement] = []
        off = 0
        while off < len(keys):
            if cur_kind != kind:          # hazard boundary: close, reopen
                close()
                cur_kind = kind
            take = min(cap - cur_len, len(keys) - off)
            if take == 0:                 # chunk full: next one
                close()
                cur_kind = kind
                continue
            cur_keys.append(keys[off:off + take])
            cur_vals.append(vals[off:off + take])
            cur_wts.append(wts[off:off + take])
            place.append(Placement(len(chunks), cur_len, take, off))
            cur_len += take
            off += take
        placements.append(place)
    close()
    return chunks, placements


def scatter(tickets: Sequence, placements: Sequence[Sequence[Placement]],
            results: Sequence) -> None:
    """Route the tape's per-chunk results back onto each ticket.

    Sets ``ticket.result``: writes (insert/delete) -> None; lookups ->
    ``(vals, found)`` over the ticket's queries; ranges -> ``(keys,
    vals, counts, truncated)`` rows for the ticket's windows — exactly
    the shapes `SLSM.lookup_many` / `SLSM.range_many` return, so serving
    a request and calling the driver directly are interchangeable.
    """
    for t, place in zip(tickets, placements):
        if OP_OF[t.kind] == "write":
            t.result = None
            continue
        parts = [tuple(arr[pl.lane:pl.lane + pl.n]
                       for arr in results[pl.chunk]) for pl in place]
        t.result = tuple(np.concatenate(plane) for plane in zip(*parts))
