"""Closed-loop multi-client load generator (the serving bench's driver).

Offered load in a closed loop is the number of concurrent clients, each
with exactly one request outstanding: a client submits, waits for its
reply, then immediately submits its next request. Sweeping the client
count sweeps the offered load — and, in the batching server, the
coalescing window's natural size, since a window can hold at most one
request per blocked client.

`closed_loop` runs one fixed request stream at one concurrency level
against one server, synchronously: each round submits the next request
of every idle client, then pumps with ``force=True`` — with every live
client blocked, the input stream is momentarily exhausted, which is
exactly the condition the adaptive time trigger exists to detect in an
open system (the closed loop just reaches it with zero wait). The
stream is re-partitioned round-robin across the clients, so every sweep
point serves the *same total ops* — throughput numbers differ only by
dispatch strategy and window size, not by workload.

Results come back phase-style (ops/s plus enqueue->reply latency
percentiles), ready for the BENCH document's ``metrics.serving`` block.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Sequence

import numpy as np


def closed_loop(server, requests: Sequence, concurrency: int,
                clock=time.perf_counter) -> Dict[str, Any]:
    """Serve `requests` at `concurrency` clients, one outstanding each.

    ``requests`` is a stream-ordered sequence of objects with
    ``kind``/``keys``/``vals`` attributes (`repro.bench.workloads.
    ServingRequest`); it is re-partitioned round-robin over
    ``concurrency`` virtual clients. Returns the phase-style summary:
    ``{clients, ops, requests, wall_s, ops_per_s, requests_per_s,
    p50_us, p99_us, p999_us, max_stall_us, windows, dispatches}``.
    """
    if concurrency < 1:
        raise ValueError("concurrency must be >= 1")
    queues: List[List] = [list(requests[i::concurrency])
                          for i in range(concurrency)]
    cursors = [0] * concurrency
    outstanding: List[Any] = [None] * concurrency
    total = sum(len(q) for q in queues)
    done = 0
    win0 = server.counters["windows"]
    disp0 = server.counters["dispatches"]
    lat: List[float] = []
    n_ops = 0
    t0 = clock()
    while done < total:
        for c in range(concurrency):
            if outstanding[c] is None and cursors[c] < len(queues[c]):
                r = queues[c][cursors[c]]
                outstanding[c] = server.submit(f"client-{c}", r.kind,
                                               r.keys, r.vals)
                cursors[c] += 1
        server.pump(force=True)
        for c in range(concurrency):
            t = outstanding[c]
            if t is not None and t.done:
                lat.append(t.latency_s)
                n_ops += t.n_ops
                outstanding[c] = None
                done += 1
    wall = clock() - t0
    ts = np.asarray(lat, np.float64) * 1e6
    return {
        "clients": int(concurrency),
        "ops": int(n_ops),
        "requests": int(total),
        "wall_s": float(wall),
        "ops_per_s": float(n_ops / wall) if wall > 0 else 0.0,
        "requests_per_s": float(total / wall) if wall > 0 else 0.0,
        "p50_us": float(np.percentile(ts, 50)),
        "p99_us": float(np.percentile(ts, 99)),
        "p999_us": float(np.percentile(ts, 99.9)),
        "max_stall_us": float(ts.max()),
        "windows": int(server.counters["windows"] - win0),
        "dispatches": int(server.counters["dispatches"] - disp0),
    }


def sustained_at_slo(sweep: Sequence[Dict[str, Any]],
                     slo_p99_us: float) -> float:
    """Sustained throughput at the p99 SLO: the best ops/s among sweep
    points whose p99 enqueue->reply latency meets the target (0.0 when
    no offered-load point meets it)."""
    ok = [pt["ops_per_s"] for pt in sweep if pt["p99_us"] <= slo_p99_us]
    return float(max(ok)) if ok else 0.0
