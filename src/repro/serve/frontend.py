"""Asyncio front-end: ``await submit(...)`` over the batching server.

`AsyncServer` wraps a `server.Server` in an event loop: clients are
coroutines that await their requests; a single pump task drives the
coalescing windows. Because the engine is a host-driven device program,
all actual work still happens synchronously inside `Server.pump` — the
front-end's job is purely to let many logical clients interleave their
submissions onto one window stream, which is what makes the windows
worth coalescing in the first place.

Usage::

    async with AsyncServer(Server(tree)) as srv:
        vals, found = await srv.submit("alice", "lookup", keys)

The context manager starts the pump task on entry and drains on exit.
Each submit parks the ticket's result in an `asyncio.Future` the pump
resolves when the ticket's window executes.

Replication rides the same pump task: the server's idle pumps drive
the engine's replication endpoint (DESIGN.md §14), so an
``AsyncServer`` over a follower keeps applying the leader's stream
between client reads with no extra machinery, and one over a leader
keeps shipping. A follower server (``Server(tree, role="follower")``)
rejects write submits at intake; route writes to the leader.

Self-healing (DESIGN.md §15) needs no front-end changes either: under
quorum acks an awaited write simply resolves later — the pump holds its
ticket until k followers confirm the bytes and resolves the future on
release — and the ``role`` property is live, flipping when the wrapped
engine auto-promotes on lease expiry or fences after being deposed. If
the ack becomes impossible (deposition, quorum timeout, drain), the
held future is *rejected* with `repro.serve.QuorumAckError`, so the
awaiting client raises instead of hanging forever.
"""
from __future__ import annotations

import asyncio
from typing import Any

from repro.serve.server import Server


class AsyncServer:
    """Awaitable facade over a `Server` (see module docstring)."""

    def __init__(self, server: Server, poll_s: float = 1e-4):
        self.server = server
        self.poll_s = poll_s
        self._task: asyncio.Task | None = None
        self._stop = False

    @property
    def role(self) -> str:
        """The wrapped server's replication role (leader/follower)."""
        return self.server.role

    async def submit(self, client: str, kind: str, keys,
                     vals=None) -> Any:
        """Submit one tagged request and await its result (None for
        insert/delete, the driver-call tuples for lookup/range)."""
        ticket = self.server.submit(client, kind, keys, vals)
        ticket.future = asyncio.get_running_loop().create_future()
        return await ticket.future

    async def _run(self) -> None:
        """The pump task: serve windows as the policy fires them; sleep
        a poll tick when nothing was served (the server's idle pump
        spends the governor's idle allowance on those ticks)."""
        while not self._stop:
            served = self.server.pump()
            if served == 0:
                await asyncio.sleep(self.poll_s)

    async def start(self) -> "AsyncServer":
        """Start the pump task (idempotent)."""
        if self._task is None:
            self._stop = False
            self._task = asyncio.create_task(self._run())
        return self

    async def stop(self) -> None:
        """Serve every pending request, stop the pump task, and drain
        the engine's maintenance backlog."""
        self._stop = True
        if self._task is not None:
            await self._task
            self._task = None
        self.server.drain()

    async def __aenter__(self) -> "AsyncServer":
        """Context entry: start the pump task."""
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        """Context exit: stop and drain."""
        await self.stop()
