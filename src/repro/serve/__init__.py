"""repro.serve — the continuous-batching serving layer (DESIGN.md §11).

Module map:

  coalescer.py — hazard-ordered request->tape-chunk folding (adjacent
                 same-kind ops merge; stream order is preserved, which
                 is what makes serving bitwise-equal to sequential
                 per-op execution) + result scatter
  server.py    — `Server` (submit/pump/drain/warm/stats), the adaptive
                 time/size `WindowPolicy`, the maintenance `Governor`,
                 and per-client latency accounting
  frontend.py  — `AsyncServer`, the asyncio ``await submit(...)`` facade
  loadgen.py   — closed-loop multi-client driver + SLO helper (the
                 `serving` bench scenario's engine room)

The data plane is the engine's device-resident mixed-op tape
(`repro.engine.tape`): one coalescing window lowers to one `lax.scan`
dispatch, so steady-state serving pays one host->device launch and one
device->host sync per *window*, never per op — and, after `warm()`,
never JITs.
"""
from repro.serve.coalescer import Placement, coalesce, scatter  # noqa: F401
from repro.serve.frontend import AsyncServer                    # noqa: F401
from repro.serve.loadgen import closed_loop, sustained_at_slo   # noqa: F401
from repro.serve.server import (Governor, QuorumAckError,       # noqa: F401
                                Server, Ticket, WindowPolicy)
