"""Durability layer: sequence-numbered WAL + device-pytree snapshots.

The sLSM's deferred-write design (paper 2.1: buffer in memory, push
updates down later) means the whole tree lives in device memory and
dies with the process. This module is the recovery story (DESIGN.md
§12): every *driver-boundary write op* — the same tagged chunks the
tape and scheduler produce, including RETUNE decisions — is appended to
a CRC-framed, strictly sequence-numbered write-ahead log before the
device state that absorbs it can be observed by a client
(log-before-ack); periodically the full device pytree is serialized as
an atomic snapshot stamped with the WAL seqno watermark; and
``SLSM.restore`` / ``ShardedSLSM.restore`` (engine.py / sharded.py)
load the newest valid snapshot and replay the WAL tail through the
*existing* chunk-apply programs, so recovery reuses the warmed write
path instead of maintaining a second one.

Correctness contract: replay is oracle-exact at the *answer* level, not
the bitwise-state level. The scheduler's invariant — reads are exact at
every point between maintenance steps (DESIGN.md §8) — plus the tuner's
answer-invariant retunes (§9) mean a restored engine may hold its runs
at a different maintenance progress than the crashed one did, yet every
lookup and range answers bitwise-identically to a fresh engine fed the
durable op prefix. The crash-point injection suite
(``tests/durability/``) asserts exactly that, at byte-level torn tails,
chunk boundaries, and mid-seal/mid-spill/mid-RETUNE crash points.

WAL file format (little-endian):

    magic  b"SLSMWAL1"
    record := crc32 u32 | length u32 | seqno u64 | kind u8 | epoch u8
              | pad[2] | payload[length]

The crc32 covers everything after the crc field (length through
payload), so a torn or bit-flipped tail is rejected as a unit; seqnos
are strictly consecutive, so a valid-looking record after a gap is
rejected too. `read_wal` returns the longest well-formed prefix — a
torn final record is *dropped cleanly*, never partially applied — and
`WalWriter` truncates that torn tail before resuming appends.

The epoch byte (one of the format-1/2 pad bytes, so old logs decode as
epoch 0) guards *file reuse across failovers*: `promote()` bumps the
writer's epoch, so stale bytes from a previous incarnation that happen
to sit past a record-aligned truncation point — with the right next
seqno — are rejected by the prefix rule's non-decreasing-epoch check
instead of being replayed as live records.

Replication (DESIGN.md §14) rides this same framing: `WalTailer`
incrementally yields each newly durable frame *verbatim* so a leader
can ship raw frame bytes, and `WalWriter.append_frame` lets a follower
append them byte-identically, preserving the leader's seqno/epoch
stamps — leader WAL and follower WAL are bitwise-equal streams.

Record kinds:

    REC_META    json engine fingerprint (driver kind, params, shards) —
                always the first record, verified on reattach; carries
                ``"wal": 2`` (the weighted-record format version — not
                part of the engine fingerprint, so v1 dirs reattach)
    REC_WRITE   legacy (format 1) write chunk: n u32, keys int32[n],
                vals int32[n] (a TOMBSTONE value is a delete) — decoded
                for replay compatibility, never written anymore
    REC_WRITE2  one driver-boundary weighted write chunk (DESIGN.md
                §13): n u32, keys int32[n], vals int32[n], wts int8[n]
                (+1 insert, -1 delete)
    REC_RETUNE  one applied tuner allocation switch (utf-8 preset name)

Fsync batching: `WalWriter.append` only buffers; `Durability.sync`
writes and fsyncs the whole batch once — one fsync per driver call (or
per serving window), not per record. That group commit is what makes
log-before-ack affordable: `repro.serve` stamps replies only after
`run_tape` returns, and `run_tape` syncs its window's records before
dispatching it.

Snapshots are directories ``snap_<seqno>/`` (atomic ``.tmp-<pid>`` +
rename publish, one ``leaf_<i>.npy`` per pytree leaf, sha256-verified
``meta.json``), garbage-collected to ``keep_snapshots``.

Segmented logs (DESIGN.md §15): with ``segment_bytes`` set, `sync`
seals the active ``wal.log`` once it exceeds that size by renaming it
to ``wal_<first_seqno>.log`` and starting a fresh active tail — the
seqno/epoch stream continues unbroken across files, `read_wal_chain`
decodes the whole chain with cross-file continuity enforced, and the
`WalTailer` relocates its cursor across segment boundaries by seqno.
`Durability.prune` then deletes sealed segments entirely at or below a
watermark (never the active tail), which is what bounds WAL growth:
once a snapshot covers seqno s and every attached follower has acked
>= s, nothing below s is needed for recovery or for bootstrap
(snapshot + retained tail). Without ``segment_bytes`` (the default)
the log stays a single file and nothing here changes.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
import re
import shutil
import struct
import sys
import time
import zlib
from pathlib import Path
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import numpy as np

from repro.core.params import SLSMParams, TuningPolicy

MAGIC = b"SLSMWAL1"

# record framing: crc32 u32 | payload length u32 | seqno u64 | kind u8
#                 | epoch u8 | pad2
_HEADER = struct.Struct("<IIQBB2x")
_CRC_BODY_LEN = _HEADER.size - 4          # crc covers header-after-crc+payload
_MAX_PAYLOAD = 1 << 28                    # sanity bound while scanning

REC_META = 0      # json engine fingerprint (first record of every WAL)
REC_WRITE = 1     # legacy write chunk (keys+vals int32; TOMBSTONE = delete)
REC_RETUNE = 2    # one applied tuner allocation switch (preset name)
REC_WRITE2 = 3    # weighted write chunk (keys+vals int32, wts int8)

WAL_FORMAT = 2    # record-format version stamped into the META record
WRITE_KINDS = (REC_WRITE, REC_WRITE2)


class WalRecord(NamedTuple):
    """One decoded WAL record: its sequence number, kind tag, raw
    payload bytes (see the module docstring for the payload codecs),
    and the failover epoch it was stamped under (0 until the first
    `promote()` of the log's lineage)."""

    seqno: int
    kind: int
    payload: bytes
    epoch: int = 0


class SnapshotError(RuntimeError):
    """A snapshot directory failed integrity verification (missing or
    malformed meta.json, or a leaf whose sha256 does not match)."""


# --------------------------------------------------------------------------
# record codecs
# --------------------------------------------------------------------------

def encode_record(seqno: int, kind: int, payload: bytes,
                  epoch: int = 0) -> bytes:
    """Frame one record: crc32 header (covering length/seqno/kind/epoch
    and the payload) + payload bytes."""
    head = _HEADER.pack(0, len(payload), seqno, kind, epoch)
    crc = zlib.crc32(head[4:] + payload) & 0xFFFFFFFF
    return _HEADER.pack(crc, len(payload), seqno, kind, epoch) + payload


def encode_write(keys, vals, wts) -> bytes:
    """REC_WRITE2 payload: n u32 + keys int32[n] + vals int32[n] +
    wts int8[n] — one driver-boundary weighted write chunk (weight +1 is
    an insert, -1 a delete; DESIGN.md §13)."""
    k = np.ascontiguousarray(np.asarray(keys, np.int32).reshape(-1))
    v = np.ascontiguousarray(np.asarray(vals, np.int32).reshape(-1))
    w = np.ascontiguousarray(np.asarray(wts, np.int8).reshape(-1))
    if k.shape != v.shape or k.shape != w.shape:
        raise ValueError("encode_write: keys, vals and wts must match")
    return struct.pack("<I", k.size) + k.tobytes() + v.tobytes() + w.tobytes()


def decode_write(payload: bytes, kind: int = REC_WRITE2
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Decode a write chunk of either format to weighted form:
    -> (keys int32[n], vals int32[n], wts int32[n]).

    REC_WRITE2 decodes natively; a legacy REC_WRITE record maps its
    reserved TOMBSTONE value to a -1-weight delete with payload 0 — the
    one place the historical sentinel survives, so pre-weighted WAL
    directories replay exactly."""
    (n,) = struct.unpack_from("<I", payload, 0)
    if kind == REC_WRITE2:
        if len(payload) != 4 + 9 * n:
            raise ValueError(f"malformed REC_WRITE2 payload: n={n}, "
                             f"{len(payload)} bytes")
        k = np.frombuffer(payload, np.int32, count=n, offset=4)
        v = np.frombuffer(payload, np.int32, count=n, offset=4 + 4 * n)
        w = np.frombuffer(payload, np.int8, count=n, offset=4 + 8 * n)
        return k.copy(), v.copy(), w.astype(np.int32)
    if len(payload) != 4 + 8 * n:
        raise ValueError(f"malformed REC_WRITE payload: n={n}, "
                         f"{len(payload)} bytes")
    from repro.core.params import TOMBSTONE
    k = np.frombuffer(payload, np.int32, count=n, offset=4)
    v = np.frombuffer(payload, np.int32, count=n, offset=4 + 4 * n)
    is_del = v == np.int32(TOMBSTONE)
    w = np.where(is_del, np.int32(-1), np.int32(1))
    return k.copy(), np.where(is_del, np.int32(0), v), w


def read_wal(path) -> Tuple[List[WalRecord], int]:
    """Decode the longest well-formed prefix of a WAL file.

    Returns ``(records, good_bytes)``: every record up to — but not
    including — the first framing violation (short header, implausible
    length, CRC mismatch, a non-consecutive seqno, or a *decreasing*
    epoch), and the byte offset where that violation starts. A torn or
    corrupted tail is thereby dropped as a unit: no partial record is
    ever surfaced. The epoch check is what makes ``promote()``'s file
    reuse safe — stale pre-failover bytes past a record-aligned cut
    carry an older epoch and are rejected even when their seqno happens
    to be consecutive. ``good_bytes == 0`` means the file (or its
    magic) is unreadable and a resuming writer must start it over. A
    missing file decodes to ``([], 0)``.
    """
    p = Path(path)
    if not p.exists():
        return [], 0
    data = p.read_bytes()
    if len(data) < len(MAGIC) or data[:len(MAGIC)] != MAGIC:
        return [], 0
    records: List[WalRecord] = []
    off = len(MAGIC)
    prev: Optional[int] = None
    prev_epoch = 0
    while off + _HEADER.size <= len(data):
        crc, length, seqno, kind, epoch = _HEADER.unpack_from(data, off)
        end = off + _HEADER.size + length
        if length > _MAX_PAYLOAD or end > len(data):
            break
        if zlib.crc32(data[off + 4:end]) & 0xFFFFFFFF != crc:
            break
        if prev is not None and seqno != prev + 1:
            break
        if epoch < prev_epoch:
            break
        records.append(WalRecord(seqno, kind,
                                 bytes(data[off + _HEADER.size:end]),
                                 epoch))
        prev = seqno
        prev_epoch = epoch
        off = end
    return records, off


def check_frame(frame: bytes) -> Optional[WalRecord]:
    """Validate one standalone framed record (exact length, CRC) and
    decode it, or return None if the bytes are not a complete well-
    formed frame — the follower-side gate that rejects a corrupted or
    torn replication message without poisoning the stream."""
    if len(frame) < _HEADER.size:
        return None
    crc, length, seqno, kind, epoch = _HEADER.unpack_from(frame, 0)
    if length > _MAX_PAYLOAD or len(frame) != _HEADER.size + length:
        return None
    if zlib.crc32(frame[4:]) & 0xFFFFFFFF != crc:
        return None
    return WalRecord(seqno, kind, bytes(frame[_HEADER.size:]), epoch)


# --------------------------------------------------------------------------
# segmented-log chain (sealed wal_<first_seqno>.log files + active wal.log)
# --------------------------------------------------------------------------

_SEG_RE = re.compile(r"^wal_(\d+)\.log$")


def list_segments(directory) -> List[Tuple[int, Path]]:
    """Sealed, immutable WAL segments under `directory` as
    ``[(first_seqno, path), ...]`` sorted ascending by their first
    record's seqno (encoded in the filename at seal time). The active
    tail (``wal.log``) is never listed here — it is still being
    appended to and must never be pruned."""
    directory = Path(directory)
    if not directory.is_dir():
        return []
    out = []
    for p in directory.iterdir():
        m = _SEG_RE.match(p.name)
        if m:
            out.append((int(m.group(1)), p))
    return sorted(out)


def _first_seqno(path) -> Optional[int]:
    """Seqno of the first (possibly torn) frame header in a WAL file,
    or None when the file is missing/empty — a cheap O(1) probe used to
    detect that the active file was sealed and replaced underneath a
    tailer's cursor."""
    try:
        with open(path, "rb") as f:
            f.seek(len(MAGIC))
            head = f.read(_HEADER.size)
    except OSError:
        return None
    if len(head) < _HEADER.size:
        return None
    return _HEADER.unpack(head)[2]


def wal_chain(directory, active: str = "wal.log") -> List[Path]:
    """The ordered file chain of a (possibly segmented) WAL directory:
    every sealed segment ascending, then the active tail if present."""
    directory = Path(directory)
    paths = [p for _, p in list_segments(directory)]
    ap = directory / active
    if ap.exists():
        paths.append(ap)
    return paths


def read_wal_chain(directory, active: str = "wal.log"
                   ) -> Tuple[List[WalRecord], int]:
    """Decode the retained record stream of a whole WAL directory —
    every sealed segment in order, then the active tail — enforcing the
    `read_wal` prefix rule *across* file boundaries (consecutive
    seqnos, non-decreasing epochs). Returns ``(records,
    good_bytes_total)``; a pruned directory's stream simply starts at
    the first retained segment's seqno instead of 0."""
    records: List[WalRecord] = []
    total = 0
    prev: Optional[int] = None
    prev_epoch = 0
    for p in wal_chain(directory, active):
        recs, _ = read_wal(p)
        total += len(MAGIC)
        for r in recs:
            if prev is not None and r.seqno != prev + 1:
                return records, total
            if r.epoch < prev_epoch:
                return records, total
            records.append(r)
            prev, prev_epoch = r.seqno, r.epoch
            total += _HEADER.size + len(r.payload)
    return records, total


def chain_frames(directory, from_seqno: int,
                 active: str = "wal.log") -> List[bytes]:
    """Raw frame bytes of every retained record with ``seqno >=
    from_seqno`` across the segment chain, in order — the verbatim tail
    a leader's `bootstrap` copies past a snapshot watermark."""
    t = WalTailer(Path(directory) / active)
    frames: List[bytes] = []
    while True:
        got = t.poll()
        if not got:
            return frames
        frames.extend(f for r, f in got if r.seqno >= from_seqno)


class WalTailer:
    """Incremental reader of a live WAL's durable frame stream.

    A replication leader's shipping cursor: `poll` reads the file from
    a byte offset and yields each newly appended well-formed frame
    exactly once, as ``(record, raw_frame_bytes)`` — raw bytes so
    frames ship verbatim and a follower's `WalWriter.append_frame`
    reproduces the leader's log bitwise. The `read_wal` prefix rule
    applies incrementally: a frame surfaces only when fully present
    with a valid CRC, the expected consecutive seqno, and a
    non-decreasing epoch; a torn tail stays pending until the writer
    completes it.

    Segment chains: `path` names the *active* tail; when the durable
    stream spans sealed ``wal_<first_seqno>.log`` segments, the cursor
    hops files by seqno — a clean EOF on a sealed segment continues
    into the next one, and a mismatch at the cursor's offset (the
    active file was sealed and replaced underneath it) triggers a
    relocation of `next_seqno` across the chain. `pruned_gap` is set
    when the needed seqno was pruned away entirely: the cursor can
    never serve it and the consumer must re-`bootstrap`.
    """

    def __init__(self, path, offset: Optional[int] = None,
                 next_seqno: Optional[int] = None, epoch: int = 0):
        self.path = Path(path)          # the active tail
        self.dir = self.path.parent
        self.offset = len(MAGIC) if offset is None else offset
        self.next_seqno = next_seqno    # None = accept any first seqno
        self.epoch = epoch
        self.pruned_gap = False
        self._cur = self.path           # file the cursor points into
        self._cur_first: Optional[int] = None   # its first seqno, if seen
        # with no explicit position, start at the head of the chain
        self._needs_locate = offset is None and next_seqno is None

    def _poll_file(self, max_records: Optional[int],
                   out: List[Tuple[WalRecord, bytes]]) -> str:
        """Consume frames from the current file at the cursor; returns
        why it stopped: 'budget', 'eof' (cleanly exhausted), 'torn'
        (incomplete tail), 'mismatch' (complete frame that violates the
        prefix rule), or 'missing' (file gone)."""
        if not self._cur.exists():
            return "missing"
        with open(self._cur, "rb") as f:
            f.seek(self.offset)
            data = f.read()
        off = 0
        while True:
            if max_records is not None and len(out) >= max_records:
                return "budget"
            if off + _HEADER.size > len(data):
                return "eof" if off == len(data) else "torn"
            crc, length, seqno, kind, epoch = _HEADER.unpack_from(data, off)
            end = off + _HEADER.size + length
            if length > _MAX_PAYLOAD:
                return "mismatch"
            if end > len(data):
                return "torn"
            frame = bytes(data[off:end])
            if zlib.crc32(frame[4:]) & 0xFFFFFFFF != crc:
                return "mismatch"
            if self.next_seqno is not None and seqno != self.next_seqno:
                return "mismatch"
            if epoch < self.epoch:
                return "mismatch"
            if self.offset == len(MAGIC):
                self._cur_first = seqno
            out.append((WalRecord(seqno, kind, frame[_HEADER.size:], epoch),
                        frame))
            self.next_seqno = seqno + 1
            self.epoch = epoch
            self.offset += len(frame)
            off = end

    def _active_replaced(self) -> bool:
        """Was the active file sealed and restarted underneath a cursor
        positioned in it? (Its first record's seqno changed, or it shrank
        below the cursor while once holding records.)"""
        if self._cur != self.path:
            return False
        first = _first_seqno(self.path)
        if self._cur_first is None:
            # the cursor was parked at the head of a then-empty active:
            # it was replaced iff the file now opens at some seqno other
            # than the one the cursor is waiting for (that seqno was
            # sealed into a segment underneath us)...
            if first is not None:
                return (self.next_seqno is not None
                        and first != self.next_seqno)
            # ...or the active is empty *again* but the awaited seqno
            # was meanwhile sealed into the chain (tiny segments can
            # seal on every append, so the active is empty at each
            # poll and the new frames live only in sealed segments)
            if self.next_seqno is None:
                return False
            sealed = [p for p in wal_chain(self.dir, self.path.name)
                      if p != self.path]
            nf = _first_seqno(sealed[-1]) if sealed else None
            return nf is not None and nf >= self.next_seqno
        if first is None:
            try:
                size = os.path.getsize(self.path)
            except OSError:
                return True
            return self.offset > size
        return first != self._cur_first

    def _locate(self) -> bool:
        """Position the cursor at `next_seqno` (or the chain head when
        None) by walking the segment chain. Returns False — setting
        `pruned_gap` — when the needed seqno precedes every retained
        frame."""
        self._needs_locate = False
        chain = wal_chain(self.dir, self.path.name)
        if not chain:
            return False
        if self.next_seqno is None:
            self._cur, self.offset, self._cur_first = \
                chain[0], len(MAGIC), _first_seqno(chain[0])
            return True
        # last chain file whose first seqno <= next_seqno (an empty
        # active tail is a valid final position: frames arrive later)
        idx = None
        for i, p in enumerate(chain):
            first = _first_seqno(p)
            if first is None:       # empty active tail: head of nothing
                if idx is None:
                    idx = i
                break
            if first <= self.next_seqno:
                idx = i
            else:
                break
        if idx is None:
            self.pruned_gap = True
            return False
        while True:
            p = chain[idx]
            off = len(MAGIC)
            try:
                data = p.read_bytes()
            except OSError:
                return False
            found_end = False
            while off + _HEADER.size <= len(data):
                _, length, seqno, _, _ = _HEADER.unpack_from(data, off)
                end = off + _HEADER.size + length
                if length > _MAX_PAYLOAD or end > len(data):
                    break
                if seqno >= self.next_seqno:
                    found_end = True
                    break
                off = end
            if found_end or idx == len(chain) - 1:
                self._cur, self.offset = p, off
                self._cur_first = _first_seqno(p)
                return True
            idx += 1    # seqno continues in the next chain file

    def poll(self, max_records: Optional[int] = None
             ) -> List[Tuple[WalRecord, bytes]]:
        """Read every frame that became durable since the last poll
        (up to `max_records`), advancing the cursor past each — hopping
        sealed-segment boundaries transparently."""
        out: List[Tuple[WalRecord, bytes]] = []
        if self._needs_locate and not self._locate():
            return out
        relocated = False
        for _hop in range(64):
            status = self._poll_file(max_records, out)
            if status == "budget":
                break
            if status == "eof":
                if self._cur != self.path:
                    if not self._locate():      # sealed: hop the chain
                        break
                    continue
                if not relocated and self._active_replaced():
                    relocated = True
                    if self._locate():
                        continue
                break
            if status in ("mismatch", "torn", "missing"):
                # a roll may have replaced the bytes under the cursor;
                # relocate once — a genuine violation relocates to the
                # same spot and stays pending, exactly as before
                if not relocated and (self._cur != self.path
                                      or status == "missing"
                                      or self._active_replaced()):
                    relocated = True
                    if self._locate():
                        continue
                break
        return out

    def rewind(self, offset: int, next_seqno: Optional[int],
               epoch: int = 0) -> None:
        """Reset the cursor to an explicit byte position in the active
        file (leader retransmit after a follower reports a gap): the
        next `poll` re-reads from `offset` expecting `next_seqno`."""
        self._cur = self.path
        self._cur_first = None
        self._needs_locate = False
        self.pruned_gap = False
        self.offset = offset
        self.next_seqno = next_seqno
        self.epoch = epoch

    def rewind_to(self, next_seqno: int, epoch: int = 0) -> None:
        """Seqno-addressed rewind (segment-chain aware): the next
        `poll` relocates `next_seqno` across the chain, wherever the
        rolls put it — the retransmit path that survives sealing."""
        self.next_seqno = next_seqno
        self.epoch = epoch
        self.pruned_gap = False
        self._needs_locate = True


def record_offsets(path) -> List[Tuple[WalRecord, int, int]]:
    """``[(record, start, end), ...]`` byte extents of every well-formed
    record — the crash-point injection harness's map of where to cut."""
    records, _ = read_wal(path)
    out, off = [], len(MAGIC)
    for rec in records:
        end = off + _HEADER.size + len(rec.payload)
        out.append((rec, off, end))
        off = end
    return out


class WalWriter:
    """Append-only writer with torn-tail recovery and group commit.

    Opening an existing WAL scans it (`read_wal`), truncates whatever
    torn tail a crash left, and resumes seqnos after the last valid
    record (never below ``min_next_seqno``, so a log restarted after
    snapshot-only recovery cannot reuse watermarked seqnos). `append`
    only buffers; `sync` writes the whole batch in one OS write and —
    when asked — one fsync: the per-driver-call group commit the
    serving layer's log-before-ack window boundary rides.
    """

    def __init__(self, path, min_next_seqno: int = 0):
        self.path = Path(path)
        self.head: Optional[WalRecord] = None   # the META record, if any
        self.epoch = 0                          # failover epoch stamp
        self.first_seqno_in_file: Optional[int] = None  # segment-roll bound
        if self.path.exists():
            records, good = read_wal(self.path)
            if good == 0:
                self.path.write_bytes(MAGIC)    # unreadable: start over
                good, records = len(MAGIC), []
            else:
                with open(self.path, "r+b") as f:
                    f.truncate(good)            # drop the torn tail
            self.next_seqno = records[-1].seqno + 1 if records else 0
            self.epoch = records[-1].epoch if records else 0
            if records:
                self.first_seqno_in_file = records[0].seqno
            if records and records[0].kind == REC_META:
                self.head = records[0]
        else:
            self.path.write_bytes(MAGIC)
            good = len(MAGIC)
            self.next_seqno = 0
        self.next_seqno = max(self.next_seqno, min_next_seqno)
        self._f = open(self.path, "ab")
        self._buf: List[bytes] = []
        self.size = good          # well-formed bytes incl. buffered records
        self.records = 0          # records appended by THIS writer
        self.syncs = 0            # sync() calls that flushed something

    @property
    def last_seqno(self) -> int:
        """Seqno of the most recently appended record (-1 if none ever)."""
        return self.next_seqno - 1

    def append(self, kind: int, payload: bytes) -> int:
        """Buffer one framed record; returns the seqno it was stamped
        with. Nothing reaches the OS until `sync`."""
        seqno = self.next_seqno
        rec = encode_record(seqno, kind, payload, self.epoch)
        self._buf.append(rec)
        self.next_seqno += 1
        self.size += len(rec)
        self.records += 1
        if self.first_seqno_in_file is None:
            self.first_seqno_in_file = seqno
        if kind == REC_META and self.head is None:
            self.head = WalRecord(seqno, kind, payload, self.epoch)
        return seqno

    def append_frame(self, frame: bytes) -> int:
        """Buffer one *pre-framed* record verbatim (the replication
        follower path): the frame must pass `check_frame`, carry this
        writer's exact next seqno, and not regress the epoch — its
        leader-assigned stamps are preserved byte-identically. Returns
        the frame's seqno; raises ValueError on any violation (the
        caller drops or re-requests the frame, the log is untouched)."""
        rec = check_frame(frame)
        if rec is None:
            raise ValueError("append_frame: malformed frame (CRC/framing)")
        if rec.seqno != self.next_seqno:
            raise ValueError(f"append_frame: seqno {rec.seqno} != expected "
                             f"{self.next_seqno}")
        if rec.epoch < self.epoch:
            raise ValueError(f"append_frame: epoch regressed "
                             f"({rec.epoch} < {self.epoch})")
        self._buf.append(frame)
        self.next_seqno = rec.seqno + 1
        self.epoch = rec.epoch
        self.size += len(frame)
        self.records += 1
        if self.first_seqno_in_file is None:
            self.first_seqno_in_file = rec.seqno
        if rec.kind == REC_META and self.head is None:
            self.head = rec
        return rec.seqno

    def bump_epoch(self) -> int:
        """Advance the failover epoch stamped into subsequent records —
        called by a follower's ``promote()`` so any stale bytes a later
        crash exposes from the pre-failover lineage are rejected by the
        prefix rule's epoch check. Returns the new epoch."""
        if self.epoch >= 0xFF:
            raise ValueError("epoch exhausted (255 failovers on one log)")
        self.epoch += 1
        return self.epoch

    def sync(self, fsync: bool = True) -> None:
        """Group commit: one OS write of every buffered record, then —
        with `fsync` — one fdatasync-equivalent barrier. A no-op when
        nothing is buffered."""
        if not self._buf:
            return
        self._f.write(b"".join(self._buf))
        self._buf.clear()
        self._f.flush()
        if fsync:
            os.fsync(self._f.fileno())
        self.syncs += 1

    def close(self) -> None:
        """Flush (without fsync) and release the file handle."""
        self.sync(fsync=False)
        self._f.close()


# --------------------------------------------------------------------------
# pytree snapshot codec (the repo's one serialization path — the
# repro.checkpoint facade reuses it)
# --------------------------------------------------------------------------

# numpy can't natively save/compare ml_dtypes types; store bit-views
try:
    import ml_dtypes
    _EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}
except ImportError:             # pragma: no cover — ml_dtypes ships with jax
    _EXOTIC = {}


def _encode_leaf(leaf: np.ndarray) -> Tuple[np.ndarray, str]:
    name = leaf.dtype.name
    if name in _EXOTIC:
        return leaf.view(_EXOTIC[name][1]), name
    return leaf, name


def _decode_leaf(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][0])
    return arr


def _sha256_file(path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def write_snapshot(directory, num: int, leaves, meta: Dict[str, Any],
                   keep_last: Optional[int] = None,
                   prefix: str = "snap_") -> Path:
    """Atomically publish one numbered pytree snapshot.

    Writes ``<directory>/<prefix><num>.tmp-<pid>/`` — one
    ``leaf_<i>.npy`` per host-numpy leaf plus a ``meta.json`` carrying
    `meta`, per-leaf shapes/dtypes, and sha256 digests — then renames
    it to ``<prefix><num>/`` (the atomic publish: a crash mid-write
    leaves only an ignored ``.tmp`` dir). With `keep_last`, older
    numbered snapshots beyond that count are garbage-collected.
    Returns the published path.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"{prefix}{num}"
    tmp = Path(f"{final}.tmp-{os.getpid()}")
    tmp.mkdir(parents=True, exist_ok=True)
    doc = dict(meta)
    doc["leaves"] = []
    for i, leaf in enumerate(leaves):
        leaf = np.asarray(leaf)
        fn = f"leaf_{i}.npy"
        enc, dt_name = _encode_leaf(leaf)
        np.save(tmp / fn, enc)
        doc["leaves"].append({"file": fn, "shape": list(leaf.shape),
                              "dtype": dt_name,
                              "sha256": _sha256_file(tmp / fn)})
    (tmp / "meta.json").write_text(json.dumps(doc))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    if keep_last is not None:
        for _, old in list_snapshots(directory, prefix)[:-keep_last]:
            shutil.rmtree(old, ignore_errors=True)
    return final


def list_snapshots(directory, prefix: str = "snap_"
                   ) -> List[Tuple[int, Path]]:
    """Published (non-``.tmp``) snapshots under `directory`, as
    ``[(num, path), ...]`` sorted ascending by number."""
    directory = Path(directory)
    if not directory.is_dir():
        return []
    out = []
    for d in directory.iterdir():
        if not d.is_dir() or ".tmp" in d.name:
            continue
        if not d.name.startswith(prefix):
            continue
        suffix = d.name[len(prefix):]
        if suffix.lstrip("-").isdigit():
            out.append((int(suffix), d))
    return sorted(out)


def gc_tmp_snapshots(directory) -> None:
    """Remove orphaned ``.tmp-<pid>`` snapshot dirs (a crash mid-write
    leaves one; it was never published, so deleting it is always safe)."""
    directory = Path(directory)
    if not directory.is_dir():
        return
    for d in directory.iterdir():
        if d.is_dir() and ".tmp-" in d.name:
            shutil.rmtree(d, ignore_errors=True)


def read_snapshot(path) -> Tuple[List[np.ndarray], Dict[str, Any]]:
    """Load + verify one published snapshot directory.

    Every leaf file's sha256 is checked against ``meta.json`` before
    its array is surfaced. Returns ``(leaves, meta)``; raises
    `SnapshotError` on any missing file, malformed metadata, or digest
    mismatch (the caller falls back to an older snapshot)."""
    path = Path(path)
    try:
        meta = json.loads((path / "meta.json").read_text())
    except (OSError, ValueError) as e:
        raise SnapshotError(f"unreadable snapshot meta in {path}: {e}")
    leaves = []
    for entry in meta.get("leaves", []):
        fp = path / entry["file"]
        try:
            if _sha256_file(fp) != entry["sha256"]:
                raise SnapshotError(f"snapshot corruption detected: {fp}")
            arr = np.load(fp)
        except OSError as e:
            raise SnapshotError(f"unreadable snapshot leaf {fp}: {e}")
        leaves.append(_decode_leaf(arr, entry["dtype"]))
    return leaves, meta


def load_latest_snapshot(directory, prefix: str = "snap_"
                         ) -> Optional[Tuple[int, List[np.ndarray],
                                             Dict[str, Any]]]:
    """Newest snapshot that passes verification, or None.

    Tries snapshots newest-first; a corrupted one is reported to stderr
    and skipped — recovery then proceeds from the previous snapshot (or
    from a full-WAL replay when none survive), trading restore time for
    correctness instead of failing."""
    for num, path in reversed(list_snapshots(directory, prefix)):
        try:
            leaves, meta = read_snapshot(path)
            return num, leaves, meta
        except SnapshotError as e:
            print(f"# durability: skipping bad snapshot {path.name}: {e}",
                  file=sys.stderr)
    return None


# --------------------------------------------------------------------------
# params serialization (the snapshot/WAL engine fingerprint)
# --------------------------------------------------------------------------

def params_to_dict(p: SLSMParams) -> Dict[str, Any]:
    """JSON-safe dict form of an `SLSMParams` (nested `TuningPolicy`
    included) — the engine fingerprint stored in the WAL's META record
    and every snapshot, so `restore` can rebuild the exact static
    configuration without the caller re-supplying it."""
    d = dataclasses.asdict(p)
    d["eps_per_level"] = (None if p.eps_per_level is None
                          else list(p.eps_per_level))
    return d


def params_from_dict(d: Dict[str, Any]) -> SLSMParams:
    """Inverse of `params_to_dict` (lists back to tuples, the tuning
    dict back to a `TuningPolicy`)."""
    d = dict(d)
    tuning = d.get("tuning")
    if isinstance(tuning, dict):
        d["tuning"] = TuningPolicy(**tuning)
    if d.get("eps_per_level") is not None:
        d["eps_per_level"] = tuple(d["eps_per_level"])
    return SLSMParams(**d)


def _canon(obj: Any) -> Any:
    """JSON-normalized form (tuples->lists etc.) for fingerprint
    comparison between a fresh meta dict and one parsed from the WAL."""
    return json.loads(json.dumps(obj, sort_keys=True))


# --------------------------------------------------------------------------
# the durability manager (what the drivers own)
# --------------------------------------------------------------------------

class Durability:
    """One engine's durability surface: its WAL + its snapshot series.

    Owned by a driver (``SLSM(..., durability=...)`` /
    ``ShardedSLSM(..., durability=...)``) — the driver logs every write
    chunk and applied RETUNE through `log_write`/`log_retune`, group-
    commits with `sync` at each driver-call (or serving-window)
    boundary, and snapshots the device pytree with `snapshot`. The
    maintenance governor polls `should_snapshot` in idle gaps
    (``repro.serve.Governor.idle``) so snapshot cost never rides a
    client's window.

    ``fsync=False`` keeps the write+flush (process-crash durability,
    what the injection tests simulate) but skips the disk barrier — for
    tests and benches that do not model power loss.

    ``replica=True`` marks a replication follower's log: the WAL is a
    shipped copy of the leader's stream (bootstrapped from a snapshot +
    tail, extended via `append_frame`), so `ensure_header` never
    injects a local META record — a tail-only log stays a verbatim
    continuation of the leader's seqno stream.

    ``segment_bytes`` (None = a single unbounded ``wal.log``, the
    pre-segmentation behavior) makes `sync` seal the active file into
    ``wal_<first_seqno>.log`` once it exceeds that size; `prune` can
    then delete sealed segments at or below a watermark (DESIGN.md §15
    — the replication leader prunes at min(snapshot watermark, min
    follower ack), a standalone engine at its own snapshot
    watermark)."""

    def __init__(self, directory, *, fsync: bool = True,
                 snapshot_every_bytes: int = 1 << 20,
                 keep_snapshots: int = 2, replica: bool = False,
                 segment_bytes: Optional[int] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        gc_tmp_snapshots(self.dir)
        self.wal_path = self.dir / "wal.log"
        self.fsync = fsync
        self.replica = replica
        self.snapshot_every_bytes = snapshot_every_bytes
        self.keep_snapshots = keep_snapshots
        self.segment_bytes = segment_bytes
        self._writer: Optional[WalWriter] = None
        self._bytes_at_snapshot = len(MAGIC)
        self._sealed_bytes = sum(p.stat().st_size
                                 for _, p in list_segments(self.dir))
        self.last_snapshot_ms = 0.0
        self.counters = collections.Counter(snapshots=0, wal_rolls=0,
                                            wal_pruned_bytes=0,
                                            wal_pruned_segments=0)

    @property
    def writer(self) -> WalWriter:
        """The lazily opened `WalWriter` (opening truncates any torn
        tail; seqnos resume past the log, the newest snapshot's
        watermark, and any sealed segments — and the epoch carries over
        a roll boundary, so a fresh active tail after a failover keeps
        stamping the bumped epoch)."""
        if self._writer is None:
            snaps = list_snapshots(self.dir)
            min_next = snaps[-1][0] + 1 if snaps else 0
            epoch_floor = 0
            segs = list_segments(self.dir)
            if segs:
                recs, _ = read_wal(segs[-1][1])
                if recs:
                    min_next = max(min_next, recs[-1].seqno + 1)
                    epoch_floor = recs[-1].epoch
            self._writer = WalWriter(self.wal_path, min_next_seqno=min_next)
            self._writer.epoch = max(self._writer.epoch, epoch_floor)
        return self._writer

    # -- logging (driver write boundary) -----------------------------------
    def ensure_header(self, meta: Dict[str, Any]) -> None:
        """Write the leading META record on a fresh WAL, or verify an
        existing one matches `meta` — attaching an engine with different
        params/driver kind to a populated durability directory is a
        configuration error, not something replay can paper over.

        The ``"wal"`` record-format version is stripped from both sides
        of the comparison: it versions the WRITE payload codec, not the
        engine, and replay decodes either format — so a v1 (pre-
        weighted) directory reattaches and upgrades in place.

        A META record is only ever written to a *genuinely fresh* log
        (no records, no snapshot watermark) — a headless log that
        already holds records, or resumes past a watermark, is
        mid-stream (a replica's tail-only bootstrap, or snapshot-only
        recovery) and injecting a META there would corrupt the seqno
        stream; the fingerprint is then verified via the snapshot's
        copy by `restore` instead."""
        w = self.writer
        if w.head is None:
            if self.replica or w.last_seqno >= 0:
                return
            w.append(REC_META, json.dumps(_canon(meta),
                                          sort_keys=True).encode())
            self.sync()
            return
        existing = json.loads(w.head.payload.decode())
        strip = lambda d: {k: v for k, v in d.items() if k != "wal"}
        if strip(existing) != strip(_canon(meta)):
            raise ValueError(
                f"durability dir {self.dir} belongs to a different engine "
                f"configuration (logged {existing.get('driver')!r} "
                f"fingerprint does not match this engine)")

    def header_meta(self) -> Optional[Dict[str, Any]]:
        """The decoded META fingerprint of this WAL, or None when the
        log is missing/unreadable — or when pruning removed the genesis
        segment (restore then falls back to the snapshot's copy)."""
        chain = wal_chain(self.dir)
        if not chain:
            return None
        records, _ = read_wal(chain[0])
        if records and records[0].kind == REC_META:
            return json.loads(records[0].payload.decode())
        return None

    def log_write(self, keys, vals, wts) -> int:
        """Buffer one driver-boundary weighted write chunk; returns its
        seqno. Durable only after the next `sync` (the driver calls it
        before any result of the op can reach a client)."""
        return self.writer.append(REC_WRITE2, encode_write(keys, vals, wts))

    def append_frame(self, frame: bytes) -> int:
        """Buffer one leader-framed record verbatim (the replication
        follower path — see `WalWriter.append_frame`): leader-assigned
        seqno/epoch stamps are preserved, so the follower's log is a
        bitwise copy of the leader's stream. Durable after `sync`."""
        return self.writer.append_frame(frame)

    def log_retune(self, target: str) -> int:
        """Buffer one applied tuner allocation switch; returns its
        seqno. Replay re-applies it so a restored adaptive engine
        carries the allocation its WAL position had (answers are
        invariant either way — DESIGN.md §9)."""
        return self.writer.append(REC_RETUNE, target.encode())

    def sync(self) -> None:
        """Group commit: flush every buffered record in one write (+ one
        fsync unless configured off), then seal the active file into a
        segment if it outgrew ``segment_bytes``."""
        self.writer.sync(fsync=self.fsync)
        self._maybe_roll()

    def _maybe_roll(self) -> None:
        """Seal the active ``wal.log`` into ``wal_<first_seqno>.log``
        once it exceeds ``segment_bytes`` and start a fresh active tail
        continuing the same seqno/epoch stream. Only ever called right
        after a sync, so the sealed file is complete and durable."""
        if self.segment_bytes is None or self._writer is None:
            return
        w = self._writer
        if w.size < self.segment_bytes or w.first_seqno_in_file is None:
            return
        first, nxt, epoch = w.first_seqno_in_file, w.next_seqno, w.epoch
        w.close()
        sealed = self.dir / f"wal_{first}.log"
        os.rename(self.wal_path, sealed)
        self._sealed_bytes += os.path.getsize(sealed)
        self.counters["wal_rolls"] += 1
        nw = WalWriter(self.wal_path, min_next_seqno=nxt)
        nw.epoch = epoch
        self._writer = nw

    def prune(self, upto_seqno: int) -> int:
        """Delete every sealed segment whose records all have ``seqno <=
        upto_seqno`` (the active tail is never touched). The caller owns
        the watermark discipline: a standalone engine passes
        `prune_floor` (its newest snapshot's seqno), a replication
        leader additionally floors it at the minimum follower ack so a
        bootstrap of any attached follower still finds its tail.
        Returns the number of segments deleted."""
        segs = list_segments(self.dir)
        if not segs:
            return 0
        # a sealed segment's last seqno = the next chain file's first - 1
        # (the chain is gapless); the final sealed segment is bounded by
        # the active tail's first record, or decoded directly if the
        # active tail is still empty
        firsts = [f for f, _ in segs]
        active_first = (self._writer.first_seqno_in_file
                        if self._writer is not None
                        else _first_seqno(self.wal_path))
        bounds = firsts[1:] + [active_first]
        n = 0
        for (first, p), nxt_first in zip(segs, bounds):
            if nxt_first is not None:
                last = nxt_first - 1
            else:
                recs, _ = read_wal(p)
                last = recs[-1].seqno if recs else None
            if last is None or last > upto_seqno:
                break
            sz = os.path.getsize(p)
            os.remove(p)
            self._sealed_bytes -= sz
            self.counters["wal_pruned_bytes"] += sz
            self.counters["wal_pruned_segments"] += 1
            n += 1
        return n

    def prune_floor(self) -> int:
        """Highest seqno local recovery no longer needs from the WAL:
        the newest snapshot's watermark (-1 when no snapshot exists —
        then nothing may be pruned)."""
        snaps = list_snapshots(self.dir)
        return snaps[-1][0] if snaps else -1

    def read_records(self) -> List[WalRecord]:
        """Decode the retained record stream — the whole segment chain,
        sealed files then the active tail — without opening a writer
        (pure read: a torn tail is ignored here, truncated only when a
        writer attaches)."""
        return read_wal_chain(self.dir)[0]

    # -- snapshots ----------------------------------------------------------
    @property
    def log_bytes(self) -> int:
        """Monotone bytes ever logged through this directory's WAL
        stream (active + sealed + already-pruned) — the growth measure
        `should_snapshot` compares, immune to rolls and prunes shrinking
        the on-disk footprint."""
        w_size = self._writer.size if self._writer else (
            os.path.getsize(self.wal_path) if self.wal_path.exists() else 0)
        return (self._sealed_bytes + int(self.counters["wal_pruned_bytes"])
                + w_size)

    def should_snapshot(self) -> bool:
        """Has the WAL grown `snapshot_every_bytes` past the last
        snapshot? (The governor's idle-gap trigger.) False until the
        writer exists — an engine that never logged has nothing to
        snapshot."""
        if self._writer is None:
            return False
        return (self.log_bytes
                - self._bytes_at_snapshot) >= self.snapshot_every_bytes

    def snapshot(self, drv) -> Path:
        """Serialize `drv`'s full device pytree as one atomic snapshot
        stamped with the current WAL seqno watermark (everything logged
        is synced first, so snapshot seqno S == "records <= S are fully
        reflected in these leaves"). Returns the published path."""
        t0 = time.perf_counter()
        self.sync()
        seqno = self.writer.last_seqno
        leaves = [np.asarray(x) for x in
                  jax.device_get(jax.tree_util.tree_leaves(drv.state))]
        meta = {"seqno": seqno, **drv._snapshot_meta()}
        path = write_snapshot(self.dir, seqno, leaves, meta,
                              keep_last=self.keep_snapshots)
        self._bytes_at_snapshot = self.log_bytes
        self.counters["snapshots"] += 1
        self.last_snapshot_ms = (time.perf_counter() - t0) * 1e3
        return path

    # -- telemetry / lifecycle ----------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Durability telemetry: WAL size/record/sync counters, snapshot
        count, last snapshot wall-time, bytes logged since the last
        snapshot (the `should_snapshot` residual), and the segmentation
        ledger (sealed segments on disk, rolls, pruned bytes/segments)."""
        active = self._writer.size if self._writer else (
            os.path.getsize(self.wal_path) if self.wal_path.exists() else 0)
        return {
            "wal_bytes": int(self._sealed_bytes + active),
            "wal_active_bytes": int(active),
            "wal_segments": len(list_segments(self.dir)),
            "wal_rolls": int(self.counters["wal_rolls"]),
            "wal_pruned_bytes": int(self.counters["wal_pruned_bytes"]),
            "wal_pruned_segments": int(self.counters["wal_pruned_segments"]),
            "wal_records": int(self._writer.records if self._writer else 0),
            "wal_syncs": int(self._writer.syncs if self._writer else 0),
            "replica": bool(self.replica),
            "snapshots": int(self.counters["snapshots"]),
            "snapshot_ms_last": float(self.last_snapshot_ms),
            "bytes_since_snapshot": int(max(0, self.log_bytes
                                            - self._bytes_at_snapshot)),
        }

    def close(self) -> None:
        """Flush and release the WAL file handle (idempotent)."""
        if self._writer is not None:
            self._writer.close()
            self._writer = None


def as_durability(spec) -> Optional[Durability]:
    """Driver-constructor coercion: None passes through, a `Durability`
    passes through, a path becomes ``Durability(path)`` with defaults."""
    if spec is None or isinstance(spec, Durability):
        return spec
    return Durability(spec)
