"""Batching policy: the pad/bucket grid every batched entry point shares.

Every batched op in the engine — `lookup_many`, `range_many`, the
staged insert chunks, and the serving layer's coalesced windows
(repro.serve) — compiles one program per padded lane width, so the
set of widths in circulation IS the compile-cache footprint. This
module is the single home for that policy:

  * `bucket_pow2`      — the generic power-of-two lane grid (O(log Q)
                         programs for arbitrary Q);
  * `ADAPTIVE_BUCKETS` — the coarse lookup grid adaptive engines use so
                         `warm()` can precompile every (preset x
                         structure x bucket) combination;
  * `RANGE_BUCKETS`    — the scan-count grid (coarse: each batched scan
                         program's width axis is the candidate buffer);
  * the pad helpers (`pad_to`, `pad_pow2`) that realize a bucket as a
    KEY_EMPTY-padded lane array;
  * `range_many_host`  — the shared pad/dispatch/trim driver for the
    batched range entry points of both engines.

Until PR 6 these lived as underscore-privates in `engine.py` and were
imported across modules (`sharded.py`) — promoting them makes the grid
a public contract the serving layer can warm against.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.params import KEY_EMPTY


def bucket_pow2(n: int, floor: int = 16) -> int:
    """Round a query count up to the next power-of-two bucket (>= floor).
    The one bucketing policy for every batched-lookup entry point: padded
    lane counts hit O(log Q) compiled programs instead of one per Q."""
    return max(floor, 1 << (n - 1).bit_length())


# adaptive engines quantize batched-lookup lanes to this coarse bucket
# set: every preset allocation is its own static-param read program, so
# the bucket set must stay small enough for warm() to precompile the
# whole (preset x structure x bucket) grid — a retune must never leave
# an unwarmed shape for a timed read to trip over
ADAPTIVE_BUCKETS = (256, 1024, 4096)

# batched range scans quantize to this bucket grid (every engine — the
# scan program's width axis is the candidate buffer, so the lane count
# stays coarse); warm() precompiles the whole grid per allocation
RANGE_BUCKETS = (8, 32)

# mixed-op tapes (repro.engine.tape) quantize their slot count to this
# grid: one lax.scan program per (params x structure x slot bucket), NOP
# slots padding the tail — the serving layer's window sizes all land on
# a handful of precompiled interpreters (SLSM.warm_tape)
TAPE_BUCKETS = (4, 16, 64)


def pad_to(qs: np.ndarray, width: int) -> np.ndarray:
    """Pad a query vector with KEY_EMPTY to `width` lanes."""
    out = np.full(width, KEY_EMPTY, np.int32)
    out[:len(qs)] = qs
    return out


def pad_pow2(qs: np.ndarray) -> np.ndarray:
    """Pad a query vector with KEY_EMPTY to its `bucket_pow2` width, so
    repeated mixed-size batches hit O(log Q) compiled programs."""
    return pad_to(qs, bucket_pow2(len(qs)))


def adaptive_bucket(n: int) -> int:
    """Smallest warmed adaptive bucket holding n lanes (pow2 past the
    largest, for callers exceeding the warmed grid)."""
    for b in ADAPTIVE_BUCKETS:
        if n <= b:
            return b
    return bucket_pow2(n)


def range_bucket(n: int) -> int:
    """Smallest warmed scan-count bucket holding n lanes (pow2 past the
    largest, for callers exceeding the warmed grid)."""
    for b in RANGE_BUCKETS:
        if n <= b:
            return b
    return bucket_pow2(n)


def tape_bucket(n: int) -> int:
    """Smallest warmed tape-slot bucket holding n slots (pow2 past the
    largest, for callers exceeding the warmed grid)."""
    for b in TAPE_BUCKETS:
        if n <= b:
            return b
    return bucket_pow2(n)


def range_many_host(dispatch, max_range: int, ranges):
    """Shared `range_many` driver for both engines: pad the scan list to
    the `RANGE_BUCKETS` grid, run the engine's jitted batched program
    ``dispatch(los, his, n_valid)``, trim back to the Q requested rows.
    One implementation so the bucket grid, padding dtype, and empty-batch
    contract cannot diverge between drivers."""
    r = np.asarray(ranges, np.int32).reshape(-1, 2)
    q = r.shape[0]
    if q == 0:
        return (np.zeros((0, max_range), np.int32),
                np.zeros((0, max_range), np.int32),
                np.zeros(0, np.int32), np.zeros(0, bool))
    width = range_bucket(q)
    los = np.zeros(width, np.int32)
    his = np.zeros(width, np.int32)
    los[:q], his[:q] = r[:, 0], r[:, 1]
    k, v, c, trunc = dispatch(jnp.asarray(los), jnp.asarray(his),
                              jnp.int32(q))
    return (np.asarray(k)[:q], np.asarray(v)[:q],
            np.asarray(c)[:q], np.asarray(trunc)[:q])
