"""Host-side driver — the paper's insert/merge control flow (Algorithm 2).

`SLSM` owns the state pytree; *when* maintenance work happens is the
`repro.engine.scheduler.MergeScheduler`'s decision: with
`SLSMParams.merge_budget == 0` (default) the whole Do-Merge cascade runs
synchronously inside the insert chunk that triggers it (the paper's
behaviour, and the write-stall pathology that comes with it); with a
positive budget the cascade is paced one bounded step per chunk and
`drain()` is the completion barrier. Every data-touching op is a jitted
device computation dispatched through the ops backend selected by
`SLSMParams.backend`.
"""
from __future__ import annotations

import collections
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import KEY_EMPTY, SLSMParams
from repro.engine import tape as TP
from repro.engine import wal as WAL
from repro.engine.backend import get_backend
from repro.engine.batching import (ADAPTIVE_BUCKETS, RANGE_BUCKETS,
                                   TAPE_BUCKETS, adaptive_bucket,
                                   bucket_pow2, pad_to, range_bucket,
                                   range_many_host)
from repro.engine.compaction import (CompactionPolicy, LevelingPolicy,
                                     TieringPolicy)
from repro.engine.memtable import init_state, stage_append
from repro.engine.read_path import (aggregate_many, level_probe_stats,
                                    lookup_batch, lookup_many, range_many,
                                    range_query)
from repro.engine.scheduler import MergeScheduler
from repro.engine.tuner import READ, ReadModePolicy, Tuner, retune_filters

# fixed width of the tuner's sampled probe-telemetry dispatch: one shape
# -> one compiled level_probe_stats program per (allocation, structure)
PROBE_SAMPLE = 256

# WAL/snapshot fingerprints name compaction policies by kind string so
# restore() can rebuild the configured policy without pickling it
_POLICY_KINDS = {"tiering": TieringPolicy, "leveling": LevelingPolicy}


def _policy_kind(policy: CompactionPolicy) -> str:
    """Fingerprint name of a configured compaction policy (the inverse
    of the `_POLICY_KINDS` lookup restore() performs)."""
    for name, cls in _POLICY_KINDS.items():
        if type(policy) is cls:
            return name
    return type(policy).__name__.lower()


def reject_reserved(keys: np.ndarray, vals: np.ndarray | None = None,
                    op: str = "insert") -> None:
    """Reserved-sentinel guard at the public API boundary.

    KEY_EMPTY (INT32_MAX) is the engine's padding/empty-slot key;
    letting it in from user data would alias padding (silently dropped
    keys), and a lookup of KEY_EMPTY can false-positive against empty
    stage slots. Values are unrestricted: deletes are carried by the
    record's weight lane (DESIGN.md §13), not a reserved value, so
    every int32 — including the historical TOMBSTONE bit pattern — is a
    legal payload. Both drivers call this before touching device state.
    """
    del vals  # no reserved values under the weighted record algebra
    if keys.size and (keys == KEY_EMPTY).any():
        raise ValueError(
            f"{op}: key {int(KEY_EMPTY)} (KEY_EMPTY/INT32_MAX) is reserved "
            "as the engine's empty-slot sentinel and cannot be stored or "
            "queried")


class SLSM:
    """Host-side driver: owns the state pytree; the merge scheduler owns
    the maintenance schedule.

    `insert`/`delete`/`lookup`/`range` match the paper's API. The merge
    cascade (Do-Merge) is decomposed into bounded steps (scheduler.py):
    recursion depth and level occupancy are host decisions; every
    data-touching op is a jitted device computation.
    """

    def __init__(self, params: SLSMParams | None = None,
                 policy: CompactionPolicy | None = None,
                 durability=None):
        self.p = params or SLSMParams()
        get_backend(self.p.backend)  # fail fast on unknown backends
        self.policy = policy or TieringPolicy()
        self.policy.validate(self.p)
        self.state = init_state(self.p)
        # p_active = the tuner's current allocation applied to p (same
        # physical geometry, possibly different effective filter/buffer/
        # fence view); == p forever under static tuning (DESIGN.md §9)
        self.p_active = self.p
        self.tuner = Tuner(self)
        self._read_policy = ReadModePolicy()
        self.scheduler = MergeScheduler(self)
        # maintenance counters (the bench runner's merge-count trajectory);
        # backlog_peak = most pending merge steps ever observed at a chunk
        # boundary (0 in synchronous mode only if no step was ever
        # deferred); reads/writes feed the tuner's workload-mix signal
        self.stats = collections.Counter(seals=0, flushes=0, spills=0,
                                         compactions=0, backlog_peak=0,
                                         retunes=0, reads=0, writes=0,
                                         rows_merged_in=0, rows_merged_out=0,
                                         rows_annihilated=0,
                                         ghost_payload_bytes_skipped=0)
        # durability surface (DESIGN.md §12): None (default) = volatile
        # engine, a path or wal.Durability = WAL every write op +
        # snapshot on demand; _replaying suppresses re-logging while
        # restore() replays the WAL tail through this same write path
        self._replaying = False
        self.durability = WAL.as_durability(durability)
        if self.durability is not None:
            self.durability.ensure_header(self._wal_meta())
        # replication hook (DESIGN.md §14): a replication.Leader /
        # .Follower claims this; repro.serve pumps it between windows.
        # fenced (DESIGN.md §15) = a deposed leader: writes raise until
        # a future promote() — the guard that keeps a partitioned old
        # leader from diverging from the cluster
        self.replication = None
        self.fenced = False

    # -- write path -------------------------------------------------------
    def _guard_writes(self) -> None:
        """Reject writes into a read-only engine: a fenced (deposed)
        leader or a replica follower (DESIGN.md §15). Replay and
        `apply_replicated` bypass this via ``_replaying``."""
        if self._replaying:
            return
        if self.fenced:
            raise RuntimeError(
                "write rejected: this engine was fenced (deposed leader) "
                "— demote() happened; rejoin via the new leader's "
                "bootstrap or promote() to lead again")
        if self.durability is not None and self.durability.replica:
            raise RuntimeError(
                "write rejected: replica engines are read-only until "
                "promote()")

    def insert(self, keys, vals) -> None:
        """Batched insert (paper Algorithm 1/2): stage in Rn-sized chunks;
        after each chunk the scheduler runs up to `merge_budget` voluntary
        merge steps plus whatever the next chunk structurally forces
        (everything, when merge_budget == 0 — the legacy synchronous
        cascade)."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        vals = np.asarray(vals, np.int32).reshape(-1)
        assert keys.shape == vals.shape
        reject_reserved(keys, vals, op="insert")
        self._insert(keys, vals, np.ones_like(keys))

    def _insert(self, keys: np.ndarray, vals: np.ndarray,
                wts: np.ndarray) -> None:
        """Post-validation weighted write path (delete() enters here with
        weight -1 records). With durability on, the whole op is logged as
        one WAL record before any device state changes and
        group-committed before returning (one fsync per driver call, not
        per chunk — DESIGN.md §12)."""
        if len(keys) > 0:
            self._guard_writes()
        log = (self.durability is not None and not self._replaying
               and len(keys) > 0)
        if log:
            self.durability.log_write(keys, vals, wts)
        self.stats["writes"] += len(keys)
        self.tuner.note_writes(len(keys))
        rn = self.p.Rn
        for off in range(0, len(keys), rn):
            ck, cv = keys[off:off + rn], vals[off:off + rn]
            cw = wts[off:off + rn]
            n = len(ck)
            if n < rn:
                ck = np.pad(ck, (0, rn - n), constant_values=KEY_EMPTY)
                cv = np.pad(cv, (0, rn - n))
                cw = np.pad(cw, (0, rn - n))
            self.state = stage_append(self.p_active, self.state,
                                      jnp.asarray(ck), jnp.asarray(cv),
                                      jnp.asarray(cw), jnp.int32(n))
            self.scheduler.on_chunk()
        if log:
            self.durability.sync()

    def delete(self, keys) -> None:
        """Deletes are weight -1 records (paper 2.8 tombstones, recast as
        the Z-set retraction — DESIGN.md §13); a key's presence is the
        sign of its newest record's weight, and the pair physically
        vanishes (annihilates) when a merge creates the deepest data
        (paper 2.5)."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        reject_reserved(keys, op="delete")
        self._insert(keys, np.zeros_like(keys), np.full_like(keys, -1))

    def drain(self) -> None:
        """Merge barrier: retire every pending maintenance step. After
        drain, a budgeted engine answers lookups/ranges identically to a
        synchronous one fed the same ops (reads are exact *without*
        draining too — pending-merge runs stay visible until their step
        retires them; drain only completes the deferred work)."""
        self.scheduler.drain()

    def warm(self, buckets: tuple = ADAPTIVE_BUCKETS) -> None:
        """Precompile the engine's full maintenance program set, so no
        insert chunk ever pays a first-use jit compile (the other — and
        at bench scale dominant — write-stall source besides cascade
        work; see MergeScheduler.warm). Optional; call before
        latency-sensitive serving.

        Also precompile the *read* programs (batched lookup per `bucket`,
        the single-key shape, the range-scan grid — `RANGE_BUCKETS`
        batched widths plus the single-scan program) for every
        levels-structure the engine can grow into, so mid-stream level
        materialization never drops a compile into a live lookup or
        scan. With adaptive tuning the grid spans every preset
        allocation — a retune swaps jit-static params, and without this
        the first read after a switch would pay the compile the pacing
        budget cannot flatten — plus the probe-telemetry pass."""
        self.scheduler.warm()
        if self.tuner.enabled:
            param_sets = [alloc.apply(self.p)
                          for alloc in self.tuner.presets.values()]
        else:
            param_sets = [self.p]
        skip = self.tuner.enabled
        outs = []
        for pa in param_sets:
            for n_levels in range(self.p.max_levels + 1):
                st = init_state(pa, n_levels)
                for b in buckets:
                    qs = jnp.zeros((b,), jnp.int32)
                    outs.append(lookup_many(pa, st, qs, jnp.int32(0),
                                            False, skip))
                outs.append(lookup_batch(pa, st, jnp.zeros((1,), jnp.int32),
                                         False, skip))
                for b in RANGE_BUCKETS:
                    z = jnp.zeros((b,), jnp.int32)
                    outs.append(range_many(pa, st, z, z, jnp.int32(0)))
                outs.append(range_query(pa, st, jnp.int32(0), jnp.int32(0)))
                if skip:
                    outs.append(level_probe_stats(
                        pa, st, jnp.zeros((PROBE_SAMPLE,), jnp.int32)))
        jax.block_until_ready(outs)

    # -- read path ----------------------------------------------------------
    def _on_reads(self, qs: np.ndarray) -> None:
        """Feed the tuner's workload signal: count the reads, stash the
        batch for write-boundary probe telemetry, and roll the
        controller (scheduler.on_read — decision-only; retunes and
        merges bind at the next write chunk or at drain(), so a lookup
        never absorbs maintenance work). Inert under static tuning."""
        self.stats["reads"] += qs.size
        t = self.tuner
        if not t.enabled:
            return
        t.note_reads(qs.size)
        t.last_queries = qs[:PROBE_SAMPLE].copy()
        self.scheduler.on_read()

    def lookup(self, keys, sparse: bool = False):
        """Point lookups (paper 2.7): newest-to-oldest across stage, memory
        runs, then Bloom/fence-gated disk levels. Compiles one program per
        distinct query-array shape — prefer `lookup_many` for mixed sizes."""
        qs_np = np.asarray(keys, np.int32).reshape(-1)
        reject_reserved(qs_np, op="lookup")
        self._on_reads(qs_np)
        qs = jnp.asarray(qs_np)
        vals, found = lookup_batch(self.p_active, self.state, qs, sparse,
                                   self.tuner.enabled)
        return np.asarray(vals), np.asarray(found)

    def lookup_many(self, keys, sparse: bool = False):
        """Batched multi-key fast path: all Q lookups in ONE device
        dispatch — a single fused Bloom-probe + fence-search pass per
        structure (paper 2.3/2.4) instead of one dispatch per query.
        Queries are padded to a power-of-two bucket so arbitrary Q reuses
        O(log Q) compiled programs. Same results as `lookup`."""
        qs = np.asarray(keys, np.int32).reshape(-1)
        reject_reserved(qs, op="lookup_many")
        if qs.size == 0:
            return np.zeros(0, np.int32), np.zeros(0, bool)
        self._on_reads(qs)
        width = (adaptive_bucket(qs.size) if self.tuner.enabled
                 else bucket_pow2(qs.size))
        vals, found = lookup_many(self.p_active, self.state,
                                  jnp.asarray(pad_to(qs, width)),
                                  jnp.int32(qs.size), sparse,
                                  self.tuner.enabled)
        return np.asarray(vals)[:qs.size], np.asarray(found)[:qs.size]

    def range_device(self, lo: int, hi: int):
        """Device-resident range query [lo, hi) (paper 2.9): one jitted
        dispatch of the fence-pruned scan engine (DESIGN.md §10), no
        host round-trip. Returns jax arrays ``(keys (max_range,), vals,
        count, truncated)`` — rows KEY_EMPTY-padded past ``count`` —
        so latency-sensitive callers (the bench runner, `range_many`
        consumers) can chain or batch transfers instead of paying a
        per-scan sync."""
        return range_query(self.p_active, self.state, jnp.int32(lo),
                           jnp.int32(hi))

    def range(self, lo: int, hi: int, return_truncated: bool = False):
        """Range query [lo, hi) (paper 2.9): newest-wins, deleted keys
        (negative newest weight) dropped, key-sorted; truncated at
        `max_range` results. With
        `return_truncated`, also returns whether the result is only a
        prefix of the window (more than max_range live keys, or a
        `range_cand` budget overflow — the result is exact iff False).
        Convenience trim of `range_device` (this is where the one host
        sync happens)."""
        k, v, c, trunc = self.range_device(lo, hi)
        c = int(c)
        out = np.asarray(k)[:c], np.asarray(v)[:c]
        return out + (bool(trunc),) if return_truncated else out

    def range_many(self, ranges):
        """Batched multi-scan fast path: all Q scans ``[(lo, hi), ...)``
        in ONE device dispatch of the fence-pruned scan engine — shared
        candidate gather, one fused merge-dedup pass (DESIGN.md §10) —
        instead of one dispatch (and one host sync) per scan. Scan
        counts are padded to the `RANGE_BUCKETS` grid so mixed batch
        sizes reuse a handful of compiled programs, mirroring
        `lookup_many`.

        Returns ``(keys (Q, max_range), vals, counts (Q,),
        truncated (Q,))`` as numpy arrays; row i holds ``counts[i]``
        key-sorted live pairs for window i (see `range` for the
        truncated-flag contract)."""
        return range_many_host(
            lambda los, his, n: range_many(self.p_active, self.state,
                                           los, his, n),
            self.p.max_range, ranges)

    def aggregate_many(self, ranges):
        """Batched windowed aggregates: ``count(lo, hi)`` and
        ``sum(lo, hi)`` over the live keys of each window ``[(lo, hi),
        ...)`` in ONE device dispatch (DESIGN.md §13). Rides the same
        fence-pruned candidate gather as `range_many` but reduces the
        merged survivor mask on-device instead of materializing rows, so
        a window's aggregate is exact past `max_range` — only a
        `range_cand` candidate-budget overflow (reported per-row in
        `truncated`) can clip it.

        Returns ``(counts (Q,), sums (Q,), truncated (Q,))`` as numpy
        arrays; sums use the engine's int32 wraparound arithmetic."""
        r = np.asarray(ranges, np.int32).reshape(-1, 2)
        q = r.shape[0]
        if q == 0:
            return (np.zeros(0, np.int32), np.zeros(0, np.int32),
                    np.zeros(0, bool))
        width = range_bucket(q)
        los = np.zeros(width, np.int32)
        his = np.zeros(width, np.int32)
        los[:q], his[:q] = r[:, 0], r[:, 1]
        c, s, t = aggregate_many(self.p_active, self.state,
                                 jnp.asarray(los), jnp.asarray(his),
                                 jnp.int32(q))
        return np.asarray(c)[:q], np.asarray(s)[:q], np.asarray(t)[:q]

    def count(self, lo: int, hi: int) -> int:
        """Live-key count over [lo, hi) (exact; one-window
        `aggregate_many`)."""
        c, _, _ = self.aggregate_many([(lo, hi)])
        return int(c[0])

    def sum(self, lo: int, hi: int) -> int:
        """Sum of live values over [lo, hi) (int32 wraparound; one-window
        `aggregate_many`)."""
        _, s, _ = self.aggregate_many([(lo, hi)])
        return int(s[0])

    # -- mixed-op tape (repro.engine.tape, DESIGN.md §11) -------------------
    def tape_write_capacity(self) -> int:
        """Max write keys the next `run_tape` call may carry, under the
        current occupancy: its headroom pass must be able to reserve one
        free run slot per in-scan seal the writes can force
        (`tape.tape_seal_bound`), and flushing can only push `run_count`
        down to ``run_count % runs_merged_eff``. Serving layers split
        windows that exceed this into multiple tapes."""
        p = self.p_active
        rc, sc = int(self.state.run_count), int(self.state.stage_count)
        # mirror ensure_stage_space(): pre-existing full stage seals first
        while sc >= p.Rn:
            if rc >= p.R:
                rc -= p.runs_merged_eff
            rc += 1
            sc -= p.Rn
        free = p.R - rc % p.runs_merged_eff
        return (free + 1) * p.Rn - 1 - sc

    def run_tape(self, chunks, sparse: bool = False):
        """Execute a coalesced mixed-op window as ONE device dispatch.

        `chunks` is a stream-ordered sequence of `tape.TapeChunk`s (or
        ``(kind, keys, vals)`` tuples): ``write`` chunks stage weighted
        records — `wts` lanes of +1 (insert) or -1 (delete), all +1 when
        omitted — ``lookup`` chunks carry point queries, ``range``
        chunks carry (lo, hi) window bounds. The
        whole window lowers to one `lax.scan` over tagged slots
        (`tape.tape_exec`), so a mixed stream pays one host->device
        launch and one device->host sync instead of one per op — the
        serving layer's steady-state data plane (DESIGN.md §11).

        Results are per-chunk, in order: writes -> in-scan seal count,
        lookups -> ``(vals, found)``, ranges -> ``(keys, vals, counts,
        truncated)`` — numpy, trimmed to each chunk's op count, and
        identical to what the per-op driver calls would have returned
        (same `_impl` ops in the same stream order; maintenance timing
        never changes read results — DESIGN.md §8).

        Headroom precondition (handled here, before each dispatch): the
        staging buffer absorbs every write slot and a free run slot
        exists for every seal the tape can trigger
        (`scheduler.ensure_stage_space` / `reserve_run_slots`). Windows
        whose writes exceed `tape_write_capacity` are segmented into
        multiple tapes at write boundaries (splitting a write chunk is
        stream-order-neutral), so steady-state serving usually stays at
        one dispatch per window and never fails on a heavy one.
        Flush/spill/compact/retune stay host steps *between* tapes (the
        maintenance governor's job), never inside one.
        """
        chunks = [c if isinstance(c, TP.TapeChunk) else TP.TapeChunk(*c)
                  for c in chunks]
        if not chunks:
            return []
        n_writes = n_reads = 0
        last_reads = None
        for ch in chunks:
            k = np.asarray(ch.keys, np.int32).reshape(-1)
            if ch.kind == "write":
                reject_reserved(k, op="tape write")
                n_writes += k.size
            elif ch.kind == "lookup":
                reject_reserved(k, op="tape lookup")
                n_reads += k.size
                last_reads = k
            elif ch.kind != "range":
                raise ValueError(f"unknown tape chunk kind {ch.kind!r}")
        if n_writes:
            self._guard_writes()
        # durability: one WAL record per write chunk (stream order is
        # preserved; segmentation below never reorders writes), group-
        # committed before this call returns — the serving layer stamps
        # replies only after run_tape returns, so every acked window is
        # durable (log-before-ack, DESIGN.md §12)
        log = self.durability is not None and not self._replaying
        if log:
            for ch in chunks:
                if ch.kind == "write":
                    k = np.asarray(ch.keys, np.int32).reshape(-1)
                    if k.size:
                        w = (np.ones_like(k) if ch.wts is None
                             else np.asarray(ch.wts, np.int32).reshape(-1))
                        self.durability.log_write(
                            k, np.asarray(ch.vals, np.int32).reshape(-1), w)
        results = [0] * len(chunks)
        # stream-ordered work list of (original chunk index, chunk);
        # oversized writes split across segments under the same index
        work = list(enumerate(chunks))
        while work:
            self.scheduler.ensure_stage_space()
            budget = self.tape_write_capacity()
            seg, seg_idx = [], []
            while work:
                i, ch = work[0]
                if ch.kind == "write":
                    k = np.asarray(ch.keys, np.int32).reshape(-1)
                    v = np.asarray(ch.vals, np.int32).reshape(-1)
                    w = (np.ones_like(k) if ch.wts is None
                         else np.asarray(ch.wts, np.int32).reshape(-1))
                    if budget <= 0:
                        break
                    if k.size > budget:
                        seg.append(TP.TapeChunk("write", k[:budget],
                                                v[:budget], w[:budget]))
                        seg_idx.append(i)
                        work[0] = (i, TP.TapeChunk("write", k[budget:],
                                                   v[budget:], w[budget:]))
                        budget = 0
                        continue
                    budget -= k.size
                seg.append(ch)
                seg_idx.append(i)
                work.pop(0)
            assert seg, "tape segmentation made no progress"
            seals = TP.tape_seal_bound(self.p_active,
                                       int(self.state.stage_count), seg)
            if seals:
                self.scheduler.reserve_run_slots(seals)
            ops, keys, vals, wts, nv = TP.build_tape(self.p_active, seg)
            self.state, ys = TP.tape_exec(
                self.p_active, self.state, jnp.asarray(ops),
                jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(wts),
                jnp.asarray(nv), sparse, self.tuner.enabled)
            for i, res in zip(seg_idx, TP.unpack_tape(self.p_active, seg, ys)):
                if chunks[i].kind == "write":
                    results[i] += res
                    self.stats["seals"] += res
                else:
                    results[i] = res
        self.stats["writes"] += n_writes
        self.stats["reads"] += n_reads
        if n_writes:
            self.tuner.note_writes(n_writes)
        if n_reads:
            self.tuner.note_reads(n_reads)
            if self.tuner.enabled and last_reads is not None:
                self.tuner.last_queries = last_reads[:PROBE_SAMPLE].copy()
        if log:
            self.durability.sync()
        return results

    def voluntary_steps(self, budget: int) -> int:
        """Roll the tuner's decision boundary, then run up to `budget`
        ready maintenance steps (scheduler.voluntary_steps; a decided
        RETUNE rides the backlog like any merge). The maintenance
        governor's uniform entry point (repro.serve) — identical
        signature on `ShardedSLSM` — for spending merge budget in idle
        gaps and at window boundaries instead of per insert chunk.
        Returns how many steps ran."""
        self.tuner.decide()
        return self.scheduler.voluntary_steps(budget)

    def warm_tape(self, buckets: tuple = TAPE_BUCKETS) -> None:
        """Precompile the mixed-op tape interpreter grid: one program
        per (allocation x levels-structure x slot bucket), like `warm`'s
        read grid — after this, steady-state serving windows never JIT
        (`run_tape` only ever dispatches these shapes). Call alongside
        `warm()` before latency-sensitive serving."""
        if self.tuner.enabled:
            param_sets = [alloc.apply(self.p)
                          for alloc in self.tuner.presets.values()]
        else:
            param_sets = [self.p]
        skip = self.tuner.enabled
        outs = []
        for pa in param_sets:
            for n_levels in range(self.p.max_levels + 1):
                for t in buckets:
                    st = init_state(pa, n_levels)
                    outs.append(TP.tape_exec(
                        pa, st, jnp.zeros((t,), jnp.int32),
                        jnp.full((t, pa.Rn), KEY_EMPTY, jnp.int32),
                        jnp.zeros((t, pa.Rn), jnp.int32),
                        jnp.zeros((t, pa.Rn), jnp.int32),
                        jnp.zeros((t,), jnp.int32), False, skip))
        jax.block_until_ready(outs)

    # -- tuner plumbing ----------------------------------------------------
    def sample_probe_stats(self) -> None:
        """Dispatch one per-level probe-telemetry pass over the most
        recent read batch (read_path.level_probe_stats). Called by the
        scheduler at write-chunk boundaries — alongside the maintenance
        work — so the instrumented dispatch never inflates a lookup's
        latency."""
        qs = self.tuner.last_queries
        if qs is None:
            return
        sample = np.full(PROBE_SAMPLE, KEY_EMPTY, np.int32)
        sample[:min(PROBE_SAMPLE, qs.size)] = qs[:PROBE_SAMPLE]
        c, h = level_probe_stats(self.p_active, self.state,
                                 jnp.asarray(sample))
        self.tuner.note_probe_stats(c, h)

    @property
    def policy_active(self):
        """Compaction policy under the current allocation: the configured
        policy, or the eager `ReadModePolicy` while the read-optimized
        allocation is active (fold structure down so the occupancy-masked
        read path probes less — DESIGN.md §9)."""
        if self.tuner.enabled and self.tuner.active == READ:
            return self._read_policy
        return self.policy

    def apply_retune(self) -> None:
        """The device half of a scheduler RETUNE step: swap the active
        parameter set to the tuner's target allocation and rebuild every
        resident Bloom filter under it in one jitted dispatch
        (tuner.retune_filters). Runs written afterwards pick up the new
        geometry at their own construction (levels.index_new_run). With
        durability on, the applied switch is WAL-logged and synced so a
        restored engine carries the same allocation trajectory (retunes
        are answer-invariant, so losing an unsynced one is harmless —
        DESIGN.md §9/§12)."""
        if self.durability is not None and not self._replaying:
            self.durability.log_retune(self.tuner.target)
        alloc = self.tuner.allocation(self.tuner.target)
        self.p_active = alloc.apply(self.p)
        self.state = retune_filters(self.p_active, self.state)
        self.tuner.applied()
        if self.durability is not None and not self._replaying:
            self.durability.sync()

    # -- durability (repro.engine.wal, DESIGN.md §12) -----------------------
    def _wal_meta(self) -> dict:
        """Engine fingerprint for the WAL's META record: enough to
        rebuild — and refuse to mix up — this engine configuration."""
        return {"driver": "slsm", "params": WAL.params_to_dict(self.p),
                "policy": _policy_kind(self.policy),
                "wal": WAL.WAL_FORMAT}

    def _snapshot_meta(self) -> dict:
        """Host-side state that rides a snapshot beside the pytree
        leaves: the engine fingerprint, the levels-structure depth the
        leaves were captured at, the tuner's controller position, and
        the stats counters at the watermark (replaying the WAL tail
        re-counts the rest, so restored totals match an uncrashed
        run)."""
        return {**self._wal_meta(), "n_levels": self.n_levels,
                "tuner": {"active": self.tuner.active,
                          "read_frac": float(self.tuner.read_frac)},
                "stats": {k: int(v) for k, v in self.stats.items()}}

    def snapshot(self):
        """Serialize the full device pytree (stage + runs + levels +
        filters, under the current allocation) as one atomic snapshot
        stamped with the WAL seqno watermark; restore() then only
        replays records past it. Returns the published directory.
        Requires a durability layer (the Governor triggers this in idle
        gaps — repro.serve)."""
        if self.durability is None:
            raise ValueError("snapshot() requires a durability layer: "
                             "construct with SLSM(..., durability=path)")
        return self.durability.snapshot(self)

    def _adopt_snapshot(self, leaves, meta: dict) -> None:
        """Install snapshot `leaves` as the live state pytree and adopt
        the host-side controller/stats position captured in `meta`.
        The physical geometry is params-determined (filters are sized at
        eps_floor — DESIGN.md §9), so a template built from the same
        params always matches the leaves' shapes."""
        template = init_state(self.p, int(meta["n_levels"]))
        treedef = jax.tree_util.tree_structure(template)
        self.state = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(x) for x in leaves])
        for k, v in meta.get("stats", {}).items():
            self.stats[k] = int(v)
        t = meta.get("tuner")
        if t and self.tuner.enabled:
            name = t.get("active", self.tuner.active)
            self.tuner.active = self.tuner.target = name
            self.tuner.read_frac = float(t.get("read_frac",
                                               self.tuner.read_frac))
            self.p_active = self.tuner.allocation(name).apply(self.p)

    def _replay(self, records) -> None:
        """Re-apply a WAL tail through the existing chunk-apply programs
        (_insert / apply_retune) with re-logging suppressed. Replay is
        answer-exact, not bitwise-state-exact: maintenance may pace
        differently than the crashed run, but reads are exact at every
        point between merge steps (DESIGN.md §8), so every lookup/range
        afterwards matches an uncrashed engine fed the same records."""
        self._replaying = True
        try:
            n = 0
            for rec in records:
                if rec.kind in WAL.WRITE_KINDS:
                    k, v, w = WAL.decode_write(rec.payload, rec.kind)
                    self._insert(k, v, w)
                elif rec.kind == WAL.REC_RETUNE:
                    if self.tuner.enabled:
                        self.tuner.target = rec.payload.decode()
                        if self.tuner.pending:
                            self.apply_retune()
                            self.stats["retunes"] += 1
                else:
                    continue
                n += 1
            self.stats["replayed_records"] += n
        finally:
            self._replaying = False

    @classmethod
    def restore(cls, path, params: SLSMParams | None = None,
                policy: CompactionPolicy | None = None, durability=None):
        """Recover an engine from a durability directory: load the
        newest snapshot that passes verification (none is fine — replay
        then starts from genesis), replay every WAL record past its
        watermark, and return the live engine. A torn final WAL record
        is dropped cleanly (CRC framing rejects it as a unit — no
        partial apply). `params`/`policy` default to the fingerprint
        recorded in the snapshot/WAL META. Restore wall time and replay
        size are reported in ``stats()`` as ``restore_us`` /
        ``replayed_records``."""
        t0 = time.perf_counter()
        dur = WAL.as_durability(durability if durability is not None
                                else path)
        # decode the durable prefix BEFORE any writer truncates the tail
        records = dur.read_records()
        header = next((json.loads(r.payload.decode()) for r in records
                       if r.kind == WAL.REC_META), None)
        snap = WAL.load_latest_snapshot(dur.dir)
        meta = snap[2] if snap is not None else header
        if meta is None and params is None:
            raise ValueError(f"nothing to restore in {dur.dir}: no valid "
                             "snapshot and no readable WAL header")
        if params is None:
            params = WAL.params_from_dict(meta["params"])
        if policy is None and meta is not None:
            policy = _POLICY_KINDS.get(meta.get("policy", "tiering"),
                                       TieringPolicy)()
        drv = cls(params, policy, durability=dur)
        watermark = -1
        if snap is not None:
            num, leaves, smeta = snap
            drv._adopt_snapshot(leaves, smeta)
            watermark = num
        drv._replay([r for r in records if r.seqno > watermark])
        drv.stats["restore_us"] += int((time.perf_counter() - t0) * 1e6)
        return drv

    @classmethod
    def open_replica(cls, path, *, fsync: bool = False):
        """Open a replication follower over a bootstrapped directory
        (DESIGN.md §14): a plain `restore` of the leader's shipped
        snapshot + WAL tail, but with a *replica-mode* durability layer
        — the log is a verbatim copy of the leader's stream (extended
        only by ``Durability.append_frame``), so no local META record
        is ever injected into it. The returned engine is what
        `repro.engine.replication.Follower` drives."""
        return cls.restore(path, durability=WAL.Durability(
            path, fsync=fsync, replica=True))

    def apply_replicated(self, records) -> int:
        """Apply decoded leader WAL records through the same chunk-apply
        programs `restore` replays with (re-logging suppressed — the
        follower's durability layer appended the raw frames verbatim
        before this is called). Returns the records applied; the
        cumulative count rides ``stats['replayed_records']``."""
        before = self.stats["replayed_records"]
        self._replay(records)
        return self.stats["replayed_records"] - before

    def promote(self) -> "SLSM":
        """Failover: turn this replica into a writable leader. Bumps
        the WAL epoch (so stale pre-failover bytes the reused file may
        expose later are rejected by the prefix rule) and re-enables
        local logging; seqnos resume after the last applied record.
        Returns self. The transport-level half (dropping unacked
        buffered frames) lives in ``replication.Follower.promote``,
        which calls this."""
        if self.durability is None:
            raise ValueError("promote() requires a durability layer")
        self.durability.writer.bump_epoch()
        self.durability.replica = False
        self.fenced = False
        self.stats["promotions"] += 1
        return self

    def demote(self) -> "SLSM":
        """Fence this engine against writes (the deposed-leader exit,
        DESIGN.md §15): a leader that learned — via an ack at a higher
        epoch — that an automatic failover superseded it must stop
        accepting writes *immediately*, even mid-partition. Reads stay
        served (stale until rejoin); every write raises until a future
        `promote()`. Returns self."""
        self.fenced = True
        self.stats["demotions"] += 1
        return self

    # -- stats ----------------------------------------------------------------
    @property
    def n_live(self) -> int:
        """Resident elements across stage + memory runs + disk levels
        (duplicates and negative-weight delete records count until a
        merge annihilates them)."""
        n = int(self.state.stage_count) + int(self.state.buf_counts.sum())
        for lv in self.state.levels:
            n += int(lv.counts.sum())
        return n

    @property
    def n_levels(self) -> int:
        """Disk levels materialized so far (paper 2.4; grown lazily up to
        `max_levels`)."""
        return len(self.state.levels)
