"""Host-side driver — the paper's insert/merge control flow (Algorithm 2).

`SLSM` owns the state pytree and schedules seals and merges: recursion
depth, level occupancy, and the compaction policy (tiering vs leveling)
are host decisions; every data-touching op is a jitted device
computation dispatched through the ops backend selected by
`SLSMParams.backend`.
"""
from __future__ import annotations

import collections

import jax.numpy as jnp
import numpy as np

from repro.core.params import KEY_EMPTY, TOMBSTONE, SLSMParams
from repro.engine.backend import get_backend
from repro.engine.compaction import (CompactionPolicy, TieringPolicy,
                                     compact_last_level,
                                     merge_buffer_to_level0, merge_level_down)
from repro.engine.levels import empty_level
from repro.engine.memtable import init_state, seal_run, stage_append
from repro.engine.read_path import (bucket_pow2, lookup_batch, lookup_many,
                                    range_query)


def _pad_pow2(qs: np.ndarray) -> np.ndarray:
    """Pad a query vector with KEY_EMPTY to its `bucket_pow2` width, so
    repeated mixed-size batches hit O(log Q) compiled programs."""
    out = np.full(bucket_pow2(len(qs)), KEY_EMPTY, np.int32)
    out[:len(qs)] = qs
    return out


class SLSM:
    """Host-side driver: owns the state pytree, schedules seals and merges.

    `insert`/`delete`/`lookup`/`range` match the paper's API. The merge
    cascade (Do-Merge) runs here: recursion depth and level occupancy are
    host decisions; every data-touching op is a jitted device computation.
    """

    def __init__(self, params: SLSMParams | None = None,
                 policy: CompactionPolicy | None = None):
        self.p = params or SLSMParams()
        get_backend(self.p.backend)  # fail fast on unknown backends
        self.policy = policy or TieringPolicy()
        self.policy.validate(self.p)
        self.state = init_state(self.p)
        # maintenance counters (the bench runner's merge-count trajectory)
        self.stats = collections.Counter(seals=0, flushes=0, spills=0,
                                         compactions=0)

    # -- write path -------------------------------------------------------
    def insert(self, keys, vals) -> None:
        """Batched insert (paper Algorithm 1/2): stage in Rn-sized chunks,
        sealing the active run and cascading merges whenever it fills."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        vals = np.asarray(vals, np.int32).reshape(-1)
        assert keys.shape == vals.shape
        rn = self.p.Rn
        for off in range(0, len(keys), rn):
            ck, cv = keys[off:off + rn], vals[off:off + rn]
            n = len(ck)
            if n < rn:
                ck = np.pad(ck, (0, rn - n), constant_values=KEY_EMPTY)
                cv = np.pad(cv, (0, rn - n))
            self.state = stage_append(self.p, self.state, jnp.asarray(ck),
                                      jnp.asarray(cv), jnp.int32(n))
            while int(self.state.stage_count) >= rn:
                if int(self.state.run_count) == self.p.R:
                    self._flush_buffer()
                self.state = seal_run(self.p, self.state)
                self.stats["seals"] += 1

    def delete(self, keys) -> None:
        """Deletes are tombstone inserts (paper 2.8); they commit — i.e.
        the key-value pairs vanish — when a merge creates the deepest data
        (paper 2.5)."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        self.insert(keys, np.full_like(keys, TOMBSTONE))

    # -- merge cascade (Do-Merge) ------------------------------------------
    def _flush_buffer(self) -> None:
        self._ensure_space(0)
        self.state = merge_buffer_to_level0(self.p, self.state,
                                            self._drop_tombstones_into(0))
        self.stats["flushes"] += 1

    def _ensure_space(self, level: int) -> None:
        if level >= self.p.max_levels:
            raise RuntimeError(
                "sLSM capacity exceeded: increase max_levels "
                f"(currently {self.p.max_levels})")
        if level >= len(self.state.levels):
            self.state = self.state._replace(
                levels=self.state.levels + (empty_level(self.p, level),))
            return
        n_runs = int(self.state.levels[level].n_runs)
        if not self.policy.needs_spill(self.p, n_runs):
            return
        if level == self.p.max_levels - 1:
            new_state, raw = compact_last_level(self.p, self.state)
            cap = self.p.level_cap(level)
            if int(raw) > cap:
                raise RuntimeError(
                    f"sLSM deepest level overflow ({int(raw)} > {cap} "
                    f"live elements): increase max_levels beyond "
                    f"{self.p.max_levels}")
            self.state = new_state
            self.stats["compactions"] += 1
        else:
            self._ensure_space(level + 1)
            self.state = merge_level_down(
                self.p, self.state, level,
                self.policy.runs_to_spill(self.p, n_runs),
                self._drop_tombstones_into(level + 1))
            self.stats["spills"] += 1

    def _drop_tombstones_into(self, target_level: int) -> bool:
        """Deletes commit when the merge output becomes the deepest data."""
        for lv in self.state.levels[target_level:]:
            if int(lv.n_runs) > 0:
                return False
        return True

    # -- read path ----------------------------------------------------------
    def lookup(self, keys, sparse: bool = False):
        """Point lookups (paper 2.7): newest-to-oldest across stage, memory
        runs, then Bloom/fence-gated disk levels. Compiles one program per
        distinct query-array shape — prefer `lookup_many` for mixed sizes."""
        qs = jnp.asarray(np.asarray(keys, np.int32).reshape(-1))
        vals, found = lookup_batch(self.p, self.state, qs, sparse)
        return np.asarray(vals), np.asarray(found)

    def lookup_many(self, keys, sparse: bool = False):
        """Batched multi-key fast path: all Q lookups in ONE device
        dispatch — a single fused Bloom-probe + fence-search pass per
        structure (paper 2.3/2.4) instead of one dispatch per query.
        Queries are padded to a power-of-two bucket so arbitrary Q reuses
        O(log Q) compiled programs. Same results as `lookup`."""
        qs = np.asarray(keys, np.int32).reshape(-1)
        if qs.size == 0:
            return np.zeros(0, np.int32), np.zeros(0, bool)
        vals, found = lookup_many(self.p, self.state,
                                  jnp.asarray(_pad_pow2(qs)),
                                  jnp.int32(qs.size), sparse)
        return np.asarray(vals)[:qs.size], np.asarray(found)[:qs.size]

    def range(self, lo: int, hi: int):
        """Range query [lo, hi) (paper 2.9): newest-wins, tombstones
        dropped, key-sorted; truncated at `max_range` results."""
        k, v, c = range_query(self.p, self.state, jnp.int32(lo), jnp.int32(hi))
        c = int(c)
        return np.asarray(k)[:c], np.asarray(v)[:c]

    # -- stats ----------------------------------------------------------------
    @property
    def n_live(self) -> int:
        """Resident elements across stage + memory runs + disk levels
        (duplicates/tombstones count until a merge elides them)."""
        n = int(self.state.stage_count) + int(self.state.buf_counts.sum())
        for lv in self.state.levels:
            n += int(lv.counts.sum())
        return n

    @property
    def n_levels(self) -> int:
        """Disk levels materialized so far (paper 2.4; grown lazily up to
        `max_levels`)."""
        return len(self.state.levels)
