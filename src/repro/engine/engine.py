"""Host-side driver — the paper's insert/merge control flow (Algorithm 2).

`SLSM` owns the state pytree; *when* maintenance work happens is the
`repro.engine.scheduler.MergeScheduler`'s decision: with
`SLSMParams.merge_budget == 0` (default) the whole Do-Merge cascade runs
synchronously inside the insert chunk that triggers it (the paper's
behaviour, and the write-stall pathology that comes with it); with a
positive budget the cascade is paced one bounded step per chunk and
`drain()` is the completion barrier. Every data-touching op is a jitted
device computation dispatched through the ops backend selected by
`SLSMParams.backend`.
"""
from __future__ import annotations

import collections

import jax.numpy as jnp
import numpy as np

from repro.core.params import KEY_EMPTY, TOMBSTONE, SLSMParams
from repro.engine.backend import get_backend
from repro.engine.compaction import CompactionPolicy, TieringPolicy
from repro.engine.memtable import init_state, stage_append
from repro.engine.read_path import (bucket_pow2, lookup_batch, lookup_many,
                                    range_query)
from repro.engine.scheduler import MergeScheduler


def _pad_pow2(qs: np.ndarray) -> np.ndarray:
    """Pad a query vector with KEY_EMPTY to its `bucket_pow2` width, so
    repeated mixed-size batches hit O(log Q) compiled programs."""
    out = np.full(bucket_pow2(len(qs)), KEY_EMPTY, np.int32)
    out[:len(qs)] = qs
    return out


def reject_reserved(keys: np.ndarray, vals: np.ndarray | None = None,
                    op: str = "insert") -> None:
    """Reserved-sentinel guard at the public API boundary.

    KEY_EMPTY (INT32_MAX) is the engine's padding/empty-slot key and
    TOMBSTONE (INT32_MIN) its delete marker value; letting either in from
    user data would alias padding (silently dropped keys) or deletes
    (vanishing values), and a lookup of KEY_EMPTY can false-positive
    against empty stage slots. Both drivers call this before touching
    device state.
    """
    if keys.size and (keys == KEY_EMPTY).any():
        raise ValueError(
            f"{op}: key {int(KEY_EMPTY)} (KEY_EMPTY/INT32_MAX) is reserved "
            "as the engine's empty-slot sentinel and cannot be stored or "
            "queried")
    if vals is not None and vals.size and (vals == TOMBSTONE).any():
        raise ValueError(
            f"{op}: value {int(TOMBSTONE)} (TOMBSTONE/INT32_MIN) is "
            "reserved as the delete marker; storing it would make the key "
            "unreadable — use delete() instead")


class SLSM:
    """Host-side driver: owns the state pytree; the merge scheduler owns
    the maintenance schedule.

    `insert`/`delete`/`lookup`/`range` match the paper's API. The merge
    cascade (Do-Merge) is decomposed into bounded steps (scheduler.py):
    recursion depth and level occupancy are host decisions; every
    data-touching op is a jitted device computation.
    """

    def __init__(self, params: SLSMParams | None = None,
                 policy: CompactionPolicy | None = None):
        self.p = params or SLSMParams()
        get_backend(self.p.backend)  # fail fast on unknown backends
        self.policy = policy or TieringPolicy()
        self.policy.validate(self.p)
        self.state = init_state(self.p)
        self.scheduler = MergeScheduler(self)
        # maintenance counters (the bench runner's merge-count trajectory);
        # backlog_peak = most pending merge steps ever observed at a chunk
        # boundary (0 in synchronous mode only if no step was ever deferred)
        self.stats = collections.Counter(seals=0, flushes=0, spills=0,
                                         compactions=0, backlog_peak=0)

    # -- write path -------------------------------------------------------
    def insert(self, keys, vals) -> None:
        """Batched insert (paper Algorithm 1/2): stage in Rn-sized chunks;
        after each chunk the scheduler runs up to `merge_budget` voluntary
        merge steps plus whatever the next chunk structurally forces
        (everything, when merge_budget == 0 — the legacy synchronous
        cascade)."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        vals = np.asarray(vals, np.int32).reshape(-1)
        assert keys.shape == vals.shape
        reject_reserved(keys, vals, op="insert")
        self._insert(keys, vals)

    def _insert(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Post-validation write path (delete() enters here: its tombstone
        values are the engine's own, not user data)."""
        rn = self.p.Rn
        for off in range(0, len(keys), rn):
            ck, cv = keys[off:off + rn], vals[off:off + rn]
            n = len(ck)
            if n < rn:
                ck = np.pad(ck, (0, rn - n), constant_values=KEY_EMPTY)
                cv = np.pad(cv, (0, rn - n))
            self.state = stage_append(self.p, self.state, jnp.asarray(ck),
                                      jnp.asarray(cv), jnp.int32(n))
            self.scheduler.on_chunk()

    def delete(self, keys) -> None:
        """Deletes are tombstone inserts (paper 2.8); they commit — i.e.
        the key-value pairs vanish — when a merge creates the deepest data
        (paper 2.5)."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        reject_reserved(keys, op="delete")
        self._insert(keys, np.full_like(keys, TOMBSTONE))

    def drain(self) -> None:
        """Merge barrier: retire every pending maintenance step. After
        drain, a budgeted engine answers lookups/ranges identically to a
        synchronous one fed the same ops (reads are exact *without*
        draining too — pending-merge runs stay visible until their step
        retires them; drain only completes the deferred work)."""
        self.scheduler.drain()

    def warm(self) -> None:
        """Precompile the engine's full maintenance program set, so no
        insert chunk ever pays a first-use jit compile (the other — and
        at bench scale dominant — write-stall source besides cascade
        work; see MergeScheduler.warm). Optional; call before
        latency-sensitive serving."""
        self.scheduler.warm()

    # -- read path ----------------------------------------------------------
    def lookup(self, keys, sparse: bool = False):
        """Point lookups (paper 2.7): newest-to-oldest across stage, memory
        runs, then Bloom/fence-gated disk levels. Compiles one program per
        distinct query-array shape — prefer `lookup_many` for mixed sizes."""
        qs_np = np.asarray(keys, np.int32).reshape(-1)
        reject_reserved(qs_np, op="lookup")
        qs = jnp.asarray(qs_np)
        vals, found = lookup_batch(self.p, self.state, qs, sparse)
        return np.asarray(vals), np.asarray(found)

    def lookup_many(self, keys, sparse: bool = False):
        """Batched multi-key fast path: all Q lookups in ONE device
        dispatch — a single fused Bloom-probe + fence-search pass per
        structure (paper 2.3/2.4) instead of one dispatch per query.
        Queries are padded to a power-of-two bucket so arbitrary Q reuses
        O(log Q) compiled programs. Same results as `lookup`."""
        qs = np.asarray(keys, np.int32).reshape(-1)
        reject_reserved(qs, op="lookup_many")
        if qs.size == 0:
            return np.zeros(0, np.int32), np.zeros(0, bool)
        vals, found = lookup_many(self.p, self.state,
                                  jnp.asarray(_pad_pow2(qs)),
                                  jnp.int32(qs.size), sparse)
        return np.asarray(vals)[:qs.size], np.asarray(found)[:qs.size]

    def range(self, lo: int, hi: int, return_truncated: bool = False):
        """Range query [lo, hi) (paper 2.9): newest-wins, tombstones
        dropped, key-sorted; truncated at `max_range` results. With
        `return_truncated`, also returns whether the [lo, hi) window held
        more than max_range live keys (the result is exact iff False)."""
        k, v, c, trunc = range_query(self.p, self.state, jnp.int32(lo),
                                     jnp.int32(hi))
        c = int(c)
        out = np.asarray(k)[:c], np.asarray(v)[:c]
        return out + (bool(trunc),) if return_truncated else out

    # -- stats ----------------------------------------------------------------
    @property
    def n_live(self) -> int:
        """Resident elements across stage + memory runs + disk levels
        (duplicates/tombstones count until a merge elides them)."""
        n = int(self.state.stage_count) + int(self.state.buf_counts.sum())
        for lv in self.state.levels:
            n += int(lv.counts.sum())
        return n

    @property
    def n_levels(self) -> int:
        """Disk levels materialized so far (paper 2.4; grown lazily up to
        `max_levels`)."""
        return len(self.state.levels)
