"""Memory tier (paper 2.1-2.3): the staging buffer + sealed memory runs.

The staging buffer is the dense-array form of the paper's active
skiplist (DESIGN.md §2): the O(log Rn) ordered insert becomes a batched
sort of the 2*Rn staging region, and the paper's in-place update of
duplicate keys (3.9.1) is the newest-wins dedup. Sealing turns Rn staged
elements into an immutable sorted run with a Bloom filter and min/max
index — the moment the active skiplist becomes a memory run.

Records are weighted (DESIGN.md §13): every lane carries a weight (+1
insert, -1 delete) in its own SoA plane alongside keys/vals/seqs.

Every op here exists in two forms: `<name>_impl` (pure, vmappable —
the sharded engine maps them over the shard axis) and the jitted,
donating single-tree wrapper the `SLSM` driver calls.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import bloom as BL
from repro.core import runs as RU
from repro.core.params import KEY_EMPTY, SLSMParams
from repro.engine.levels import LevelState, empty_level

I32 = jnp.int32

# -inf key sentinel for "max key of an empty run"
_KEY_MIN = -(2 ** 31)


class SLSMState(NamedTuple):
    # staging buffer == the active run (kept key-sorted, newest-wins deduped)
    stage_keys: jax.Array   # (2*Rn,)
    stage_vals: jax.Array
    stage_wts: jax.Array    # (2*Rn,) record weights: +1 insert, -1 delete
    stage_seqs: jax.Array
    stage_count: jax.Array  # ()
    # sealed memory runs
    buf_keys: jax.Array     # (R, Rn)
    buf_vals: jax.Array
    buf_wts: jax.Array      # (R, Rn)
    buf_seqs: jax.Array
    buf_counts: jax.Array   # (R,)
    buf_mins: jax.Array     # (R,)
    buf_maxs: jax.Array     # (R,)
    buf_blooms: jax.Array   # (R, words_buf) uint32
    run_count: jax.Array    # ()
    next_seq: jax.Array     # () global write counter == recency order
    levels: Tuple[LevelState, ...]


def init_state(p: SLSMParams, n_levels: int = 0) -> SLSMState:
    """Fresh engine state. `n_levels` preallocates disk tiers eagerly —
    the single-tree driver grows them lazily (n_levels=0, the paper's
    unbounded growth up to max_levels); the sharded engine preallocates
    all of them so every shard shares one pytree structure."""
    wb = p.bloom_words_physical(p.Rn, p.mem_eps)
    return SLSMState(
        stage_keys=jnp.full((p.stage_cap,), KEY_EMPTY, I32),
        stage_vals=jnp.zeros((p.stage_cap,), I32),
        stage_wts=jnp.zeros((p.stage_cap,), I32),
        stage_seqs=jnp.zeros((p.stage_cap,), I32),
        stage_count=jnp.zeros((), I32),
        buf_keys=jnp.full((p.R, p.Rn), KEY_EMPTY, I32),
        buf_vals=jnp.zeros((p.R, p.Rn), I32),
        buf_wts=jnp.zeros((p.R, p.Rn), I32),
        buf_seqs=jnp.zeros((p.R, p.Rn), I32),
        buf_counts=jnp.zeros((p.R,), I32),
        buf_mins=jnp.full((p.R,), KEY_EMPTY, I32),
        buf_maxs=jnp.full((p.R,), _KEY_MIN, I32),
        buf_blooms=jnp.zeros((p.R, wb), jnp.uint32),
        run_count=jnp.zeros((), I32),
        next_seq=jnp.zeros((), I32),
        levels=tuple(empty_level(p, lvl) for lvl in range(n_levels)),
    )


# --------------------------------------------------------------------------
# insertion path (paper Algorithm 2, batched)
# --------------------------------------------------------------------------

def stage_append_impl(p: SLSMParams, state: SLSMState, keys: jax.Array,
                      vals: jax.Array, wts: jax.Array,
                      n_valid: jax.Array) -> SLSMState:
    """Append an Rn-sized chunk into the active run, then re-sort + dedup.

    The active skiplist's O(log Rn) ordered insert becomes a batched
    sort of the 2*Rn staging region; the paper's in-place update of
    duplicate keys (3.9.1) is the newest-wins dedup (each record
    retracts its predecessor, so keeping the newest IS the telescoped
    weight sum — DESIGN.md §13).
    """
    rn = p.Rn
    pos = jnp.arange(rn, dtype=I32)
    valid = pos < n_valid
    ck = jnp.where(valid, keys.astype(I32), KEY_EMPTY)
    cw = jnp.where(valid, wts.astype(I32), 0)
    # seqnos only on valid lanes: next_seq advances by n_valid, so stamping
    # padded lanes (pos >= n_valid) would collide with the NEXT chunk's
    # live seqnos — masked to 0, the same dead value compact() uses
    cs = jnp.where(valid, state.next_seq + pos, 0)
    sk = jax.lax.dynamic_update_slice(state.stage_keys, ck, (state.stage_count,))
    sv = jax.lax.dynamic_update_slice(state.stage_vals, vals.astype(I32),
                                      (state.stage_count,))
    sw = jax.lax.dynamic_update_slice(state.stage_wts, cw, (state.stage_count,))
    ss = jax.lax.dynamic_update_slice(state.stage_seqs, cs, (state.stage_count,))
    k, v, w, s = RU.sort_records(sk, sv, sw, ss)
    ok = RU.survivor_mask(k, w, drop_annihilated=False)
    k, v, w, s, cnt = RU.compact(k, v, w, s, ok)
    return state._replace(stage_keys=k, stage_vals=v, stage_wts=w,
                          stage_seqs=s, stage_count=cnt,
                          next_seq=state.next_seq + n_valid)


stage_append = functools.partial(jax.jit, static_argnums=0,
                                 donate_argnums=1)(stage_append_impl)


def seal_run_impl(p: SLSMParams, state: SLSMState) -> SLSMState:
    """Seal Rn staged elements into memory run slot `run_count`.

    Builds the run's Bloom filter and min/max index (paper 2.3) — the
    moment the active skiplist becomes an immutable sorted run.
    """
    rn = p.Rn
    bits, _, kk = p.bloom_geometry(rn, p.mem_eps)
    wb = p.bloom_words_physical(rn, p.mem_eps)
    rk, rv, rw, rs = (state.stage_keys[:rn], state.stage_vals[:rn],
                      state.stage_wts[:rn], state.stage_seqs[:rn])
    slot = state.run_count
    filt = BL.bloom_build(rk, jnp.ones((rn,), bool), wb, kk, bits)
    empty_tail = jnp.full((rn,), KEY_EMPTY, I32)
    return state._replace(
        stage_keys=jnp.concatenate([state.stage_keys[rn:], empty_tail]),
        stage_vals=jnp.concatenate([state.stage_vals[rn:], jnp.zeros_like(empty_tail)]),
        stage_wts=jnp.concatenate([state.stage_wts[rn:], jnp.zeros_like(empty_tail)]),
        stage_seqs=jnp.concatenate([state.stage_seqs[rn:], jnp.zeros_like(empty_tail)]),
        stage_count=state.stage_count - rn,
        buf_keys=state.buf_keys.at[slot].set(rk),
        buf_vals=state.buf_vals.at[slot].set(rv),
        buf_wts=state.buf_wts.at[slot].set(rw),
        buf_seqs=state.buf_seqs.at[slot].set(rs),
        buf_counts=state.buf_counts.at[slot].set(rn),
        buf_mins=state.buf_mins.at[slot].set(rk[0]),
        buf_maxs=state.buf_maxs.at[slot].set(rk[rn - 1]),
        buf_blooms=state.buf_blooms.at[slot].set(filt),
        run_count=state.run_count + 1,
    )


seal_run = functools.partial(jax.jit, static_argnums=0,
                             donate_argnums=1)(seal_run_impl)
