"""Ops-dispatch layer: every hot primitive resolves to one backend.

The paper's pitch is that the sLSM's logically-separate layers invite
"opportunistic and granular optimization". This module is the seam that
makes that concrete: the three data-plane primitives the read/compaction
paths are built from — Bloom probe, fence-pointer page search, k-way run
merge — are resolved through one `OpsBackend` record, selected by
`SLSMParams.backend`:

  jnp    — the pure-jnp reference implementations (vmapped over runs;
           XLA fuses them into the surrounding computation);
  pallas — the purpose-built TPU kernels in `repro.kernels`
           (`bloom_probe`, `fence_lookup`, `heap_merge`), which fall
           back to interpret mode off-TPU so the same code path is
           testable on CPU.

Both backends implement identical semantics (the kernels are oracle-
tested against the jnp forms in tests/test_kernels.py, and whole-engine
equivalence is property-tested in tests/test_engine.py), so the switch
is purely a performance knob. One carve-out: the sparse (Bloom-
compacted) read path dispatches only its Bloom gate — its candidate-
compacted fence search has a per-(run, query) shape the per-run fence
kernel does not take (see read_path.search_level_sparse).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import bloom as BL
from repro.core import runs as RU

I32 = jnp.int32


def strided_fences(fences: jax.Array, stride: int) -> jax.Array:
    """A level's *effective* fence array under the current allocation's
    stride view (every stride-th fence, an (mu*stride)-wide page window —
    DESIGN.md §9). Stride 1 returns the physical array untouched, so the
    static-tuning path lowers to a no-op. Every fence consumer — dense
    and sparse lookups, probe telemetry, range window bounds, and the
    mixed-op tape's branches — derives its view here, so the strided
    geometry cannot diverge between read paths."""
    return fences[:, ::stride] if stride > 1 else fences


def fence_window_idx(queries: jax.Array, fences: jax.Array, keys: jax.Array,
                     count: jax.Array, mu: int) -> jax.Array:
    """Fence-pointer lookup on one disk run (paper 2.4): binary-search the
    fences, then the mu-wide page they bound. Returns the element index of
    the hit, or -1."""
    f = jnp.searchsorted(fences, queries, side="right").astype(I32) - 1
    start = jnp.clip(f, 0, fences.shape[0] - 1) * mu
    # strided fence views (mu = base_mu * stride, DESIGN.md §9) can leave
    # a partial last page: pin the window inside the run so dynamic_slice
    # cannot silently shift it out from under the returned index
    start = jnp.minimum(start, keys.shape[0] - mu)

    def one(st, q):
        win = jax.lax.dynamic_slice(keys, (st,), (mu,))
        off = jnp.searchsorted(win, q).astype(I32)
        offc = jnp.minimum(off, mu - 1)
        hit = (off < mu) & (win[offc] == q)
        idx = st + offc
        return jnp.where(hit & (idx < count), idx, -1)

    return jax.vmap(one)(start, queries)


@dataclasses.dataclass(frozen=True)
class OpsBackend:
    """The four hot primitives the engine dispatches on.

    bloom_probe_many:  (blooms (D, W) u32, qs (Q,) i32, k, bits) -> (D, Q) bool
                       `bits` = effective filter width (static, <= W*32):
                       the per-level bit allocation (DESIGN.md §9); None
                       probes the whole physical bitset.
    fence_lookup_many: (qs (Q,), fences (D, F), keys (D, cap),
                        counts (D,), mu)                          -> (D, Q) i32 idx | -1
    merge_runs:        (keys (k, cap), vals, wts, seqs, drop: bool)
                       -> (keys, vals, wts, seqs, count)
                       weighted k-way merge (DESIGN.md §13): only the
                       (key, weight, seq) lanes enter the merge network;
                       payloads are gathered once, for surviving rows.
                       `drop` elides records whose summed weight is <= 0
                       (annihilation — the deepest-merge delete commit).
    range_merge:       (keys (Q, C), vals, wts, seqs, offsets (Q, P+1),
                        drop: bool) -> (keys, vals, wts, seqs, keep (Q, C))
                       the range engine's per-scan candidate merge
                       (DESIGN.md §10): each row holds P sorted
                       segments at `offsets`; rows come back in global
                       (key, seq) order with the weighted survivor mask
                       (negative-weight rows dropped when `drop`). jnp =
                       per-row sort; pallas = the merge-path tournament
                       kernel, the mask fused into the final round —
                       both gather the payload lane only after the
                       merge, through the survivors' source indices.
    """
    name: str
    bloom_probe_many: Callable
    fence_lookup_many: Callable
    merge_runs: Callable
    range_merge: Callable


# -- jnp reference backend ---------------------------------------------------

def _jnp_bloom_many(blooms, qs, k: int, bits: int | None = None):
    return jax.vmap(lambda w: BL.bloom_probe(w, qs, k, bits))(blooms)


def _jnp_fence_many(qs, fences, keys, counts, mu: int):
    return jax.vmap(
        lambda f, kk, c: fence_window_idx(qs, f, kk, c, mu)
    )(fences, keys, counts)


def _jnp_range_merge(keys, vals, wts, seqs, offsets, drop_annihilated: bool):
    from repro.kernels.range_merge.ref import range_merge_ref
    return range_merge_ref(keys, vals, wts, seqs, offsets, drop_annihilated)


JNP_BACKEND = OpsBackend(
    name="jnp",
    bloom_probe_many=_jnp_bloom_many,
    fence_lookup_many=_jnp_fence_many,
    merge_runs=RU.merge_runs,
    range_merge=_jnp_range_merge,
)


# -- pallas kernel backend ---------------------------------------------------
# Runs (D, the leading axis) are unrolled in a python loop: D is static and
# each kernel keeps its run VMEM-resident across the query grid, so one
# pallas_call per run is the natural launch shape.

def _pallas_bloom_many(blooms, qs, k: int, bits: int | None = None):
    from repro.kernels.bloom_probe import bloom_probe_op
    return jnp.stack([bloom_probe_op(blooms[d], qs, k, bits)
                      for d in range(blooms.shape[0])])


def _pallas_fence_many(qs, fences, keys, counts, mu: int):
    from repro.kernels.fence_lookup import fence_lookup_op
    return jnp.stack([fence_lookup_op(qs, fences[d], keys[d], counts[d], mu)
                      for d in range(keys.shape[0])])


def _pallas_merge_runs(keys2d, vals2d, wts2d, seqs2d, drop_annihilated: bool):
    from repro.kernels.heap_merge import heap_merge_op
    return heap_merge_op(keys2d, vals2d, wts2d, seqs2d, drop_annihilated)


def _pallas_range_merge(keys, vals, wts, seqs, offsets,
                        drop_annihilated: bool):
    from repro.kernels.range_merge import range_merge_op
    return range_merge_op(keys, vals, wts, seqs, offsets, drop_annihilated)


PALLAS_BACKEND = OpsBackend(
    name="pallas",
    bloom_probe_many=_pallas_bloom_many,
    fence_lookup_many=_pallas_fence_many,
    merge_runs=_pallas_merge_runs,
    range_merge=_pallas_range_merge,
)


BACKENDS = {"jnp": JNP_BACKEND, "pallas": PALLAS_BACKEND}


def candidate_gate(be: OpsBackend, qs: jax.Array, blooms: jax.Array,
                   mins: jax.Array, maxs: jax.Array, k: int,
                   bits: int | None = None) -> jax.Array:
    """(D, Q) candidate mask over one level's runs: min/max window AND
    Bloom positive (paper 2.3). The single source of the gating invariant
    — both the dense path (via `lookup_level_many`) and the sparse path
    (via `read_path.level_gate`) use it. `bits` is the level's effective
    filter width (None = the physical array, the static-mode default)."""
    inwin = (qs[None, :] >= mins[:, None]) & (qs[None, :] <= maxs[:, None])
    return inwin & be.bloom_probe_many(blooms, qs, k, bits).astype(bool)


def lookup_level_many(be: OpsBackend, qs: jax.Array, blooms: jax.Array,
                      mins: jax.Array, maxs: jax.Array, fences: jax.Array,
                      keys: jax.Array, counts: jax.Array, k: int, mu: int,
                      bits: int | None = None):
    """One fused candidate pass over all D runs of a level for Q queries.

    This is the batched read fast path's per-level body: a single
    backend-dispatched Bloom-probe pass (paper 2.3) and a single
    fence-search pass (paper 2.4) cover every (run, query) pair at once —
    no per-query dispatch. Both the single-tree dense lookup and the
    vmapped sharded lookup route through it, on either backend.

    Returns ``(hit (D, Q) bool, idx (D, Q) i32)``: ``hit`` requires the
    min/max window, a Bloom positive, AND an exact fence-page key match;
    ``idx`` is clamped to a gatherable element index (only meaningful
    where ``hit``).
    """
    gate = candidate_gate(be, qs, blooms, mins, maxs, k, bits)
    idx = be.fence_lookup_many(qs, fences, keys, counts, mu)
    return gate & (idx >= 0), jnp.maximum(idx, 0)


def fence_window_bounds(lo: jax.Array, hi: jax.Array, fences: jax.Array,
                        keys: jax.Array, count: jax.Array, mu: int):
    """[start, end) element bounds of the window [lo, hi) in one disk run,
    located through the fence pointers (paper 2.4/2.9, DESIGN.md §10).

    For each bound: binary-search the (possibly strided) fences for its
    page, then refine inside the mu-wide page window — O(log F + log mu)
    instead of a search over the whole run, and the shape the range
    kernel's VMEM budget wants. `lo`/`hi` may be batched (any shape);
    returns (start, end) of the same shape with start <= end <= count.
    """
    def locate(q):
        f = jnp.searchsorted(fences, q, side="right").astype(I32) - 1
        st = jnp.clip(f, 0, fences.shape[0] - 1) * mu
        # strided fence views can leave a partial last page: pin the
        # window inside the run (keys are globally sorted, so a window
        # reaching back before the fence group still refines correctly)
        st = jnp.minimum(st, keys.shape[0] - mu)
        win = jax.lax.dynamic_slice(keys, (st,), (mu,))
        return st + jnp.searchsorted(win, q).astype(I32)

    batched = jnp.shape(lo) != ()
    loc = jax.vmap(locate) if batched else locate
    start, end = loc(lo), loc(hi)
    end = jnp.minimum(end, count)
    return jnp.minimum(start, end), end


def get_backend(name: str) -> OpsBackend:
    """Resolve `SLSMParams.backend` to its `OpsBackend` record ("jnp" |
    "pallas"); raises ValueError for unknown names."""
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; options: {sorted(BACKENDS)}") from None
