"""Disk-tier state (paper 2.4): D immutable sorted runs per level.

A level is a statically-shaped pytree: run payloads plus the per-run
index structures the paper attaches to disk runs — min/max keys, a Bloom
filter, and fence pointers every mu slots. Runs are weighted-record SoA
(DESIGN.md §13): the weight plane rides next to keys/seqs in the merge
lanes, the payload plane stays separate. Slot 0 is always the oldest
resident run; `shift_level` preserves that invariant when runs spill.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bloom as BL
from repro.core import runs as RU
from repro.core.params import KEY_EMPTY, SLSMParams

I32 = jnp.int32

# -inf key sentinel for "max key of an empty run"
_KEY_MIN = -(2 ** 31)


class LevelState(NamedTuple):
    """One disk tier: D immutable sorted runs (paper 2.4)."""
    keys: jax.Array    # (D, cap_l) sorted ascending, KEY_EMPTY padded
    vals: jax.Array    # (D, cap_l)
    wts: jax.Array     # (D, cap_l) record weights: +1 insert, -1 delete
    seqs: jax.Array    # (D, cap_l)
    counts: jax.Array  # (D,)
    mins: jax.Array    # (D,)
    maxs: jax.Array    # (D,)
    blooms: jax.Array  # (D, words_l) uint32
    fences: jax.Array  # (D, n_fences_l)
    n_runs: jax.Array  # () number of occupied run slots (oldest = slot 0)


def empty_level(p: SLSMParams, level: int) -> LevelState:
    """Fresh all-empty tier with `level_cap(level)` geometry (paper 2.4:
    level capacities grow geometrically, O((mD)^k) elements at level k)."""
    cap = p.level_cap(level)
    w = p.bloom_words_physical(cap, p.level_eps(level))
    return LevelState(
        keys=jnp.full((p.D, cap), KEY_EMPTY, I32),
        vals=jnp.zeros((p.D, cap), I32),
        wts=jnp.zeros((p.D, cap), I32),
        seqs=jnp.zeros((p.D, cap), I32),
        counts=jnp.zeros((p.D,), I32),
        mins=jnp.full((p.D,), KEY_EMPTY, I32),
        maxs=jnp.full((p.D,), _KEY_MIN, I32),
        blooms=jnp.zeros((p.D, w), jnp.uint32),
        fences=jnp.full((p.D, p.n_fences(level)), KEY_EMPTY, I32),
        n_runs=jnp.zeros((), I32),
    )


def index_new_run(p: SLSMParams, level: int, k, v, w_, s, cnt):
    """Pad a merged run to level capacity; build its Bloom filter and
    min/max index (paper 2.3) and fence pointers every mu slots (2.4).

    The filter is built at `level`'s *effective* geometry (the current
    allocation's per-level bits/k, DESIGN.md §9) inside the physically
    allocated word array — this is the rebuild-on-spill path: every run a
    merge writes automatically carries the latest allocation's filter.
    Fences are always built at the finest granularity (every mu slots);
    `fence_stride` is a read-side view and costs nothing to retune."""
    cap = p.level_cap(level)
    bits, _, kk = p.bloom_geometry(cap, p.level_eps(level))
    w = p.bloom_words_physical(cap, p.level_eps(level))
    pad = cap - k.shape[0]
    if pad < 0:  # deepest-level compaction scratch is larger than cap
        k, v, w_, s = k[:cap], v[:cap], w_[:cap], s[:cap]
    # build the filter at the pre-pad width: a spill's merged run is often
    # far narrower than its destination capacity (the deepest level's xD
    # bonus especially), and the scatter inside bloom_build processes
    # every lane, padded or not — building before padding cuts the
    # dominant cost of a deep spill step ~4x (the delete-phase tail).
    # Padding adds only KEY_EMPTY lanes, which the valid mask drops, so
    # the filter is bit-identical either way.
    filt = BL.bloom_build(k, k != KEY_EMPTY, w, kk, bits)
    if pad > 0:
        k = jnp.concatenate([k, jnp.full((pad,), KEY_EMPTY, I32)])
        v = jnp.concatenate([v, jnp.zeros((pad,), I32)])
        w_ = jnp.concatenate([w_, jnp.zeros((pad,), I32)])
        s = jnp.concatenate([s, jnp.zeros((pad,), I32)])
    fences = RU.build_fences(k, p.mu, p.n_fences(level))
    mn, mx = RU.run_minmax(k, cnt)
    return k, v, w_, s, filt, fences, mn, mx


def set_level_run(lv: LevelState, slot, k, v, w, s, cnt, filt, fences, mn, mx,
                  bump: int = 1) -> LevelState:
    """Install an indexed run into `slot` (runs land append-order, newest
    last — the recency order Do-Merge relies on, paper 2.5)."""
    return lv._replace(
        keys=lv.keys.at[slot].set(k), vals=lv.vals.at[slot].set(v),
        wts=lv.wts.at[slot].set(w),
        seqs=lv.seqs.at[slot].set(s), counts=lv.counts.at[slot].set(cnt),
        mins=lv.mins.at[slot].set(mn), maxs=lv.maxs.at[slot].set(mx),
        blooms=lv.blooms.at[slot].set(filt),
        fences=lv.fences.at[slot].set(fences),
        n_runs=lv.n_runs + bump,
    )


def shift_level(p: SLSMParams, lv: LevelState, n: int) -> LevelState:
    """Drop the n oldest runs (slots [0, n)), shifting the rest down —
    the source-level half of a Do-Merge spill (paper 2.5: the ceil(m*D)
    oldest runs of a full level move to the next)."""
    def roll(a, fill):
        tail_shape = (n,) + a.shape[1:]
        return jnp.concatenate([a[n:], jnp.full(tail_shape, fill, a.dtype)])
    return LevelState(
        keys=roll(lv.keys, KEY_EMPTY), vals=roll(lv.vals, 0),
        wts=roll(lv.wts, 0),
        seqs=roll(lv.seqs, 0), counts=roll(lv.counts, 0),
        mins=roll(lv.mins, KEY_EMPTY), maxs=roll(lv.maxs, _KEY_MIN),
        blooms=roll(lv.blooms, 0), fences=roll(lv.fences, KEY_EMPTY),
        n_runs=lv.n_runs - n,
    )
