"""Incremental merge scheduler: the Do-Merge cascade as paced, bounded steps.

The paper's Do-Merge (Algorithm 2 / 2.5) is recursive: the insert that
fills the staging buffer pays for the seal, the flush, every level spill
the flush triggers, and — worst case — the deepest-level compaction, all
synchronously inside one insert chunk. That is the classic LSM write
stall (Luo & Carey, "On Performance Stability in LSM-based Storage
Systems"): p50 insert latency is one staged sort, p99 is the whole
cascade, two-plus orders of magnitude apart.

This module decomposes the cascade into four bounded-work step kinds —
each already a single jitted device op in `memtable`/`compaction`:

  seal     — stage -> one sealed memory run            (memtable.seal_run)
  flush    — ceil(m*R) memory runs -> one L0 run       (compaction.merge_buffer_to_level0)
  spill l  — ceil(m*D) runs of level l -> one l+1 run  (compaction.merge_level_down)
  compact  — all runs of the deepest level -> one run  (compaction.compact_last_level)

and paces them: after every staged insert chunk the scheduler executes up
to `SLSMParams.merge_budget` *voluntary* steps, deepest level first, then
runs whatever is structurally *forced* (the next chunk must fit the
staging buffer). With budget 0 the voluntary pass is empty and the forced
chain reproduces the legacy synchronous cascade exactly. With budget >= 1
a level that fills is retired during the many chunks of slack before the
next run arrives for it, so the forced chain almost never recurses and
the insert tail collapses to the cost of the single largest step.

Pacing invariants (DESIGN.md §8):
  * every step is one atomic state transition: a merge's source runs stay
    visible to the read path until the very dispatch that installs the
    merged output retires them, so reads are exact at every point between
    steps — no drain needed for correctness;
  * a step runs only when its destination has a free run slot under the
    compaction policy (`step_ready`), so pacing never violates the
    policy's occupancy bounds;
  * `drain()` is the barrier: it retires every pending step, after which
    budgeted and synchronous engines answer lookups/ranges identically
    (they may hold different — equally valid — resting structures);
  * voluntary work runs earlier than the synchronous schedule would, so
    a tree at its declared capacity can raise the deepest-level overflow
    RuntimeError a few chunks sooner than merge_budget=0 — the remedy is
    the same either way (increase max_levels).

Annihilation stays the host decision it was in the synchronous cascade:
a step elides zero-sum (deleted) keys iff its output becomes the deepest
data *at the moment the step runs* (paper 2.5/2.8). Each merge step also
books the Z-set telemetry (rows in/out, annihilated rows) host-side —
the counts ride occupancy counters the scheduler already reads.

The adaptive tuner (repro.engine.tuner, DESIGN.md §9) rides this same
machinery: a decided allocation switch surfaces as a fifth step kind,

  retune   — rebuild every resident filter under the new allocation
             (tuner.retune_filters) and swap the driver's active params

which is paced, drained, and telemetered exactly like a merge. With the
default static tuning policy no RETUNE step ever becomes pending and
the scheduler is bit-identical to its pre-tuner behaviour.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.params import SLSMParams
from repro.engine.compaction import (CompactionPolicy, compact_last_level,
                                     merge_buffer_to_level0, merge_level_down)
from repro.engine.levels import empty_level
from repro.engine.memtable import init_state, seal_run, stage_append

SEAL, FLUSH, SPILL, COMPACT = "seal", "flush", "spill", "compact"
RETUNE = "retune"


class Occupancy(NamedTuple):
    """Host-side occupancy snapshot — all the scheduler ever reads."""
    stage_count: int
    run_count: int
    level_runs: Tuple[int, ...]   # n_runs per *materialized* level


def occupancy_of(state) -> Occupancy:
    """Snapshot a (single-tree) state pytree's occupancy counters."""
    return Occupancy(int(state.stage_count), int(state.run_count),
                     tuple(int(lv.n_runs) for lv in state.levels))


def step_order(p: SLSMParams) -> List[Tuple[str, int]]:
    """Canonical deepest-first step order: executing pending steps in this
    order propagates free space upward (a spill's destination is freed
    before the spill itself is attempted)."""
    order: List[Tuple[str, int]] = [(COMPACT, p.max_levels - 1)]
    order += [(SPILL, lvl) for lvl in range(p.max_levels - 2, -1, -1)]
    order += [(FLUSH, -1), (SEAL, -1)]
    return order


def step_pending(kind: str, level: int, occ: Occupancy, p: SLSMParams,
                 policy: CompactionPolicy) -> bool:
    """Does this step have work queued under the current occupancy?

    (RETUNE pendingness lives on the tuner, not the occupancy — it is
    injected by `pending_steps(..., retune=True)`.)"""
    if kind == SEAL:
        return occ.stage_count >= p.Rn
    if kind == FLUSH:
        # flush becomes *pending* at the tuner's effective buffer size;
        # only run_count >= R (physical slots exhausted) ever *forces* it
        return occ.run_count >= p.R_eff
    # spill/compact: the level must exist and the policy must want it moved
    if level >= len(occ.level_runs):
        return False
    return policy.needs_spill(p, occ.level_runs[level], level)


def step_ready(kind: str, level: int, occ: Occupancy, p: SLSMParams,
               policy: CompactionPolicy) -> bool:
    """Can this step run *now* without violating a policy bound — i.e. is
    its destination able to accept the output run? (The deepest-level
    compaction rewrites in place and is always ready.)"""
    if kind == SEAL:
        return occ.stage_count >= p.Rn and occ.run_count < p.R
    if kind == FLUSH:
        if occ.run_count < p.runs_merged_eff:
            return False
        return (len(occ.level_runs) == 0
                or not policy.needs_spill(p, occ.level_runs[0], 0))
    if kind in (COMPACT, RETUNE):
        return True
    dst = level + 1
    return (dst >= len(occ.level_runs)      # destination grown on demand
            or not policy.needs_spill(p, occ.level_runs[dst], dst))


def step_cost(kind: str, level: int, p: SLSMParams) -> int:
    """Device-op cost of one step, in elements touched by its merge — the
    uniform cost axis the pacing trades against (a seal is ~Rn, the
    deepest compaction is D * level_cap(last): orders of magnitude)."""
    if kind == SEAL:
        return p.Rn
    if kind == FLUSH:
        return p.runs_merged_eff * p.Rn
    if kind == COMPACT:
        return p.D * p.level_cap(p.max_levels - 1)
    if kind == RETUNE:   # every resident filter is rebuilt from its keys
        return p.R * p.Rn + sum(p.D * p.level_cap(lvl)
                                for lvl in range(p.max_levels))
    return p.disk_runs_merged * p.level_cap(level)


class MergeStep(NamedTuple):
    """One bounded unit of Do-Merge work (uniform interface over the
    single-step ops in memtable.py / compaction.py)."""
    kind: str
    level: int     # source level for spill/compact; -1 for seal/flush
    cost: int      # elements touched (step_cost)

    def pending(self, occ: Occupancy, p, policy) -> bool:
        """Does this step have work queued under `occ`? (step_pending)"""
        return step_pending(self.kind, self.level, occ, p, policy)

    def ready(self, occ: Occupancy, p, policy) -> bool:
        """Can this step run now without violating a policy bound?
        (step_ready)"""
        return step_ready(self.kind, self.level, occ, p, policy)


def pending_steps(p: SLSMParams, policy: CompactionPolicy,
                  occ: Occupancy, retune: bool = False) -> List[MergeStep]:
    """The step backlog under `occ`, deepest-first (execution order).

    `retune` injects the tuner's pending allocation switch at the head
    of the backlog (its pendingness lives on the tuner, not in the
    occupancy): retiring it first means every subsequent merge in the
    same pass already builds filters at the new allocation."""
    steps = [MergeStep(kind, level, step_cost(kind, level, p))
             for kind, level in step_order(p)
             if step_pending(kind, level, occ, p, policy)]
    if retune:
        steps.insert(0, MergeStep(RETUNE, -1, step_cost(RETUNE, -1, p)))
    return steps


def backlog_cost(steps: Sequence[MergeStep]) -> int:
    """Total device-op cost of a backlog (telemetry)."""
    return sum(s.cost for s in steps)


def drop_annihilated_into(state, target_level: int) -> bool:
    """Deletes commit (negative-weight records annihilate) when the merge
    output becomes the deepest data (paper 2.5/2.8) — evaluated at
    step-run time, exactly as the synchronous cascade evaluated it at
    recursion time."""
    for lv in state.levels[target_level:]:
        if int(lv.n_runs) > 0:
            return False
    return True


class MergeScheduler:
    """Single-tree scheduler: owns no array state — it reads the driver's
    occupancy and executes steps against the driver's state pytree.

    `on_chunk()` is the one entry point the insert path calls (after each
    staged Rn-chunk): voluntary budgeted steps first, forced chain after.
    `drain()` retires the whole backlog (the read-equivalence barrier).
    """

    def __init__(self, drv):
        self.drv = drv   # the SLSM driver: .p, .policy, .state, .stats

    @property
    def p(self) -> SLSMParams:
        """The driver's *active* parameter set — the current tuner
        allocation's effective view (== drv.p under static tuning)."""
        return getattr(self.drv, "p_active", self.drv.p)

    @property
    def policy(self):
        """The driver's *active* compaction policy (the eager read-mode
        overlay while the tuner's read allocation is active; otherwise
        the configured policy)."""
        return getattr(self.drv, "policy_active", self.drv.policy)

    def _retune_pending(self) -> bool:
        tuner = getattr(self.drv, "tuner", None)
        return bool(tuner is not None and tuner.pending)

    # -- step execution (each is one jitted device dispatch) ---------------

    def _materialize(self, level: int) -> None:
        """Grow the levels pytree through `level` (host decision, lazy —
        the paper's unbounded level growth, bounded by max_levels)."""
        drv = self.drv
        while len(drv.state.levels) <= level:
            drv.state = drv.state._replace(
                levels=drv.state.levels
                + (empty_level(self.p, len(drv.state.levels)),))

    def _book_merge(self, rows_in: int, rows_out: int) -> None:
        """Z-set merge telemetry (DESIGN.md §13): rows entering the merge
        vs. rows surviving it. The gap is dedup + annihilation — rows the
        weighted algebra kept out of the output, whose payloads the Ghost
        gather never touched (4 bytes of payload each)."""
        st = self.drv.stats
        st["rows_merged_in"] += rows_in
        st["rows_merged_out"] += rows_out
        st["rows_annihilated"] += rows_in - rows_out
        st["ghost_payload_bytes_skipped"] += 4 * (rows_in - rows_out)

    def run_step(self, step: MergeStep) -> None:
        """Execute one step as a single jitted device dispatch (or, for
        RETUNE, the driver's filter-rebuild + active-params swap) and
        bump the matching stats counter. The one place steps become
        state transitions — pacing, forcing, and draining all funnel
        through here."""
        drv, p = self.drv, self.p
        if step.kind == RETUNE:
            drv.apply_retune()
            drv.stats["retunes"] += 1
        elif step.kind == SEAL:
            drv.state = seal_run(p, drv.state)
            drv.stats["seals"] += 1
        elif step.kind == FLUSH:
            self._materialize(0)
            mr = p.runs_merged_eff
            rows_in = int(jnp.sum(drv.state.buf_counts[:mr]))
            slot = int(drv.state.levels[0].n_runs)
            drv.state = merge_buffer_to_level0(
                p, drv.state, drop_annihilated_into(drv.state, 0))
            self._book_merge(rows_in,
                             int(drv.state.levels[0].counts[slot]))
            drv.stats["flushes"] += 1
        elif step.kind == SPILL:
            self._materialize(step.level + 1)
            n_merge = self.policy.runs_to_spill(
                p, int(drv.state.levels[step.level].n_runs))
            rows_in = int(jnp.sum(
                drv.state.levels[step.level].counts[:n_merge]))
            slot = int(drv.state.levels[step.level + 1].n_runs)
            drv.state = merge_level_down(
                p, drv.state, step.level, n_merge,
                drop_annihilated_into(drv.state, step.level + 1))
            self._book_merge(
                rows_in,
                int(drv.state.levels[step.level + 1].counts[slot]))
            drv.stats["spills"] += 1
        else:   # COMPACT
            last = p.max_levels - 1
            rows_in = int(jnp.sum(drv.state.levels[last].counts))
            new_state, raw = compact_last_level(p, drv.state)
            cap = p.level_cap(last)
            if int(raw) > cap:
                raise RuntimeError(
                    f"sLSM deepest level overflow ({int(raw)} > {cap} "
                    f"live elements): increase max_levels beyond "
                    f"{p.max_levels}")
            drv.state = new_state
            self._book_merge(rows_in, int(raw))
            drv.stats["compactions"] += 1

    # -- forced chain (== the legacy synchronous cascade) ------------------

    def force_space(self, level: int) -> None:
        """Guarantee `level` can accept one run, recursing deeper first —
        the legacy `_ensure_space`, expressed in steps. Only runs when
        pacing slack ran out (always, when merge_budget == 0)."""
        drv, p = self.drv, self.p
        if level >= p.max_levels:
            raise RuntimeError(
                "sLSM capacity exceeded: increase max_levels "
                f"(currently {p.max_levels})")
        if level >= len(drv.state.levels):
            self._materialize(level)
            return
        if not self.policy.needs_spill(
                p, int(drv.state.levels[level].n_runs), level):
            return
        if level == p.max_levels - 1:
            self.run_step(MergeStep(COMPACT, level,
                                    step_cost(COMPACT, level, p)))
        else:
            self.force_space(level + 1)
            self.run_step(MergeStep(SPILL, level, step_cost(SPILL, level, p)))

    # -- pacing entry points ----------------------------------------------

    def _next_ready(self):
        """Deepest pending step that is ready under the live occupancy
        (None if the backlog is empty or wholly blocked)."""
        p, policy = self.p, self.policy
        occ = occupancy_of(self.drv.state)
        for step in pending_steps(p, policy, occ, self._retune_pending()):
            if step.ready(occ, p, policy):
                return step
        return None

    def on_chunk(self) -> None:
        """Voluntary budgeted steps, then whatever the next chunk forces.

        The backlog is re-derived after every applied step, so a step's
        consequences (a seal filling the buffer, a flush filling level 0)
        can be paid for inside the same chunk while budget remains — the
        same fixpoint semantics the sharded driver's masked pass uses, so
        equal budgets mean equal pacing on both drivers.

        The tuner (if adaptive) decides here, at the chunk boundary; a
        decided switch joins the backlog as a RETUNE step and is paid
        for out of the same voluntary budget as any merge. In
        synchronous mode (merge_budget == 0) the voluntary pass is
        empty, so a pending retune — like every other piece of
        maintenance in that mode — runs inline, immediately."""
        drv, p = self.drv, self.p
        tuner = getattr(drv, "tuner", None)
        if tuner is not None:
            tuner.decide()
            if tuner.take_probe_sample():
                sampler = getattr(drv, "sample_probe_stats", None)
                if sampler is not None:
                    sampler()
        backlog = pending_steps(p, self.policy, occupancy_of(drv.state),
                                self._retune_pending())
        drv.stats["backlog_peak"] = max(drv.stats["backlog_peak"],
                                        len(backlog))
        budget = p.merge_budget
        # read-mode catch-up: while the read-optimized allocation is (or
        # is about to be) active, writes are a trickle and every one of
        # them is a chance to fold structure the read path then skips —
        # so the voluntary pass runs to quiescence instead of rationing.
        # Write-phase pacing (the whole point of merge_budget) is
        # untouched: catch-up applies only in/INTO read mode — a pending
        # switch to any other allocation stays budget-paced.
        catch_up = (budget > 0 and tuner is not None and tuner.enabled
                    and (tuner.active == "read"
                         or (tuner.pending and tuner.target == "read")))
        while budget > 0 or catch_up:
            step = self._next_ready()
            if step is None:
                break
            self.run_step(step)
            budget -= 1
        if p.merge_budget == 0 and self._retune_pending():
            self.run_step(MergeStep(RETUNE, -1, step_cost(RETUNE, -1, p)))
        # forced: the staging buffer must fit the next Rn-chunk
        self.ensure_stage_space()

    def ensure_stage_space(self) -> None:
        """Forced chain: seal (flushing/cascading first when the buffer
        is out of run slots) until the staging buffer can absorb a full
        Rn-chunk — the structural precondition every insert chunk and
        every mixed-op tape dispatch relies on. This is `on_chunk`'s
        forced tail, callable standalone (the serving layer's headroom
        pass runs it between tapes)."""
        drv, p = self.drv, self.p
        while int(drv.state.stage_count) >= p.Rn:
            if int(drv.state.run_count) >= p.R:
                self.force_space(0)
                self.run_step(MergeStep(FLUSH, -1, step_cost(FLUSH, -1, p)))
            self.run_step(MergeStep(SEAL, -1, step_cost(SEAL, -1, p)))

    def reserve_run_slots(self, n: int) -> None:
        """Guarantee >= `n` free memory-run slots (flushing — and
        cascading, when level 0 is full — until they exist): the
        headroom a mixed-op tape needs before it can seal in-scan,
        where no host decision can intervene (tape.tape_seal_bound).

        A flush retires `runs_merged_eff` runs and needs that many
        resident, so the reachable floor from run_count rc is
        ``rc % runs_merged_eff``; raises ValueError when `n` exceeds
        ``R - that`` (the tape carries too many write keys — split it;
        `SLSM.tape_write_capacity` is the matching key budget)."""
        p = self.p
        floor = int(self.drv.state.run_count) % p.runs_merged_eff
        if n > p.R - floor:
            raise ValueError(
                f"cannot reserve {n} run slots: only {p.R - floor} "
                f"reachable (R={p.R}, {floor} unflushable resident runs)")
        while p.R - int(self.drv.state.run_count) < n:
            self.force_space(0)
            self.run_step(MergeStep(FLUSH, -1, step_cost(FLUSH, -1, p)))

    def voluntary_steps(self, budget: int) -> int:
        """Run up to `budget` ready steps, deepest-first, re-deriving the
        backlog after each (the same fixpoint semantics as `on_chunk`'s
        voluntary pass); returns how many ran. The maintenance governor's
        entry point (repro.serve): idle gaps and window boundaries spend
        accumulated budget here instead of pacing per insert chunk. A
        pending RETUNE rides the backlog like any merge."""
        ran = 0
        while ran < budget:
            step = self._next_ready()
            if step is None:
                break
            self.run_step(step)
            ran += 1
        return ran

    def on_read(self) -> None:
        """Decision boundary on the read path (adaptive tuning only —
        static engines never reach this, so their read path stays
        dispatch-for-dispatch identical to the pre-tuner engine).

        Reads only feed and roll the controller; they never *execute*
        maintenance — decisions bind at merge (write-chunk) boundaries,
        where `on_chunk` applies the RETUNE step and, in read mode,
        folds structure at catch-up pace. Keeping execution off the read
        path means a lookup's latency never absorbs a rebuild or merge:
        the read phase's trickle of writes is where that work lands.
        (`drain()` remains the barrier that applies everything,
        writes or not.)"""
        tuner = getattr(self.drv, "tuner", None)
        if tuner is None or not tuner.enabled:
            return
        tuner.decide()

    def drain(self) -> None:
        """Retire every pending step (the read-equivalence barrier).

        Deepest-ready-first until the backlog is empty; progress is
        guaranteed because a deeper step's execution is exactly what
        readies its shallower dependent. A pending allocation switch
        drains too: after drain() the engine is at rest *under its
        decided allocation*."""
        drv = self.drv
        while True:
            backlog = pending_steps(self.p, self.policy,
                                    occupancy_of(drv.state),
                                    self._retune_pending())
            if not backlog:
                return
            step = self._next_ready()
            if step is None:   # pragma: no cover — invariant violation
                raise RuntimeError(
                    f"merge scheduler drain stalled with backlog {backlog}")
            self.run_step(step)

    @property
    def backlog(self) -> List[MergeStep]:
        """Current pending steps (introspection/telemetry)."""
        return pending_steps(self.p, self.policy,
                             occupancy_of(self.drv.state),
                             self._retune_pending())

    # -- program warm-up ---------------------------------------------------

    def warm(self) -> None:
        """Precompile every maintenance program this engine can dispatch.

        Static shapes make the set enumerable up front: each step op is
        jit-specialized on (params, levels-pytree structure, and for
        spills the static n_merge / annihilation flag), so the programs a
        run will ever need are exactly the combinations below. Programs
        are shape-specialized, not value-specialized — executing each
        once on a throwaway zero state compiles the real path. Without
        this, every first-use compile (hundreds of ms) lands inside
        whichever insert chunk happens to trigger it: a stall the pacing
        budget cannot flatten, because it rides the very step dispatch
        that was paced. One-off; results are discarded; the jit cache is
        process-global, so same-param engines share the warmth.
        """
        from repro.engine.tuner import ReadModePolicy, retune_filters
        base, policy = self.drv.p, self.drv.policy
        tuner = getattr(self.drv, "tuner", None)
        # adaptive tuning: every preset is its own static-param program
        # set (the allocation is a jit-static argument), so warm each —
        # an allocation switch must not stall the chunk that pays for it;
        # the read-mode policy overlay adds its spill sizes to the set
        adaptive = tuner is not None and tuner.enabled
        if adaptive:
            param_sets = [alloc.apply(base)
                          for alloc in tuner.presets.values()]
            spill_sizes = sorted(set(policy.spill_sizes(base))
                                 | set(ReadModePolicy().spill_sizes(base)))
        else:
            param_sets = [base]
            spill_sizes = policy.spill_sizes(base)
        last = base.max_levels - 1
        outs = []
        for p in param_sets:
            rn = p.Rn
            dk = jnp.full((rn,), 0, jnp.int32)
            dv = jnp.zeros((rn,), jnp.int32)
            dw = jnp.ones((rn,), jnp.int32)
            for n_levels in range(p.max_levels + 1):
                # fresh dummies per call: these ops donate their state
                outs.append(stage_append(p, init_state(p, n_levels), dk, dv,
                                         dw, jnp.int32(0)))
                outs.append(seal_run(p, init_state(p, n_levels)))
                if len(param_sets) > 1:
                    outs.append(retune_filters(p, init_state(p, n_levels)))
                if n_levels == 0:
                    continue
                for drop in (True, False):
                    outs.append(merge_buffer_to_level0(
                        p, init_state(p, n_levels), drop))
                # spill of level l runs after its target l+1 materializes
                for lvl in range(min(n_levels - 1, last)):
                    for n_merge in spill_sizes:
                        for drop in (True, False):
                            outs.append(merge_level_down(
                                p, init_state(p, n_levels), lvl, n_merge,
                                drop))
            outs.append(compact_last_level(p, init_state(p, p.max_levels)))
        jax.block_until_ready(outs)
