"""Single-leader replication over the WAL (DESIGN.md §14).

The durability layer's WAL (DESIGN.md §12) is already a replication
log: CRC-framed records with strictly-consecutive seqnos, a snapshot
codec with a seqno watermark, and replay through the engines' existing
chunk-apply programs. This module ships that stream:

  * the **leader** is any durable driver (`SLSM` / `ShardedSLSM`): a
    `Leader` wraps it, `bootstrap` copies its newest snapshot + WAL
    tail into a follower directory (the initial sync), and `ship`
    tails the leader's *durable* log bytes (`wal.WalTailer`) and sends
    each frame verbatim over a pluggable transport;
  * a **follower** opens that directory via ``open_replica`` (a plain
    `restore` under a replica-mode durability layer), then `apply`s
    incoming frames: validate (`wal.check_frame`), de-duplicate and
    reorder by seqno, append verbatim (`Durability.append_frame` — the
    follower's WAL stays a bitwise copy of the leader's stream), sync,
    replay through `apply_replicated`, and ack;
  * transports are an in-process `QueueLink` (tests inject faults by
    mutating its deques) and a localhost socket pair
    (`SocketListener` / `connect` → `SocketEnd`, length-prefixed
    messages whose torn tails drop with the connection);
  * **failover** is explicit: `Follower.promote` drops unacked
    buffered frames (never acked ⇒ never durable anywhere), detaches
    the transport, and calls the engine's ``promote()`` — WAL epoch
    bump + local logging re-enabled — returning a writable leader
    whose answers bitwise-match a fresh engine fed the acked prefix.

Consistency model: read-your-writes on the leader (the driver's
log-before-ack group commit is untouched — replication ships only
*durable* bytes, so nothing a follower applies can ever be un-acked on
the leader); followers are eventually consistent and serve the batched
read paths (`lookup_many` / `range_many`) at their applied watermark.
Lag is bounded and observable: `Leader.stats()` reports
``follower_lag_records`` / ``follower_lag_bytes`` from follower acks.

The fault-injection suite (``tests/replication/``) proves answer-exact
failover under leader SIGKILL, torn stream tails, duplicated /
reordered / dropped delivery, and mid-RETUNE cuts, on both drivers ×
both backends.
"""
from __future__ import annotations

import collections
import json
import select
import shutil
import socket
import struct
from pathlib import Path
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

from repro.engine import wal as WAL
from repro.engine.engine import SLSM
from repro.engine.sharded import ShardedSLSM

# stream message framing (byte-stream transports): type u8 | len u32 | payload
_MSG = struct.Struct("<BI")
_ACK = struct.Struct("<qQB")        # applied seqno i64 | applied bytes u64 | gap u8
T_FRAME = 1                         # payload = one verbatim WAL frame
T_ACK = 2                           # payload = _ACK


class Cursor(NamedTuple):
    """A shipping position in the leader's WAL: byte `offset`, the
    `next_seqno` expected there (None = accept any first record), and
    the minimum `epoch` of subsequent frames."""

    offset: int
    next_seqno: Optional[int]
    epoch: int = 0


# --------------------------------------------------------------------------
# transports
# --------------------------------------------------------------------------

class QueueEnd:
    """One end of a `QueueLink`. The leader end uses
    `send_frames`/`recv_acks`; the follower end `recv_frames`/`send_ack`.
    Setting ``closed`` simulates a severed link (sends raise, receives
    return nothing) — the partition fault tests flip it directly."""

    def __init__(self, link: "QueueLink", is_leader: bool):
        self.link = link
        self.is_leader = is_leader
        self.closed = False

    def _check_open(self) -> None:
        if self.closed:
            raise BrokenPipeError("replication link closed")

    def send_frames(self, frames: List[bytes]) -> None:
        """Enqueue raw WAL frames toward the follower."""
        self._check_open()
        self.link.frames.extend(frames)

    def recv_frames(self) -> List[bytes]:
        """Drain every in-flight frame (empty when closed)."""
        if self.closed:
            return []
        out = list(self.link.frames)
        self.link.frames.clear()
        return out

    def send_ack(self, seqno: int, nbytes: int, gap: bool = False) -> None:
        """Enqueue one follower ack toward the leader."""
        self._check_open()
        self.link.acks.append((seqno, nbytes, gap))

    def recv_acks(self) -> List[Tuple[int, int, bool]]:
        """Drain every in-flight ``(applied_seqno, applied_bytes, gap)``."""
        if self.closed:
            return []
        out = list(self.link.acks)
        self.link.acks.clear()
        return out

    def close(self) -> None:
        """Sever this end of the link."""
        self.closed = True


class QueueLink:
    """In-process transport: a leader end and a follower end over two
    deques. The wire is inspectable — ``frames`` holds raw frame bytes
    heading to the follower, ``acks`` the ack tuples heading back — so
    fault tests duplicate, reorder, drop, or bit-flip in-flight frames
    by mutating the deques between pumps."""

    def __init__(self):
        self.frames: collections.deque = collections.deque()
        self.acks: collections.deque = collections.deque()
        self.leader = QueueEnd(self, is_leader=True)
        self.follower = QueueEnd(self, is_leader=False)


class SocketEnd:
    """One end of a localhost replication stream.

    Messages are length-prefixed (``type u8 | len u32 | payload``); a
    partially received message — the torn stream tail a dying peer
    leaves — stays buffered and is dropped with the connection, the
    transport-level mirror of the WAL's torn-tail rule. Receives are
    non-blocking (`select`-gated drains); sends are blocking and mark
    the end ``closed`` on a dead peer."""

    def __init__(self, sock: socket.socket):
        sock.setblocking(True)
        self.sock = sock
        self.closed = False
        self._buf = b""

    def _pump(self) -> None:
        while not self.closed:
            try:
                r, _, _ = select.select([self.sock], [], [], 0)
            except (OSError, ValueError):
                self.closed = True
                return
            if not r:
                return
            try:
                data = self.sock.recv(1 << 16)
            except OSError:
                data = b""
            if not data:
                self.closed = True
                return
            self._buf += data

    def _messages(self) -> List[Tuple[int, bytes]]:
        out, off = [], 0
        while off + _MSG.size <= len(self._buf):
            t, n = _MSG.unpack_from(self._buf, off)
            if off + _MSG.size + n > len(self._buf):
                break                   # torn tail: stays pending
            out.append((t, self._buf[off + _MSG.size:off + _MSG.size + n]))
            off += _MSG.size + n
        self._buf = self._buf[off:]
        return out

    def send_frames(self, frames: List[bytes]) -> None:
        """Send raw WAL frames, one message each, in one write."""
        self._send(b"".join(_MSG.pack(T_FRAME, len(f)) + f for f in frames))

    def send_ack(self, seqno: int, nbytes: int, gap: bool = False) -> None:
        """Send one ``(applied_seqno, applied_bytes, gap)`` ack."""
        self._send(_MSG.pack(T_ACK, _ACK.size)
                   + _ACK.pack(seqno, nbytes, 1 if gap else 0))

    def _send(self, blob: bytes) -> None:
        if self.closed:
            raise BrokenPipeError("replication stream closed")
        try:
            self.sock.sendall(blob)
        except OSError as e:
            self.closed = True
            raise BrokenPipeError(f"replication peer gone: {e}") from e

    def recv_frames(self) -> List[bytes]:
        """Drain every fully received frame message."""
        self._pump()
        return [p for t, p in self._messages() if t == T_FRAME]

    def recv_acks(self) -> List[Tuple[int, int, bool]]:
        """Drain every fully received ack message."""
        self._pump()
        return [(s, b, bool(g)) for t, p in self._messages()
                if t == T_ACK and len(p) == _ACK.size
                for s, b, g in (_ACK.unpack(p),)]

    def close(self) -> None:
        """Close the socket (idempotent)."""
        self.closed = True
        try:
            self.sock.close()
        except OSError:
            pass


class SocketListener:
    """Follower-side localhost listener: binds an ephemeral port
    (``port=0``) and accepts the leader's single connection."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(1)
        self.host, self.port = self._sock.getsockname()[:2]

    def accept(self, timeout: float = 30.0) -> SocketEnd:
        """Block (up to `timeout`) for the leader to connect; returns
        the follower's `SocketEnd`."""
        self._sock.settimeout(timeout)
        conn, _ = self._sock.accept()
        return SocketEnd(conn)

    def close(self) -> None:
        """Stop listening (established ends stay usable)."""
        try:
            self._sock.close()
        except OSError:
            pass


def connect(host: str, port: int, timeout: float = 30.0) -> SocketEnd:
    """Leader-side dial: connect to a follower's `SocketListener` and
    return the leader's `SocketEnd`."""
    return SocketEnd(socket.create_connection((host, port), timeout=timeout))


# --------------------------------------------------------------------------
# leader
# --------------------------------------------------------------------------

class _FollowerHandle:
    """Leader-side per-follower state: its transport end, its shipping
    tailer, and the ack-derived lag accounting."""

    def __init__(self, end, cursor: Cursor):
        self.end = end
        self.tailer: WAL.WalTailer
        self.base_offset = cursor.offset
        self.acked_seqno = (cursor.next_seqno - 1
                            if cursor.next_seqno is not None else -1)
        self.acked_bytes = 0
        self.sent_records = 0
        self.sent_bytes = 0
        self.retransmits = 0
        self.dead = False


class Leader:
    """Replication source wrapped around one durable driver.

    ``Leader(drv)`` claims ``drv.replication`` (so `repro.serve` pumps
    shipping between windows); `add_follower` bootstraps + attaches an
    in-process follower in one call, while `bootstrap` + `attach` wire
    a remote one over any transport end. `pump` (= `ship` + ack drain)
    only ever reads *durable* WAL bytes — the leader's log-before-ack
    guarantee is untouched, and nothing a follower applies can be
    un-acked on the leader."""

    def __init__(self, drv):
        if drv.durability is None:
            raise ValueError("replication requires a durable leader: "
                             "construct the engine with durability=...")
        self.drv = drv
        self.handles: List[_FollowerHandle] = []
        drv.replication = self

    # -- wiring -------------------------------------------------------------
    def bootstrap(self, dst_dir) -> Cursor:
        """Initial sync: copy the newest snapshot (if any) plus every
        well-formed WAL frame past its watermark into `dst_dir`, and
        return the `Cursor` where shipping to that follower starts.
        The copied tail preserves the leader's frame bytes verbatim, so
        the follower's log begins as a bitwise slice of the leader's."""
        dur = self.drv.durability
        dur.sync()
        dst = Path(dst_dir)
        dst.mkdir(parents=True, exist_ok=True)
        records, good = WAL.read_wal(dur.wal_path)
        watermark = -1
        snaps = WAL.list_snapshots(dur.dir)
        if snaps:
            num, spath = snaps[-1]
            shutil.copytree(spath, dst / spath.name, dirs_exist_ok=True)
            watermark = num
        tail_start = good
        for rec, start, _end in WAL.record_offsets(dur.wal_path):
            if rec.seqno > watermark:
                tail_start = start
                break
        data = dur.wal_path.read_bytes()[:good] if dur.wal_path.exists() \
            else WAL.MAGIC
        (dst / "wal.log").write_bytes(WAL.MAGIC + data[tail_start:])
        if records:
            nxt, epoch = records[-1].seqno + 1, records[-1].epoch
        elif watermark >= 0:
            nxt, epoch = watermark + 1, 0
        else:
            nxt, epoch = None, 0
        return Cursor(good, nxt, epoch)

    def attach(self, end, cursor: Optional[Cursor] = None) -> _FollowerHandle:
        """Start shipping to transport `end` from `cursor` (default:
        genesis — the whole log, META included). Returns the handle
        `stats()` reports lag for."""
        if cursor is None:
            cursor = Cursor(len(WAL.MAGIC), None, 0)
        h = _FollowerHandle(end, cursor)
        h.tailer = WAL.WalTailer(self.drv.durability.wal_path,
                                 offset=cursor.offset,
                                 next_seqno=cursor.next_seqno,
                                 epoch=cursor.epoch)
        self.handles.append(h)
        return h

    def add_follower(self, directory, *, driver: Optional[str] = None,
                     fsync: bool = False) -> "Follower":
        """Bootstrap `directory`, open a `Follower` over it, and attach
        it through an in-process `QueueLink` (reachable as
        ``follower.link`` for fault injection). `driver` defaults to
        the leader's own kind."""
        cursor = self.bootstrap(directory)
        if driver is None:
            driver = ("sharded" if isinstance(self.drv, ShardedSLSM)
                      else "single")
        link = QueueLink()
        fol = Follower(directory, link.follower, driver=driver, fsync=fsync)
        fol.link = link
        self.attach(link.leader, cursor)
        return fol

    def detach(self, handle: _FollowerHandle) -> None:
        """Stop shipping to `handle` (its transport end is closed)."""
        if handle in self.handles:
            self.handles.remove(handle)
        try:
            handle.end.close()
        except OSError:
            pass

    # -- shipping -----------------------------------------------------------
    def _offset_of(self, seqno: int) -> Optional[Cursor]:
        """Locate `seqno` in the leader's WAL for a retransmit rewind."""
        for rec, start, _end in WAL.record_offsets(
                self.drv.durability.wal_path):
            if rec.seqno == seqno:
                return Cursor(start, seqno, 0)
        return None

    def ship(self, max_records: Optional[int] = None) -> int:
        """Tail the durable log and send each new frame verbatim to
        every live follower; then drain acks (a gap ack rewinds that
        follower's cursor — retransmission, with duplicates dropped by
        the follower's seqno filter). Returns frames sent."""
        n = 0
        for h in self.handles:
            if h.dead:
                continue
            polled = h.tailer.poll(max_records)
            if polled:
                try:
                    h.end.send_frames([f for _, f in polled])
                except (BrokenPipeError, OSError):
                    h.dead = True
                    continue
                h.sent_records += len(polled)
                h.sent_bytes += sum(len(f) for _, f in polled)
                n += len(polled)
        self._drain_acks()
        return n

    def _drain_acks(self) -> None:
        for h in self.handles:
            if h.dead:
                continue
            try:
                acks = h.end.recv_acks()
            except (BrokenPipeError, OSError):
                h.dead = True
                continue
            for seqno, nbytes, gap in acks:
                if seqno > h.acked_seqno:
                    h.acked_seqno = seqno
                if nbytes > h.acked_bytes:
                    h.acked_bytes = nbytes
                if gap:
                    cur = self._offset_of(seqno + 1)
                    if cur is not None:
                        h.tailer.rewind(cur.offset, cur.next_seqno, cur.epoch)
                        h.retransmits += 1

    def pump(self) -> int:
        """One replication turn: ship new frames + drain acks (the hook
        `repro.serve.Server.pump` drives between windows)."""
        return self.ship()

    # -- telemetry ----------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Leader-side replication telemetry. ``follower_lag_records``
        / ``follower_lag_bytes`` are the *worst* follower's distance
        behind the leader's durable log (ack-derived; per-follower
        detail under ``per_follower``)."""
        w = self.drv.durability.writer
        last, size = w.last_seqno, w.size
        per = []
        for h in self.handles:
            lag_r = max(0, last - h.acked_seqno)
            lag_b = max(0, size - (h.base_offset + h.acked_bytes))
            per.append({"acked_seqno": int(h.acked_seqno),
                        "lag_records": int(lag_r),
                        "lag_bytes": int(lag_b),
                        "sent_records": int(h.sent_records),
                        "sent_bytes": int(h.sent_bytes),
                        "retransmits": int(h.retransmits),
                        "alive": not h.dead})
        return {
            "role": "leader",
            "followers": len(per),
            "last_seqno": int(last),
            "wal_bytes": int(size),
            "shipped_records": int(sum(h.sent_records for h in self.handles)),
            "shipped_bytes": int(sum(h.sent_bytes for h in self.handles)),
            "follower_lag_records": max((p["lag_records"] for p in per),
                                        default=0),
            "follower_lag_bytes": max((p["lag_bytes"] for p in per),
                                      default=0),
            "per_follower": per,
        }


# --------------------------------------------------------------------------
# follower
# --------------------------------------------------------------------------

class Follower:
    """Replication sink: a replica engine plus the apply loop.

    Opens `directory` (a `Leader.bootstrap` product — or a promoted
    follower's own dir on restart) via the engine's ``open_replica``,
    then each `apply`/`pump`: receive frames, validate every one with
    `wal.check_frame` (a corrupted frame is counted ``rejected`` and
    dropped *without poisoning the stream* — later frames still
    apply), drop duplicates (seqno ≤ applied watermark), buffer
    out-of-order arrivals by seqno, and apply each consecutive frame:
    append verbatim to the replica WAL, group-commit, replay through
    the engine's chunk-apply programs, ack ``(seqno, bytes)``. A gap
    (buffered frames with the next-expected one missing) is signalled
    on the ack so the leader rewinds and retransmits.

    Reads (`lookup_many` / `range_many` / `aggregate_many` on ``drv``)
    are eventually consistent at the applied watermark. `promote` is
    the failover exit: returns the engine as a writable leader."""

    def __init__(self, directory, end=None, *, driver: str = "single",
                 fsync: bool = False):
        cls = ShardedSLSM if driver == "sharded" else SLSM
        self.drv = cls.open_replica(directory, fsync=fsync)
        self.drv.replication = self
        self.end = end
        self.link: Optional[QueueLink] = None   # set by Leader.add_follower
        self.pending: Dict[int, Tuple[WAL.WalRecord, bytes]] = {}
        self.promoted = False
        self.counters = collections.Counter(
            applied_records=0, applied_bytes=0, duplicates=0, rejected=0,
            gap_signals=0, buffered_peak=0)

    @property
    def last_seqno(self) -> int:
        """The applied (and durable) watermark: seqno of the last
        record in the replica's WAL."""
        return self.drv.durability.writer.last_seqno

    def ingest(self, frames: List[bytes],
               max_records: Optional[int] = None) -> int:
        """Feed raw frames through the full apply pipeline (the
        transport-free seam the fault tests drive directly). Returns
        records applied."""
        if self.promoted:
            return 0
        dur = self.drv.durability
        for f in frames:
            rec = WAL.check_frame(f)
            if rec is None:
                self.counters["rejected"] += 1
                continue
            if rec.seqno <= self.last_seqno or rec.seqno in self.pending:
                self.counters["duplicates"] += 1
                continue
            self.pending[rec.seqno] = (rec, f)
        applied = 0
        while self.pending and (max_records is None
                                or applied < max_records):
            item = self.pending.pop(self.last_seqno + 1, None)
            if item is None:
                break
            rec, f = item
            try:
                dur.append_frame(f)
            except ValueError:          # epoch regression / stale frame
                self.counters["rejected"] += 1
                continue
            self.drv.apply_replicated([rec])
            self.counters["applied_records"] += 1
            self.counters["applied_bytes"] += len(f)
            applied += 1
        self.counters["buffered_peak"] = max(self.counters["buffered_peak"],
                                             len(self.pending))
        if applied:
            dur.sync()
        gap = bool(self.pending
                   and min(self.pending) > self.last_seqno + 1)
        if (applied or gap) and self.end is not None:
            if gap:
                self.counters["gap_signals"] += 1
            try:
                self.end.send_ack(self.last_seqno,
                                  self.counters["applied_bytes"], gap=gap)
            except (BrokenPipeError, OSError):
                pass                    # leader gone; promote() decides
        return applied

    def apply(self, max_records: Optional[int] = None) -> int:
        """Receive from the transport and `ingest`. Returns records
        applied (0 when detached or already promoted)."""
        if self.end is None or self.promoted:
            return 0
        return self.ingest(self.end.recv_frames(), max_records)

    def pump(self) -> int:
        """One replication turn (the `repro.serve` hook): = `apply`."""
        return self.apply()

    def promote(self):
        """Failover: make this follower the leader. Unacked buffered
        frames are dropped (never acked ⇒ never durable anywhere —
        clients were never told they happened), the transport is
        detached, and the engine's ``promote()`` bumps the WAL epoch
        and re-enables local logging, so the seqno stream resumes right
        after the last applied record and any stale pre-failover bytes
        the reused log file might expose later are rejected by the
        prefix rule's epoch check. Returns the now-writable engine."""
        self.pending.clear()
        if self.end is not None:
            try:
                self.end.close()
            except OSError:
                pass
            self.end = None
        self.promoted = True
        drv = self.drv.promote()
        drv.replication = None
        return drv

    def stats(self) -> Dict[str, Any]:
        """Follower-side replication telemetry: applied watermark,
        reorder-buffer occupancy, and the duplicate/reject counters."""
        return {
            "role": "follower",
            "promoted": self.promoted,
            "applied_seqno": int(self.last_seqno),
            "reorder_buffered": len(self.pending),
            **{k: int(v) for k, v in self.counters.items()},
        }


def converge(leader: Leader, *followers: Follower,
             max_rounds: int = 1000) -> int:
    """Pump `leader` and `followers` until every follower's ack says it
    has applied the leader's whole durable log (lag 0). Returns rounds
    used; raises RuntimeError when `max_rounds` pumps don't converge
    (e.g. a severed link)."""
    for r in range(max_rounds):
        leader.pump()
        for f in followers:
            f.pump()
        leader.pump()                   # drain the acks just sent
        if leader.stats()["follower_lag_records"] == 0:
            return r + 1
    raise RuntimeError("replication did not converge: "
                       + json.dumps(leader.stats()))
