"""Single-leader replication over the WAL (DESIGN.md §14–§15).

The durability layer's WAL (DESIGN.md §12) is already a replication
log: CRC-framed records with strictly-consecutive seqnos, a snapshot
codec with a seqno watermark, and replay through the engines' existing
chunk-apply programs. This module ships that stream:

  * the **leader** is any durable driver (`SLSM` / `ShardedSLSM`): a
    `Leader` wraps it, `bootstrap` copies its newest snapshot + WAL
    tail into a follower directory (the initial sync), and `ship`
    tails the leader's *durable* log bytes (`wal.WalTailer`) and sends
    each frame verbatim over a pluggable transport;
  * a **follower** opens that directory via ``open_replica`` (a plain
    `restore` under a replica-mode durability layer), then `apply`s
    incoming frames: validate (`wal.check_frame`), de-duplicate and
    reorder by seqno (in a *bounded* buffer), append verbatim
    (`Durability.append_frame` — the follower's WAL stays a bitwise
    copy of the leader's stream), sync, replay through
    `apply_replicated`, and ack;
  * transports are an in-process `QueueLink` (tests inject faults by
    mutating its deques) and a localhost socket pair
    (`SocketListener` / `connect` → `SocketEnd`, length-prefixed
    messages whose torn tails drop with the connection); both raise a
    typed `TransportError` on a severed link, and connect/accept retry
    with exponential backoff + jitter up to a deadline.

Self-healing (DESIGN.md §15) closes the failover loop:

  * **leases** — the leader stamps heartbeat control messages into the
    ship stream (`T_CTRL`, never a logged WAL record): its epoch,
    durable watermark, the lease duration, ack mode/quorum, and the
    ack roster. A follower holds a lease on a *monotonic clock* from
    each heartbeat; when the lease expires, the deterministic
    successor rule — highest *rostered* ack, lowest follower id on
    ties, evaluated over the last roster ONLY (never a follower's own
    live watermark, which would differ per follower and split-brain) —
    elects exactly one follower among those sharing a roster, which
    `promote(lead=True)`s automatically. Losers re-arm a *fallback*
    lease instead of disarming: each further expiry with no heartbeat
    peels one rank off the succession order, so if the designated
    successor died in the same failure the next-ranked follower
    eventually promotes instead of leaving the cluster leaderless.
  * **epoch fencing** — acks carry the acker's WAL epoch. A promoted
    successor adopts its old transport end as a *fence end*: any frame
    the deposed leader still ships is answered with an ack at the
    bumped epoch, and every live follower likewise acks at the epoch
    it applies. The deposed leader sees ``ack.epoch > own epoch``,
    marks itself `deposed`, fences its engine against writes
    (``drv.fenced``), and `demote()`s — rejoining is a fresh
    `bootstrap` from the new leader (the engines' write guard makes a
    partitioned deposed leader *reject* writes instead of diverging).
  * **quorum acks** — ``Leader(ack_mode="quorum", quorum=k)`` exposes
    `quorum_seqno()`, the k-th highest *advertised* live follower ack
    — the ack values the last heartbeat roster carried (an eager
    heartbeat fires whenever newly drained acks would advance the
    quorum, so advertising costs one control message, not a cadence
    wait). The serving layer holds client write acks until the commit
    watermark clears it. Gating on advertised acks is what makes the
    roster-only successor rule zero-RPO: a released write is covered
    by k roster entries, the roster maximum is ≥ the quorum watermark,
    and the elected successor holds everything its own roster entry
    covers.
  * **watermark-bounded pruning** — `Leader.prune()` truncates sealed
    WAL segments below min(newest snapshot watermark, minimum ack over
    attached followers — including dead ones within ``dead_grace_s``
    of their failure), so `bootstrap` of any attached follower always
    finds its tail; late joiners bootstrap from snapshot + retained
    tail. A handle dead past the grace is auto-detached so a
    permanently gone follower cannot pin disk growth forever — if it
    ever returns, the pruned-cursor check forces a fresh bootstrap.

Consistency model: read-your-writes on the leader (the driver's
log-before-ack group commit is untouched — replication ships only
*durable* bytes, so nothing a follower applies can ever be un-acked on
the leader; in quorum mode client acks are additionally held for k
follower confirmations); followers are eventually consistent and serve
the batched read paths (`lookup_many` / `range_many`) at their applied
watermark. Lag is bounded and observable: `Leader.stats()` reports
``follower_lag_records`` / ``follower_lag_bytes`` from follower acks.

The fault-injection suite (``tests/replication/``) proves answer-exact
failover under leader SIGKILL, torn stream tails, duplicated /
reordered / dropped delivery, mid-RETUNE cuts, lease expiry, live
deposed-leader partitions, quorum loss, and prune races, on both
drivers × both backends. Leases are cooperative failure detection, not
consensus: the successor rule is deterministic given a roster — all
followers holding the same roster elect exactly one — and epoch
fencing converges a deposed predecessor, but a *partially delivered*
roster update (some followers saw the newest heartbeat, some did not)
can still elect divergent winners, and clients of a deposed leader can
read stale data until its next ack round-trip. Closing those holes
needs real consensus, which this layer deliberately is not.
"""
from __future__ import annotations

import collections
import json
import random
import select
import shutil
import socket
import struct
import time
from pathlib import Path
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

from repro.engine import wal as WAL
from repro.engine.engine import SLSM
from repro.engine.sharded import ShardedSLSM

# stream message framing (byte-stream transports): type u8 | len u32 | payload
_MSG = struct.Struct("<BI")
# applied seqno i64 | applied bytes u64 | gap u8 | acker's WAL epoch u8
_ACK = struct.Struct("<qQBB")
T_FRAME = 1                         # payload = one verbatim WAL frame
T_ACK = 2                           # payload = _ACK
T_CTRL = 3                          # payload = json heartbeat/lease message


class TransportError(ConnectionError):
    """A replication transport failed: the peer is gone, the link was
    severed, or a dial/accept deadline expired. Subclasses
    `ConnectionError` so pre-existing ``except OSError`` paths keep
    working; the leader's `ship` converts it into detach (and later
    `reattach`) instead of letting it escape a pump."""


class Cursor(NamedTuple):
    """A shipping position in the leader's WAL: byte `offset` (the
    leader-log bytes already covered at bootstrap — lag-bytes
    accounting only; shipping itself is seqno-addressed), the
    `next_seqno` expected (None = accept any first record), and the
    minimum `epoch` of subsequent frames."""

    offset: int
    next_seqno: Optional[int]
    epoch: int = 0


# --------------------------------------------------------------------------
# transports
# --------------------------------------------------------------------------

class QueueEnd:
    """One end of a `QueueLink`. The leader end uses
    `send_frames`/`send_ctrl`/`recv_acks`; the follower end
    `recv_frames`/`recv_ctrl`/`send_ack`. Setting ``closed`` simulates
    a severed link (sends raise `TransportError`, receives return
    nothing) — the partition fault tests flip it directly."""

    def __init__(self, link: "QueueLink", is_leader: bool):
        self.link = link
        self.is_leader = is_leader
        self.closed = False

    def _check_open(self) -> None:
        if self.closed:
            raise TransportError("replication link closed")

    def send_frames(self, frames: List[bytes]) -> None:
        """Enqueue raw WAL frames toward the follower."""
        self._check_open()
        self.link.frames.extend(frames)

    def recv_frames(self) -> List[bytes]:
        """Drain every in-flight frame (empty when closed)."""
        if self.closed:
            return []
        out = list(self.link.frames)
        self.link.frames.clear()
        return out

    def send_ack(self, seqno: int, nbytes: int, gap: bool = False,
                 epoch: int = 0) -> None:
        """Enqueue one follower ack toward the leader."""
        self._check_open()
        self.link.acks.append((seqno, nbytes, gap, epoch))

    def recv_acks(self) -> List[Tuple[int, int, bool, int]]:
        """Drain every in-flight ``(applied_seqno, applied_bytes, gap,
        epoch)`` (legacy 3-tuples injected by tests decode as epoch
        0)."""
        if self.closed:
            return []
        out = [tuple(a) + (0,) * (4 - len(a)) for a in self.link.acks]
        self.link.acks.clear()
        return out

    def send_ctrl(self, msg: Dict[str, Any]) -> None:
        """Enqueue one heartbeat/lease control message (leader →
        follower; never a logged WAL record)."""
        self._check_open()
        self.link.ctrl.append(dict(msg))

    def recv_ctrl(self) -> List[Dict[str, Any]]:
        """Drain every in-flight control message."""
        if self.closed:
            return []
        out = list(self.link.ctrl)
        self.link.ctrl.clear()
        return out

    def close(self) -> None:
        """Sever this end of the link."""
        self.closed = True


class QueueLink:
    """In-process transport: a leader end and a follower end over three
    deques. The wire is inspectable — ``frames`` holds raw frame bytes
    heading to the follower, ``acks`` the ack tuples heading back,
    ``ctrl`` the heartbeat messages — so fault tests duplicate,
    reorder, drop, or bit-flip in-flight traffic by mutating the
    deques between pumps."""

    def __init__(self):
        self.frames: collections.deque = collections.deque()
        self.acks: collections.deque = collections.deque()
        self.ctrl: collections.deque = collections.deque()
        self.leader = QueueEnd(self, is_leader=True)
        self.follower = QueueEnd(self, is_leader=False)


class SocketEnd:
    """One end of a localhost replication stream.

    Messages are length-prefixed (``type u8 | len u32 | payload``); a
    partially received message — the torn stream tail a dying peer
    leaves — stays buffered and is dropped with the connection, the
    transport-level mirror of the WAL's torn-tail rule. Receives are
    non-blocking (`select`-gated drains) into per-type inboxes, so
    draining frames never discards a control message that arrived in
    the same burst; sends are blocking and raise `TransportError` on a
    dead peer."""

    def __init__(self, sock: socket.socket):
        sock.setblocking(True)
        self.sock = sock
        self.closed = False
        self._buf = b""
        self._in: Dict[int, List[bytes]] = {T_FRAME: [], T_ACK: [],
                                            T_CTRL: []}

    def _pump(self) -> None:
        while not self.closed:
            try:
                r, _, _ = select.select([self.sock], [], [], 0)
            except (OSError, ValueError):
                self.closed = True
                return
            if not r:
                return
            try:
                data = self.sock.recv(1 << 16)
            except OSError:
                data = b""
            if not data:
                self.closed = True
                return
            self._buf += data

    def _drain(self) -> None:
        self._pump()
        off = 0
        while off + _MSG.size <= len(self._buf):
            t, n = _MSG.unpack_from(self._buf, off)
            if off + _MSG.size + n > len(self._buf):
                break                   # torn tail: stays pending
            if t in self._in:
                self._in[t].append(self._buf[off + _MSG.size:
                                             off + _MSG.size + n])
            off += _MSG.size + n
        self._buf = self._buf[off:]

    def _take(self, t: int) -> List[bytes]:
        self._drain()
        out, self._in[t] = self._in[t], []
        return out

    def send_frames(self, frames: List[bytes]) -> None:
        """Send raw WAL frames, one message each, in one write."""
        self._send(b"".join(_MSG.pack(T_FRAME, len(f)) + f for f in frames))

    def send_ack(self, seqno: int, nbytes: int, gap: bool = False,
                 epoch: int = 0) -> None:
        """Send one ``(applied_seqno, applied_bytes, gap, epoch)`` ack."""
        self._send(_MSG.pack(T_ACK, _ACK.size)
                   + _ACK.pack(seqno, nbytes, 1 if gap else 0, epoch & 0xFF))

    def send_ctrl(self, msg: Dict[str, Any]) -> None:
        """Send one json heartbeat/lease control message."""
        blob = json.dumps(msg).encode()
        self._send(_MSG.pack(T_CTRL, len(blob)) + blob)

    def _send(self, blob: bytes) -> None:
        if self.closed:
            raise TransportError("replication stream closed")
        try:
            self.sock.sendall(blob)
        except OSError as e:
            self.closed = True
            raise TransportError(f"replication peer gone: {e}") from e

    def recv_frames(self) -> List[bytes]:
        """Drain every fully received frame message."""
        return self._take(T_FRAME)

    def recv_acks(self) -> List[Tuple[int, int, bool, int]]:
        """Drain every fully received ack message."""
        return [(s, b, bool(g), e) for p in self._take(T_ACK)
                if len(p) == _ACK.size
                for s, b, g, e in (_ACK.unpack(p),)]

    def recv_ctrl(self) -> List[Dict[str, Any]]:
        """Drain every fully received control message (malformed json
        is dropped — control traffic is advisory, never durable)."""
        out = []
        for p in self._take(T_CTRL):
            try:
                msg = json.loads(p.decode())
            except (UnicodeDecodeError, ValueError):
                continue
            if isinstance(msg, dict):
                out.append(msg)
        return out

    def close(self) -> None:
        """Close the socket (idempotent)."""
        self.closed = True
        try:
            self.sock.close()
        except OSError:
            pass


class SocketListener:
    """Follower-side localhost listener: binds an ephemeral port
    (``port=0``) and accepts the leader's single connection."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(1)
        self.host, self.port = self._sock.getsockname()[:2]

    def accept(self, timeout: float = 30.0) -> SocketEnd:
        """Wait (up to the `timeout` deadline) for the leader to
        connect, retrying transient accept failures with exponential
        backoff + jitter instead of dying on the first `OSError`.
        Raises `TransportError` when the deadline expires."""
        deadline = time.monotonic() + timeout
        delay, attempts = 0.05, 0
        rng = random.Random(self.port)
        while True:
            attempts += 1
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TransportError(
                    f"accept on :{self.port} timed out after "
                    f"{attempts - 1} attempts ({timeout:.1f}s)")
            self._sock.settimeout(min(max(delay, 0.05), remaining))
            try:
                conn, _ = self._sock.accept()
                return SocketEnd(conn)
            except socket.timeout:
                continue                # the deadline check bounds us
            except OSError:
                # transient accept failure: back off with jitter
                time.sleep(min(delay * (0.5 + rng.random()),
                               max(0.0, deadline - time.monotonic())))
                delay = min(delay * 2, 2.0)

    def close(self) -> None:
        """Stop listening (established ends stay usable)."""
        try:
            self._sock.close()
        except OSError:
            pass


def connect(host: str, port: int, timeout: float = 30.0) -> SocketEnd:
    """Leader-side dial: connect to a follower's `SocketListener`,
    retrying refused/failed attempts with exponential backoff + jitter
    until the `timeout` deadline (a follower that is still binding its
    listener is the common transient). Raises `TransportError` when
    the deadline expires."""
    deadline = time.monotonic() + timeout
    delay, attempts = 0.05, 0
    rng = random.Random(port)
    last: Optional[OSError] = None
    while True:
        attempts += 1
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TransportError(
                f"connect to {host}:{port} failed after {attempts - 1} "
                f"attempts ({timeout:.1f}s): {last}")
        try:
            return SocketEnd(socket.create_connection(
                (host, port), timeout=min(max(delay, 0.05), remaining)))
        except OSError as e:
            last = e
            time.sleep(min(delay * (0.5 + rng.random()),
                           max(0.0, deadline - time.monotonic())))
            delay = min(delay * 2, 2.0)


# --------------------------------------------------------------------------
# leader
# --------------------------------------------------------------------------

class _FollowerHandle:
    """Leader-side per-follower state: its id, transport end, shipping
    tailer, and the ack-derived lag accounting."""

    def __init__(self, end, cursor: Cursor, fid: int = 0):
        self.end = end
        self.fid = fid
        self.tailer: WAL.WalTailer
        self.base_offset = cursor.offset
        self.acked_seqno = (cursor.next_seqno - 1
                            if cursor.next_seqno is not None else -1)
        # the ack value the last heartbeat roster carried for this
        # follower (init: the bootstrap watermark, durable there by
        # construction) — quorum commits gate on this, never on a
        # fresher ack the successor rule has not seen
        self.advertised_seqno = self.acked_seqno
        self.acked_bytes = 0
        self.sent_records = 0
        self.sent_bytes = 0
        self.retransmits = 0
        self.dead = False
        self.dead_since: Optional[float] = None
        self.needs_bootstrap = False    # its cursor fell behind a prune


class Leader:
    """Replication source wrapped around one durable driver.

    ``Leader(drv)`` claims ``drv.replication`` (so `repro.serve` pumps
    shipping between windows); `add_follower` bootstraps + attaches an
    in-process follower in one call, while `bootstrap` + `attach` wire
    a remote one over any transport end. `pump` (= heartbeats + `ship`
    + ack drain + fence replies) only ever reads *durable* WAL bytes —
    the leader's log-before-ack guarantee is untouched, and nothing a
    follower applies can ever be un-acked on the leader.

    ``ack_mode="quorum"`` with ``quorum=k`` does not change shipping —
    it exposes `quorum_seqno()` (the k-th highest *advertised* live
    follower ack, -1 on quorum loss) for the serving layer to gate
    client write acks on (DESIGN.md §15). Advertised = carried by the
    last heartbeat roster, so the successor rule's input always covers
    every released write; `pump` heartbeats eagerly when fresh acks
    would advance the quorum, keeping the added ack latency to one
    control message rather than a heartbeat cadence.

    ``lease_s``/``heartbeat_s`` drive the failure detector: every
    `pump` at most one heartbeat control message per `heartbeat_s`
    (default ``lease_s / 4``) is sent to each follower, carrying the
    lease duration and the ack roster the successor rule runs on.

    A leader that observes an ack at a *higher epoch than its own* has
    been deposed by an automatic failover: it stops shipping, fences
    its engine (writes raise), and should `demote()` + rejoin via the
    new leader's `bootstrap`."""

    def __init__(self, drv, *, ack_mode: str = "leader", quorum: int = 1,
                 lease_s: float = 2.0, heartbeat_s: Optional[float] = None,
                 dead_grace_s: Optional[float] = None,
                 clock=time.monotonic):
        if drv.durability is None:
            raise ValueError("replication requires a durable leader: "
                             "construct the engine with durability=...")
        if ack_mode not in ("leader", "quorum"):
            raise ValueError(f"unknown ack_mode {ack_mode!r} "
                             "(expected 'leader' or 'quorum')")
        self.drv = drv
        self.ack_mode = ack_mode
        self.quorum = int(quorum)
        self.lease_s = float(lease_s)
        self.heartbeat_s = (float(heartbeat_s) if heartbeat_s is not None
                            else self.lease_s / 4.0)
        # how long a dead handle's frozen ack may keep pinning the
        # prune floor before `prune` auto-detaches it (a permanently
        # gone follower must not make WAL growth unbounded again)
        self.dead_grace_s = (float(dead_grace_s) if dead_grace_s is not None
                             else 8.0 * self.lease_s)
        self.clock = clock
        self.handles: List[_FollowerHandle] = []
        self.fence_ends: List[Any] = []
        self.deposed = False
        self._next_fid = 0
        self._last_hb: Optional[float] = None
        self.counters = collections.Counter(
            heartbeats=0, detaches=0, reattaches=0, fence_acks=0,
            demotions=0, prune_calls=0, pruned_segments=0, pruned_cursors=0,
            expired_handles=0)
        drv.replication = self

    # -- wiring -------------------------------------------------------------
    def bootstrap(self, dst_dir) -> Cursor:
        """Initial sync: copy the newest snapshot (if any) plus every
        *retained* WAL frame past its watermark — across the whole
        segment chain — into `dst_dir`, and return the `Cursor` where
        shipping to that follower starts. The copied tail preserves the
        leader's frame bytes verbatim, so the follower's log begins as
        a bitwise slice of the leader's; a pruned leader log is fine,
        because `prune` never deletes past its snapshot watermark."""
        dur = self.drv.durability
        dur.sync()
        dst = Path(dst_dir)
        dst.mkdir(parents=True, exist_ok=True)
        watermark = -1
        snaps = WAL.list_snapshots(dur.dir)
        if snaps:
            num, spath = snaps[-1]
            shutil.copytree(spath, dst / spath.name, dirs_exist_ok=True)
            watermark = num
        frames = WAL.chain_frames(dur.dir, watermark + 1)
        (dst / "wal.log").write_bytes(WAL.MAGIC + b"".join(frames))
        last = dur.writer.last_seqno
        if last >= 0:
            nxt, epoch = last + 1, dur.writer.epoch
        elif watermark >= 0:
            nxt, epoch = watermark + 1, 0
        else:
            nxt, epoch = None, 0
        return Cursor(dur.log_bytes, nxt, epoch)

    def attach(self, end, cursor: Optional[Cursor] = None) -> _FollowerHandle:
        """Start shipping to transport `end` from `cursor` (default:
        genesis — the whole retained log, META included). Returns the
        handle `stats()` reports lag for."""
        if cursor is None:
            cursor = Cursor(len(WAL.MAGIC), None, 0)
        h = _FollowerHandle(end, cursor, fid=self._next_fid)
        self._next_fid += 1
        h.tailer = WAL.WalTailer(self.drv.durability.wal_path)
        if cursor.next_seqno is not None:
            # seqno-addressed start: the tailer relocates it across the
            # segment chain, wherever rolls/prunes left it
            h.tailer.rewind_to(cursor.next_seqno, cursor.epoch)
        self.handles.append(h)
        return h

    def add_follower(self, directory, *, driver: Optional[str] = None,
                     fsync: bool = False, **fol_kw) -> "Follower":
        """Bootstrap `directory`, open a `Follower` over it, and attach
        it through an in-process `QueueLink` (reachable as
        ``follower.link`` for fault injection). `driver` defaults to
        the leader's own kind; extra keywords (``auto_promote``,
        ``clock``, ``pending_max``) pass through to `Follower`."""
        cursor = self.bootstrap(directory)
        if driver is None:
            driver = ("sharded" if isinstance(self.drv, ShardedSLSM)
                      else "single")
        link = QueueLink()
        fol = Follower(directory, link.follower, driver=driver, fsync=fsync,
                       **fol_kw)
        fol.link = link
        self.attach(link.leader, cursor)
        return fol

    def detach(self, handle: _FollowerHandle) -> None:
        """Stop shipping to `handle` (its transport end is closed and
        its ack no longer holds back the prune floor)."""
        if handle in self.handles:
            self.handles.remove(handle)
            self.counters["detaches"] += 1
        try:
            handle.end.close()
        except OSError:
            pass

    def reattach(self, handle: _FollowerHandle, end=None) -> None:
        """Resume shipping to a handle `ship` marked dead (transport
        failure): optionally swap in a fresh transport `end`, rewind
        its cursor to the first un-acked seqno, and revive it. The
        follower's duplicate filter makes the overlap harmless."""
        if end is not None:
            handle.end = end
        handle.dead = False
        handle.dead_since = None
        handle.tailer.rewind_to(handle.acked_seqno + 1)
        if handle not in self.handles:
            self.handles.append(handle)
        self.counters["reattaches"] += 1

    def adopt_fence(self, end) -> None:
        """Keep a deposed predecessor's transport end as a *fence end*:
        `pump` answers anything it still ships with an ack at this
        leader's (bumped) epoch, which is how the old leader learns it
        was deposed (a promoted follower passes its old end here —
        `Follower.promote(lead=True)` does it automatically)."""
        self.fence_ends.append(end)

    # -- failure detection / leases ----------------------------------------
    def _mark_dead(self, h: _FollowerHandle) -> None:
        if not h.dead:
            h.dead = True
            h.dead_since = self.clock()
            self.counters["detaches"] += 1

    def _heartbeat(self, force: bool = False) -> None:
        """Send at most one lease heartbeat per `heartbeat_s` (always,
        when `force`d) to every live follower: epoch, durable
        watermark, lease duration, ack mode + quorum (so a promoted
        successor inherits them), the ack roster (the successor rule's
        input), and the receiver's own follower id. The roster values
        sent become the handles' ``advertised_seqno`` — the quorum
        commit watermark only ever advances over advertised acks."""
        if self.deposed or not self.handles:
            return
        now = self.clock()
        if (not force and self._last_hb is not None
                and now - self._last_hb < self.heartbeat_s):
            return
        self._last_hb = now
        w = self.drv.durability.writer
        roster = []
        for h in self.handles:
            if h.dead:
                continue
            h.advertised_seqno = int(h.acked_seqno)
            roster.append([h.fid, h.advertised_seqno])
        base = {"epoch": int(w.epoch), "last_seqno": int(w.last_seqno),
                "lease_s": self.lease_s, "ack_mode": self.ack_mode,
                "quorum": int(self.quorum), "roster": roster}
        for h in self.handles:
            if h.dead:
                continue
            try:
                h.end.send_ctrl({**base, "you": h.fid})
            except (TransportError, OSError):
                self._mark_dead(h)
        self.counters["heartbeats"] += 1

    def _kth_live_ack(self, advertised: bool) -> int:
        """The k-th highest live follower ack (-1 below quorum), over
        advertised or live ack values."""
        acks = sorted((h.advertised_seqno if advertised else h.acked_seqno
                       for h in self.handles if not h.dead), reverse=True)
        if len(acks) < self.quorum:
            return -1
        return int(acks[self.quorum - 1])

    def quorum_seqno(self) -> int:
        """The replication commit watermark: in quorum mode, the k-th
        highest *advertised* live follower ack (-1 while fewer than k
        followers are live — quorum loss, nothing new may be
        client-acked); in leader mode, simply the leader's durable
        watermark. Advertised (not live) acks keep RPO 0 under the
        roster-only successor rule: a write is only client-acked once
        the roster carrying its covering acks has been broadcast, so
        whichever follower the roster elects holds the write."""
        if self.ack_mode != "quorum":
            return int(self.drv.durability.writer.last_seqno)
        return self._kth_live_ack(advertised=True)

    # -- shipping -----------------------------------------------------------
    def ship(self, max_records: Optional[int] = None) -> int:
        """Tail the durable log and send each new frame verbatim to
        every live follower; then drain acks (a gap ack rewinds that
        follower's cursor by seqno — retransmission, with duplicates
        dropped by the follower's filter). A transport failure marks
        the handle dead (`reattach` revives it); a cursor that fell
        behind the prune floor flags ``needs_bootstrap``. Returns
        frames sent (always 0 once deposed — a fenced leader ships
        nothing)."""
        n = 0
        if not self.deposed:
            for h in self.handles:
                if h.dead:
                    continue
                polled = h.tailer.poll(max_records)
                if h.tailer.pruned_gap:
                    # only possible for a handle attached after pruning
                    # ran (attached acks floor `prune`): force a fresh
                    # bootstrap instead of shipping a gapped stream
                    self._mark_dead(h)
                    h.needs_bootstrap = True
                    self.counters["pruned_cursors"] += 1
                    continue
                if polled:
                    try:
                        h.end.send_frames([f for _, f in polled])
                    except (TransportError, OSError):
                        self._mark_dead(h)
                        continue
                    h.sent_records += len(polled)
                    h.sent_bytes += sum(len(f) for _, f in polled)
                    n += len(polled)
        self._drain_acks()
        return n

    def _drain_acks(self) -> None:
        my_epoch = self.drv.durability.writer.epoch
        for h in self.handles:
            if h.dead:
                continue
            try:
                acks = h.end.recv_acks()
            except (TransportError, OSError):
                self._mark_dead(h)
                continue
            for seqno, nbytes, gap, epoch in acks:
                if epoch > my_epoch:
                    # an acker is already at a later epoch: an automatic
                    # failover deposed this leader while it was
                    # partitioned — fence the engine so no further write
                    # can be client-acked, then the caller demote()s
                    if not self.deposed:
                        self.deposed = True
                        self.drv.demote()
                    continue
                if seqno > h.acked_seqno:
                    h.acked_seqno = seqno
                if nbytes > h.acked_bytes:
                    h.acked_bytes = nbytes
                if gap:
                    h.tailer.rewind_to(seqno + 1)
                    h.retransmits += 1

    def _pump_fences(self) -> None:
        """Answer anything a deposed predecessor still ships on an
        adopted fence end with an ack at this leader's epoch (and drop
        its stale heartbeats)."""
        w = self.drv.durability.writer
        for end in list(self.fence_ends):
            try:
                frames = end.recv_frames()
                end.recv_ctrl()         # stale heartbeats: ignore
                if frames:
                    end.send_ack(int(w.last_seqno), 0, gap=False,
                                 epoch=int(w.epoch))
                    self.counters["fence_acks"] += 1
            except (TransportError, OSError):
                self.fence_ends.remove(end)

    # -- pruning ------------------------------------------------------------
    def prune(self) -> int:
        """Watermark-bounded WAL pruning (DESIGN.md §15): truncate
        sealed segments at or below min(newest snapshot watermark,
        minimum acked seqno over attached handles — dead ones included
        while they are within ``dead_grace_s`` of their failure, they
        may `reattach`). A handle dead *past* the grace is auto-
        detached first (counted ``expired_handles``): a permanently
        gone follower must not pin the floor — and disk growth —
        forever. If it ever comes back, its rewound cursor trips the
        pruned-gap check and it re-enters via a fresh bootstrap. No
        snapshot or a straggling live follower ⇒ nothing is pruned.
        Returns segments deleted."""
        now = self.clock()
        for h in list(self.handles):
            if (h.dead and h.dead_since is not None
                    and now - h.dead_since > self.dead_grace_s):
                self.detach(h)
                self.counters["expired_handles"] += 1
        dur = self.drv.durability
        floor = dur.prune_floor()
        for h in self.handles:
            floor = min(floor, h.acked_seqno)
        self.counters["prune_calls"] += 1
        if floor < 0:
            return 0
        n = dur.prune(floor)
        self.counters["pruned_segments"] += n
        return n

    def pump(self) -> int:
        """One replication turn: lease heartbeat + ship new frames +
        drain acks + fence replies (the hook `repro.serve.Server.pump`
        drives between windows). In quorum mode, acks just drained
        that would advance the commit watermark trigger an *eager*
        heartbeat — the quorum only commits over advertised acks, so
        advertising immediately keeps quorum ack latency at one pump
        instead of a heartbeat cadence."""
        self._heartbeat()
        n = self.ship()
        if (self.ack_mode == "quorum" and not self.deposed
                and self._kth_live_ack(advertised=False)
                > self._kth_live_ack(advertised=True)):
            self._heartbeat(force=True)
        self._pump_fences()
        return n

    def demote(self) -> Any:
        """Deposed-leader exit: detach every follower, close fence
        ends, fence the engine against writes (`drv.demote()` — writes
        raise until a future `promote()`), and release
        ``drv.replication``. Returns the now read-only engine;
        rejoining the cluster is a fresh `bootstrap` from the new
        leader into a new directory + `Follower` over it."""
        for h in list(self.handles):
            self.detach(h)
        for end in self.fence_ends:
            try:
                end.close()
            except OSError:
                pass
        self.fence_ends.clear()
        self.deposed = True
        self.counters["demotions"] += 1
        drv = self.drv
        drv.demote()
        drv.replication = None
        return drv

    # -- telemetry ----------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Leader-side replication telemetry. ``follower_lag_records``
        / ``follower_lag_bytes`` are the *worst* follower's distance
        behind the leader's durable log (ack-derived; per-follower
        detail under ``per_follower``); quorum/lease state and the
        self-healing counters ride along."""
        dur = self.drv.durability
        w = dur.writer
        last, size = w.last_seqno, dur.log_bytes
        per = []
        for h in self.handles:
            lag_r = max(0, last - h.acked_seqno)
            lag_b = max(0, size - (h.base_offset + h.acked_bytes))
            per.append({"fid": int(h.fid),
                        "acked_seqno": int(h.acked_seqno),
                        "advertised_seqno": int(h.advertised_seqno),
                        "lag_records": int(lag_r),
                        "lag_bytes": int(lag_b),
                        "sent_records": int(h.sent_records),
                        "sent_bytes": int(h.sent_bytes),
                        "retransmits": int(h.retransmits),
                        "needs_bootstrap": bool(h.needs_bootstrap),
                        "alive": not h.dead})
        return {
            "role": "deposed" if self.deposed else "leader",
            "followers": len(per),
            "last_seqno": int(last),
            "epoch": int(w.epoch),
            "wal_bytes": int(size),
            "ack_mode": self.ack_mode,
            "quorum": int(self.quorum),
            "quorum_seqno": self.quorum_seqno(),
            "lease_s": float(self.lease_s),
            "heartbeat_s": float(self.heartbeat_s),
            "deposed": bool(self.deposed),
            "fence_ends": len(self.fence_ends),
            "wal_pruned_bytes": int(dur.counters["wal_pruned_bytes"]),
            "wal_pruned_segments": int(dur.counters["wal_pruned_segments"]),
            "shipped_records": int(sum(h.sent_records for h in self.handles)),
            "shipped_bytes": int(sum(h.sent_bytes for h in self.handles)),
            "follower_lag_records": max((p["lag_records"] for p in per),
                                        default=0),
            "follower_lag_bytes": max((p["lag_bytes"] for p in per),
                                      default=0),
            "per_follower": per,
            **{k: int(v) for k, v in self.counters.items()},
        }


# --------------------------------------------------------------------------
# follower
# --------------------------------------------------------------------------

class Follower:
    """Replication sink: a replica engine plus the apply loop.

    Opens `directory` (a `Leader.bootstrap` product — or a promoted
    follower's own dir on restart) via the engine's ``open_replica``,
    then each `apply`/`pump`: receive control messages (lease
    heartbeats) and frames, validate every frame with
    `wal.check_frame` (a corrupted frame is counted ``rejected`` and
    dropped *without poisoning the stream* — later frames still
    apply), drop duplicates (seqno ≤ applied watermark), buffer
    out-of-order arrivals by seqno in a buffer bounded by
    ``pending_max`` (overflow evicts the highest seqnos — the ones a
    retransmit re-covers last — counts ``pending_overflow``, and
    forces an immediate gap ack so one leader round-trip heals it),
    and apply each consecutive frame: append verbatim to the replica
    WAL, group-commit, replay through the engine's chunk-apply
    programs, ack ``(seqno, bytes, gap, epoch)``.

    With ``auto_promote=True`` the follower runs the failure detector:
    each heartbeat renews a lease of the advertised duration on the
    monotonic `clock`; when the lease expires, the successor rule —
    highest rostered ack, lowest follower id on ties, evaluated over
    the last roster ONLY (a follower's live watermark differs per
    follower, so mixing it in would let several caught-up followers
    each elect themselves) — either promotes *this* follower
    (``promote(lead=True)``, the new `Leader` lands in ``new_leader``
    and fences the old stream) or stands down with a re-armed
    *fallback* lease: every further expiry with no heartbeat peels one
    rank off the succession order, so the next-ranked follower
    eventually promotes if the designated successor died too.

    Reads (`lookup_many` / `range_many` / `aggregate_many` on ``drv``)
    are eventually consistent at the applied watermark. `promote` is
    the failover exit: returns the engine as a writable leader."""

    def __init__(self, directory, end=None, *, driver: str = "single",
                 fsync: bool = False, auto_promote: bool = False,
                 pending_max: int = 512, clock=time.monotonic):
        cls = ShardedSLSM if driver == "sharded" else SLSM
        self.drv = cls.open_replica(directory, fsync=fsync)
        self.drv.replication = self
        self.end = end
        self.link: Optional[QueueLink] = None   # set by Leader.add_follower
        self.driver = driver
        self.auto_promote = auto_promote
        self.pending_max = int(pending_max)
        self.clock = clock
        self.pending: Dict[int, Tuple[WAL.WalRecord, bytes]] = {}
        self.promoted = False
        self.new_leader: Optional[Leader] = None
        self.fid: Optional[int] = None          # assigned by heartbeats
        self.roster: List[Tuple[int, int]] = []
        self.lease_s: Optional[float] = None
        self.lease_deadline: Optional[float] = None
        self.leader_epoch = 0
        self.leader_ack_mode = "leader"         # advertised by heartbeats:
        self.leader_quorum = 1                  # survives auto-promotion
        self._expiries_since_hb = 0
        self.counters = collections.Counter(
            applied_records=0, applied_bytes=0, duplicates=0, rejected=0,
            gap_signals=0, buffered_peak=0, pending_overflow=0,
            heartbeats_seen=0, lease_expiries=0, auto_promotions=0,
            standdowns=0)

    @property
    def last_seqno(self) -> int:
        """The applied (and durable) watermark: seqno of the last
        record in the replica's WAL."""
        return self.drv.durability.writer.last_seqno

    # -- apply path ---------------------------------------------------------
    def ingest(self, frames: List[bytes],
               max_records: Optional[int] = None) -> int:
        """Feed raw frames through the full apply pipeline (the
        transport-free seam the fault tests drive directly). Returns
        records applied."""
        if self.promoted:
            return 0
        dur = self.drv.durability
        overflowed = False
        for f in frames:
            rec = WAL.check_frame(f)
            if rec is None:
                self.counters["rejected"] += 1
                continue
            if rec.seqno <= self.last_seqno or rec.seqno in self.pending:
                self.counters["duplicates"] += 1
                continue
            if len(self.pending) >= self.pending_max:
                # bounded reorder buffer: keep the lowest seqnos (they
                # unblock the consecutive chain soonest), shed the
                # highest — the immediate gap ack below makes the
                # leader retransmit what was shed in one round-trip
                self.counters["pending_overflow"] += 1
                overflowed = True
                hi = max(self.pending)
                if rec.seqno >= hi:
                    continue            # incoming is the highest: drop it
                del self.pending[hi]
            self.pending[rec.seqno] = (rec, f)
        applied = 0
        while self.pending and (max_records is None
                                or applied < max_records):
            item = self.pending.pop(self.last_seqno + 1, None)
            if item is None:
                break
            rec, f = item
            try:
                dur.append_frame(f)
            except ValueError:          # epoch regression / stale frame
                self.counters["rejected"] += 1
                continue
            self.drv.apply_replicated([rec])
            self.counters["applied_records"] += 1
            self.counters["applied_bytes"] += len(f)
            applied += 1
        self.counters["buffered_peak"] = max(self.counters["buffered_peak"],
                                             len(self.pending))
        if applied:
            dur.sync()
        gap = overflowed or bool(self.pending
                                 and min(self.pending) > self.last_seqno + 1)
        if (applied or gap) and self.end is not None:
            if gap:
                self.counters["gap_signals"] += 1
            try:
                self.end.send_ack(self.last_seqno,
                                  self.counters["applied_bytes"], gap=gap,
                                  epoch=int(dur.writer.epoch))
            except (TransportError, OSError):
                pass                    # leader gone; the lease decides
        return applied

    def apply(self, max_records: Optional[int] = None) -> int:
        """Receive control messages + frames from the transport and
        `ingest`. Returns records applied (0 when detached or already
        promoted)."""
        if self.end is None or self.promoted:
            return 0
        for hb in self.end.recv_ctrl():
            self._on_heartbeat(hb)
        return self.ingest(self.end.recv_frames(), max_records)

    def pump(self) -> int:
        """One replication turn (the `repro.serve` hook): apply, then
        run the lease failure detector.

        The detector reads the *freshest* control traffic: `apply` can
        dwell in `ingest` for longer than a lease (a cold follower
        compiling its first apply shapes), during which heartbeats keep
        landing in the transport inbox. Draining them again here means
        a live, heartbeating leader is never declared dead just because
        we were busy applying its stream."""
        n = self.apply()
        if self.end is not None and not self.promoted:
            for hb in self.end.recv_ctrl():
                self._on_heartbeat(hb)
        self.maybe_promote()
        return n

    # -- leases / automatic failover ---------------------------------------
    def _on_heartbeat(self, hb: Dict[str, Any]) -> None:
        try:
            self.fid = int(hb["you"])
            self.roster = [(int(f), int(a)) for f, a in hb.get("roster", [])]
            self.lease_s = float(hb["lease_s"])
            self.leader_epoch = int(hb.get("epoch", 0))
            self.leader_ack_mode = str(hb.get("ack_mode",
                                              self.leader_ack_mode))
            self.leader_quorum = int(hb.get("quorum", self.leader_quorum))
        except (KeyError, TypeError, ValueError):
            return                      # malformed control traffic: drop
        self.lease_deadline = self.clock() + self.lease_s
        self._expiries_since_hb = 0
        self.counters["heartbeats_seen"] += 1

    def succession_rank(self) -> Optional[int]:
        """This follower's position (0 = designated successor) in the
        deterministic succession order: roster entries sorted by
        highest rostered ack, lowest follower id on ties. Evaluated
        over roster values ONLY — every follower holding the same
        roster computes the same order, which is what makes the
        election single-winner; a live applied watermark would differ
        per follower and let several caught-up followers each elect
        themselves (split-brain). None when this follower has no
        roster entry (no heartbeat ever named it)."""
        if self.fid is None:
            return None
        order = sorted(((a, -f) for f, a in self.roster), reverse=True)
        mine = [a for f, a in self.roster if f == self.fid]
        if not mine:
            return None
        return order.index((mine[0], -self.fid))

    def is_successor(self) -> bool:
        """Does the successor rule designate this follower (rank 0)?"""
        return self.succession_rank() == 0

    def maybe_promote(self) -> Optional[Leader]:
        """The failure detector (a no-op unless ``auto_promote``): on
        lease expiry, count it, and either promote this follower —
        returning the new `Leader`, also kept in ``new_leader`` — or
        stand down behind a re-armed fallback lease. Each consecutive
        expiry with no intervening heartbeat peels one rank off the
        succession order: the designated successor (rank 0) promotes
        on the first expiry, rank 1 on the second, and so on — so a
        cluster whose designated successor died in the same failure
        still converges on a leader instead of waiting for an operator
        (at the price that the lower-ranked fallback may trail the
        dead successor's watermark)."""
        if (not self.auto_promote or self.promoted
                or self.lease_deadline is None
                or self.clock() < self.lease_deadline):
            return None
        self.counters["lease_expiries"] += 1
        self._expiries_since_hb += 1
        rank = self.succession_rank()
        if rank is None or rank > self._expiries_since_hb - 1:
            # stand down — but stay armed: if the winner's stream never
            # arrives, the next expiry promotes the next rank
            self.counters["standdowns"] += 1
            self.lease_deadline = (None if rank is None
                                   else self.clock() + (self.lease_s or 2.0))
            return None
        self.counters["auto_promotions"] += 1
        self.new_leader = self.promote(lead=True)
        return self.new_leader

    def reattach(self, end) -> None:
        """Point this follower at a new transport end (rejoin after a
        failover: the new leader `attach`es the other side). Lease
        state resets until the new leader's first heartbeat."""
        if self.end is not None:
            try:
                self.end.close()
            except OSError:
                pass
        self.end = end
        self.lease_deadline = None
        self._expiries_since_hb = 0

    # -- failover exit ------------------------------------------------------
    def promote(self, lead: bool = False, fence: bool = True):
        """Failover: make this follower the leader. Unacked buffered
        frames are dropped (never acked ⇒ never durable anywhere —
        clients were never told they happened) and the engine's
        ``promote()`` bumps the WAL epoch and re-enables local logging,
        so the seqno stream resumes right after the last applied record
        and any stale pre-failover bytes the reused log file might
        expose later are rejected by the prefix rule's epoch check.

        ``promote()`` (the PR-9 form) closes the transport and returns
        the now-writable *engine*. ``promote(lead=True)`` instead
        returns a ready `Leader` wrapped around it — inheriting the
        lease duration AND the ack mode/quorum the old leader
        advertised, so a quorum (zero-RPO) cluster stays a quorum
        cluster across automatic failover (the fresh leader has no
        followers yet, so its commit watermark is -1 and nothing is
        client-acked until k followers re-attach — strictness, not
        regression) — and (with `fence`) adopts the old transport end
        as a fence end, so a deposed leader that comes back from a
        partition is answered at the bumped epoch and fences itself."""
        self.pending.clear()
        old_end, self.end = self.end, None
        self.promoted = True
        drv = self.drv.promote()
        drv.replication = None
        if not lead:
            if old_end is not None:
                try:
                    old_end.close()
                except OSError:
                    pass
            return drv
        ldr = Leader(drv,
                     ack_mode=self.leader_ack_mode,
                     quorum=self.leader_quorum,
                     lease_s=self.lease_s if self.lease_s else 2.0,
                     clock=self.clock)
        if old_end is not None:
            if fence:
                ldr.adopt_fence(old_end)
            else:
                try:
                    old_end.close()
                except OSError:
                    pass
        return ldr

    def stats(self) -> Dict[str, Any]:
        """Follower-side replication telemetry: applied watermark,
        reorder-buffer occupancy/bound, lease state, and the
        duplicate/reject/overflow counters."""
        return {
            "role": "follower",
            "promoted": self.promoted,
            "applied_seqno": int(self.last_seqno),
            "reorder_buffered": len(self.pending),
            "pending_max": int(self.pending_max),
            "fid": self.fid,
            "auto_promote": bool(self.auto_promote),
            "lease_armed": self.lease_deadline is not None,
            "leader_epoch": int(self.leader_epoch),
            "leader_ack_mode": self.leader_ack_mode,
            "leader_quorum": int(self.leader_quorum),
            "succession_rank": self.succession_rank(),
            **{k: int(v) for k, v in self.counters.items()},
        }


def converge(leader: Leader, *followers: Follower,
             max_rounds: int = 1000) -> int:
    """Pump `leader` and `followers` until every follower's ack says it
    has applied the leader's whole durable log (lag 0). Returns rounds
    used; raises RuntimeError when `max_rounds` pumps don't converge
    (e.g. a severed link)."""
    for r in range(max_rounds):
        leader.pump()
        for f in followers:
            f.pump()
        leader.pump()                   # drain the acks just sent
        if leader.stats()["follower_lag_records"] == 0:
            return r + 1
    raise RuntimeError("replication did not converge: "
                       + json.dumps(leader.stats()))
