"""Vmapped multi-shard sLSM: S independent trees in one fused pytree.

The many-tenant serving shape: S complete sLSM trees live in one stacked
state pytree (every leaf gains a leading shard axis), and every device
op is the single-tree `_impl` op vmapped over that axis — one dispatch
drives all shards. The key space is hash-partitioned (the same Murmur3
finalizer the Bloom filters use), so shards never share keys and their
results merge trivially.

Control flow stays on the host, as in the single-tree driver: the host
reads the (S,) occupancy vectors and applies each maintenance op under a
per-shard select mask — shards whose mask is off get their state back
unchanged (the vmapped op's output for them is computed and discarded;
with S trees in one fused dispatch that is the price of lockstep, and it
is exactly the work a busy fleet does anyway).

Maintenance is scheduled per shard through the same step model the
single-tree driver uses (repro.engine.scheduler): after every lockstep
insert round, each shard runs up to `merge_budget` voluntary steps —
per-shard step masks, deepest level first — then the forced chain covers
whatever the next round structurally requires. With merge_budget == 0
only the forced chain runs: the legacy lockstep deepest-first cascade,
unchanged.

Two deliberate simplifications vs the single-tree driver:
  * all `max_levels` tiers are preallocated at init so every shard
    shares one pytree structure (no per-shard lazy growth);
  * annihilated records (weight sums <= 0, DESIGN.md §13) are dropped
    only at deepest-level compaction — always legal (paper 2.5/2.8);
    the per-shard "is the target the deepest occupied level"
    refinement would make `drop_annihilated` a traced per-shard value
    inside ops that specialize on it statically.

Compaction is the paper's tiering policy. Lookups use the dense read
path (the sparse path's candidate compaction does not vmap); queries are
routed host-side to their owner shard, looked up in one vmapped
dispatch, and scattered back.
"""
from __future__ import annotations

import collections
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import KEY_EMPTY, SLSMParams
from repro.engine import compaction as CP
from repro.engine import memtable as MT
from repro.engine import read_path as RP
from repro.engine import scheduler as SCH
from repro.engine import tape as TP
from repro.engine import tuner as TU
from repro.engine import wal as WAL
from repro.engine.backend import get_backend
from repro.engine.batching import (RANGE_BUCKETS, TAPE_BUCKETS, bucket_pow2,
                                   range_bucket, range_many_host,
                                   tape_bucket)
from repro.engine.engine import reject_reserved

I32 = jnp.int32

_GOLDEN = np.uint32(0x9E3779B9)   # bloom.SEED1 — same hash family
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)


def _fmix32_np(x: np.ndarray) -> np.ndarray:
    """numpy mirror of repro.core.bloom.fmix32 (host-side routing hash)."""
    x = x.astype(np.uint32)
    x ^= x >> 16
    x = x * _C1
    x ^= x >> 13
    x = x * _C2
    x ^= x >> 16
    return x


def shard_ids(keys, n_shards: int) -> np.ndarray:
    """Owner shard of each key: fmix32(key ^ SEED1) mod S."""
    u = np.asarray(keys, np.int32).reshape(-1).view(np.uint32)
    return (_fmix32_np(u ^ _GOLDEN) % np.uint32(n_shards)).astype(np.int64)


# --------------------------------------------------------------------------
# vmapped device ops with per-shard select masks
# --------------------------------------------------------------------------

def _select(mask: jax.Array, new, old):
    """Per-shard pytree select: leaf[s] = new[s] if mask[s] else old[s]."""
    def sel(a, b):
        m = mask.reshape(mask.shape + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree.map(sel, new, old)


@functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
def _stage_append_sharded(p: SLSMParams, state, keys, vals, wts, n_valid):
    return jax.vmap(
        lambda st, k, v, w, n: MT.stage_append_impl(p, st, k, v, w, n)
    )(state, keys, vals, wts, n_valid)


@functools.partial(jax.jit, static_argnums=0)
def _seal_where(p: SLSMParams, state, mask):
    sealed = jax.vmap(lambda st: MT.seal_run_impl(p, st))(state)
    return _select(mask, sealed, state)


@functools.partial(jax.jit, static_argnums=0)
def _flush_where(p: SLSMParams, state, mask):
    new = jax.vmap(
        lambda st: CP.merge_buffer_to_level0_impl(p, st, False))(state)
    return _select(mask, new, state)


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def _merge_level_down_where(p: SLSMParams, state, level: int, n_merge: int,
                            mask):
    new = jax.vmap(
        lambda st: CP.merge_level_down_impl(p, st, level, n_merge, False)
    )(state)
    return _select(mask, new, state)


@functools.partial(jax.jit, static_argnums=0)
def _compact_last_where(p: SLSMParams, state, mask):
    new, raw = jax.vmap(lambda st: CP.compact_last_level_impl(p, st))(state)
    return _select(mask, new, state), raw


@functools.partial(jax.jit, static_argnums=(0, 3))
def _lookup_sharded(p: SLSMParams, state, qs, skip_empty: bool = False):
    """qs (S, Q): each shard looks up its own row (dense path).
    `skip_empty` passes the adaptive read path's occupancy gate through;
    under vmap it lowers to a select (see read_path._skip_if_empty), so
    it is semantics- and cost-neutral here — accepted for driver parity."""
    return jax.vmap(
        lambda st, q: RP.lookup_batch_impl(p, st, q, sparse=False,
                                           skip_empty=skip_empty)
    )(state, qs)


@functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
def _retune_filters_sharded(p: SLSMParams, state):
    """Rebuild every shard's resident filters under `p`'s (new) effective
    allocation — the vmapped device half of a RETUNE (tuner.retune_filters)."""
    return jax.vmap(lambda st: TU.retune_filters_impl(p, st))(state)


@functools.partial(jax.jit, static_argnums=0)
def _range_sharded(p: SLSMParams, state, lo, hi):
    return jax.vmap(lambda st: RP.range_query_impl(p, st, lo, hi))(state)


def _merge_shard_ranges(p: SLSMParams, k, v, c, tr):
    """Fold per-shard batched-scan results into global rows, on device.

    Inputs are the (S, Q, max_range) result planes of
    `read_path.range_many_impl` vmapped over shards (disjoint key sets,
    each row key-sorted): one `lax.sort` per scan merges them without a
    host round-trip. Shared by `_range_many_sharded` and the sharded
    mixed-op tape's range branch, so the merge contract cannot diverge."""
    mr = p.max_range
    s_n, q_n = k.shape[0], k.shape[1]
    kq = jnp.moveaxis(k, 0, 1).reshape(q_n, s_n * mr)
    vq = jnp.moveaxis(v, 0, 1).reshape(q_n, s_n * mr)
    kq, vq = jax.lax.sort((kq, vq), num_keys=1)
    total = c.sum(axis=0)
    return (kq[:, :mr], vq[:, :mr], jnp.minimum(total, mr),
            tr.any(axis=0) | (total > mr))


@functools.partial(jax.jit, static_argnums=0)
def _range_many_sharded(p: SLSMParams, state, los, his, n_valid):
    """Q scans against all S shards in one dispatch, merged on device.

    Every shard answers the whole scan batch through the fence-pruned
    engine (`read_path.range_many_impl` vmapped over the shard axis);
    the per-shard result rows — key-sorted, disjoint key sets — are then
    combined per scan with a single on-device sort (`_merge_shard_ranges`),
    so the global result never round-trips through host numpy. Returns
    the same ``(keys (Q, max_range), vals, counts, truncated)`` contract
    as the single-tree batched path, with ``truncated[i]`` true when any
    shard truncated scan i or the combined live count exceeds max_range."""
    k, v, c, tr = jax.vmap(
        lambda st: RP.range_many_impl(p, st, los, his, n_valid))(state)
    return _merge_shard_ranges(p, k, v, c, tr)


@functools.partial(jax.jit, static_argnums=0)
def _aggregate_many_sharded(p: SLSMParams, state, los, his, n_valid):
    """Q windowed aggregates against all S shards in one dispatch:
    every shard reduces its own live rows (`read_path.aggregate_many_impl`
    vmapped over the shard axis) and the disjoint per-shard partials fold
    by int32 addition — counts and wraparound sums are both associative,
    so the global aggregate needs no row merge at all. ``truncated[i]``
    is true when any shard's candidate gather overflowed for window i."""
    c, s, t = jax.vmap(
        lambda st: RP.aggregate_many_impl(p, st, los, his, n_valid))(state)
    return c.sum(axis=0), s.sum(axis=0), t.any(axis=0)


@functools.partial(jax.jit, static_argnums=(0, 7), donate_argnums=1)
def _tape_exec_sharded(p: SLSMParams, state, opcodes, keys, vals, wts,
                       n_valid, skip_empty: bool = False):
    """Sharded mixed-op tape: one `lax.scan` over T tagged slots, every
    branch the single-tree tape's op vmapped over the shard axis.

    xs are ``opcodes (T,)`` (one op kind per slot — the stream is
    global), ``keys/vals/wts (T, S, Rn)`` and ``n_valid (T, S)``
    host-routed per shard. WRITE slots append per shard and seal in-scan under a
    per-shard mask (compute-both + `_select`, the same lockstep price
    every masked maintenance op pays); LOOKUP slots answer each shard's
    routed lanes; RANGE slots broadcast their (lo, hi) lanes to every
    shard and fold the disjoint rows with `_merge_shard_ranges`. Host
    headroom preconditions are per shard (`ShardedSLSM.run_tape`)."""
    rb = TP.range_lanes(p)
    mr = p.max_range
    s_n, width = keys.shape[1], keys.shape[2]

    def zeros():
        return (jnp.zeros((s_n, width), I32),        # lookup vals
                jnp.zeros((s_n, width), bool),       # lookup found
                jnp.full((rb, mr), KEY_EMPTY, I32),  # range keys (merged)
                jnp.zeros((rb, mr), I32),            # range vals
                jnp.zeros((rb,), I32),               # range counts
                jnp.zeros((rb,), bool),              # range truncated
                jnp.zeros((), I32))                  # seals this slot

    def nop(st, k, v, w, n):
        return st, zeros()

    def write(st, k, v, w, n):
        new = jax.vmap(
            lambda s_, k_, v_, w_, n_: MT.stage_append_impl(p, s_, k_, v_,
                                                            w_, n_)
        )(st, k, v, w, n)
        mask = new.stage_count >= p.Rn
        sealed = jax.vmap(lambda s_: MT.seal_run_impl(p, s_))(new)
        out = zeros()
        return (_select(mask, sealed, new),
                out[:6] + (mask.sum(dtype=I32),))

    def lookup(st, k, v, w, n):
        lv, lf = jax.vmap(
            lambda s_, k_, n_: RP.lookup_many_impl(p, s_, k_, n_, False,
                                                   skip_empty)
        )(st, k, n)
        out = zeros()
        return st, (lv, lf) + out[2:]

    def range_(st, k, v, w, n):
        los, his, nr = k[0, :rb], v[0, :rb], n[0]
        kk, vv, cc, tt = jax.vmap(
            lambda s_: RP.range_many_impl(p, s_, los, his, nr))(st)
        rk, rv, rc, rt = _merge_shard_ranges(p, kk, vv, cc, tt)
        out = zeros()
        return st, out[:2] + (rk, rv, rc, rt) + out[6:]

    def body(st, xs):
        op, k, v, w, n = xs
        return jax.lax.switch(jnp.clip(op, 0, 3),
                              [nop, write, lookup, range_], st, k, v, w, n)

    return jax.lax.scan(body, state,
                        (opcodes.astype(I32), keys.astype(I32),
                         vals.astype(I32), wts.astype(I32),
                         n_valid.astype(I32)))


# --------------------------------------------------------------------------
# host driver
# --------------------------------------------------------------------------

class ShardedSLSM:
    """S hash-partitioned sLSM trees in one fused, vmapped state pytree."""

    def __init__(self, params: SLSMParams | None = None, n_shards: int = 4,
                 durability=None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.p = params or SLSMParams()
        get_backend(self.p.backend)
        self.S = n_shards
        self.policy = CP.TieringPolicy()   # the only policy that vmaps
        base = MT.init_state(self.p, n_levels=self.p.max_levels)
        self.state = jax.tree.map(lambda x: jnp.stack([x] * n_shards), base)
        # the tuner's active allocation applied to p (== p under static
        # tuning); one allocation governs the whole fleet — the stacked
        # pytree runs every shard through the same static program, so a
        # retune is a lockstep swap + one vmapped filter rebuild
        self.p_active = self.p
        self.tuner = TU.Tuner(self)
        # maintenance counters, summed over shards (bench trajectory);
        # backlog_peak = most pending steps observed on any ONE shard
        self.stats = collections.Counter(seals=0, flushes=0, spills=0,
                                         compactions=0, backlog_peak=0,
                                         retunes=0, reads=0, writes=0,
                                         rows_merged_in=0, rows_merged_out=0,
                                         rows_annihilated=0,
                                         ghost_payload_bytes_skipped=0)
        # durability surface (DESIGN.md §12): write ops are logged at the
        # driver boundary BEFORE shard routing, so single-tree and
        # sharded engines fed the same stream produce byte-identical
        # WALs (modulo the META fingerprint) — the recovery-parity tests
        # lean on that
        self._replaying = False
        self.durability = WAL.as_durability(durability)
        if self.durability is not None:
            self.durability.ensure_header(self._wal_meta())
        # replication hook (DESIGN.md §14): a replication.Leader /
        # .Follower claims this; repro.serve pumps it between windows.
        # fenced (DESIGN.md §15) = a deposed leader: writes raise until
        # a future promote()
        self.replication = None
        self.fenced = False

    # -- write path -------------------------------------------------------
    def _guard_writes(self) -> None:
        """Reject writes into a read-only engine: a fenced (deposed)
        leader or a replica follower (DESIGN.md §15) —
        `SLSM._guard_writes`'s contract. Replay and `apply_replicated`
        bypass this via ``_replaying``."""
        if self._replaying:
            return
        if self.fenced:
            raise RuntimeError(
                "write rejected: this engine was fenced (deposed leader) "
                "— demote() happened; rejoin via the new leader's "
                "bootstrap or promote() to lead again")
        if self.durability is not None and self.durability.replica:
            raise RuntimeError(
                "write rejected: replica engines are read-only until "
                "promote()")

    def insert(self, keys, vals) -> None:
        """Batched insert (paper Algorithm 1/2, vmapped): bucket by owner
        shard, then feed all shards in lockstep Rn-chunks; each round ends
        with the per-shard scheduler pass (budgeted voluntary steps, then
        the forced chain)."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        vals = np.asarray(vals, np.int32).reshape(-1)
        assert keys.shape == vals.shape
        reject_reserved(keys, vals, op="insert")
        self._insert(keys, vals, np.ones_like(keys))

    def _insert(self, keys: np.ndarray, vals: np.ndarray,
                wts: np.ndarray) -> None:
        """Post-validation weighted write path (delete() enters here with
        weight -1 records). With durability on, the whole op is
        WAL-logged pre-routing as one record and group-committed before
        returning (one fsync per driver call — SLSM._insert's contract,
        byte-identical records)."""
        if len(keys) == 0:
            return
        self._guard_writes()
        log = self.durability is not None and not self._replaying
        if log:
            self.durability.log_write(keys, vals, wts)
        self.stats["writes"] += len(keys)
        self.tuner.note_writes(len(keys))
        sid = shard_ids(keys, self.S)
        buckets = [(keys[sid == s], vals[sid == s], wts[sid == s])
                   for s in range(self.S)]
        rn = self.p.Rn
        rounds = max((len(bk) + rn - 1) // rn for bk, _, _ in buckets)
        for r in range(rounds):
            ck = np.full((self.S, rn), KEY_EMPTY, np.int32)
            cv = np.zeros((self.S, rn), np.int32)
            cw = np.zeros((self.S, rn), np.int32)
            n = np.zeros((self.S,), np.int32)
            for s, (bk, bv, bw) in enumerate(buckets):
                seg = bk[r * rn:(r + 1) * rn]
                n[s] = len(seg)
                ck[s, :len(seg)] = seg
                cv[s, :len(seg)] = bv[r * rn:(r + 1) * rn]
                cw[s, :len(seg)] = bw[r * rn:(r + 1) * rn]
            self.state = _stage_append_sharded(
                self.p_active, self.state, jnp.asarray(ck), jnp.asarray(cv),
                jnp.asarray(cw), jnp.asarray(n))
            self._maintain()
        if log:
            self.durability.sync()

    def delete(self, keys) -> None:
        """Weight -1 records (paper 2.8 tombstones as Z-set retractions —
        DESIGN.md §13); annihilated at deepest-level compaction
        (paper 2.5)."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        reject_reserved(keys, op="delete")
        self._insert(keys, np.zeros_like(keys), np.full_like(keys, -1))

    # -- merge scheduling (per-shard step masks over the vmapped ops) ------
    def _occupancies(self) -> list:
        """Per-shard occupancy snapshots for the scheduler's step logic."""
        stage = np.asarray(self.state.stage_count)
        runs = np.asarray(self.state.run_count)
        per_level = [np.asarray(lv.n_runs) for lv in self.state.levels]
        return [SCH.Occupancy(int(stage[s]), int(runs[s]),
                              tuple(int(lr[s]) for lr in per_level))
                for s in range(self.S)]

    def _book_merge(self, rows_in: int, rows_out: int) -> None:
        """Z-set merge telemetry over the masked shards of one step
        (mirrors `MergeScheduler._book_merge` — DESIGN.md §13): the
        in/out gap is dedup + annihilation, rows whose payloads the
        Ghost gather never touched (4 bytes each)."""
        st = self.stats
        st["rows_merged_in"] += rows_in
        st["rows_merged_out"] += rows_out
        st["rows_annihilated"] += rows_in - rows_out
        st["ghost_payload_bytes_skipped"] += 4 * (rows_in - rows_out)

    def _apply_step(self, kind: str, level: int, mask: np.ndarray) -> None:
        """Run one step kind for every masked shard in a single vmapped
        dispatch; unmasked shards pass through unchanged."""
        p, jm = self.p_active, jnp.asarray(mask)
        idx = np.flatnonzero(mask)
        if kind == SCH.SEAL:
            self.state = _seal_where(p, self.state, jm)
            self.stats["seals"] += int(mask.sum())
        elif kind == SCH.FLUSH:
            mr = p.runs_merged_eff
            rows_in = int(np.asarray(
                self.state.buf_counts)[idx, :mr].sum())
            slots = np.asarray(self.state.levels[0].n_runs)[idx]
            self.state = _flush_where(p, self.state, jm)
            self._book_merge(rows_in, int(np.asarray(
                self.state.levels[0].counts)[idx, slots].sum()))
            self.stats["flushes"] += int(mask.sum())
        elif kind == SCH.SPILL:
            nm = p.disk_runs_merged
            rows_in = int(np.asarray(
                self.state.levels[level].counts)[idx, :nm].sum())
            slots = np.asarray(self.state.levels[level + 1].n_runs)[idx]
            self.state = _merge_level_down_where(
                p, self.state, level, nm, jm)
            self._book_merge(rows_in, int(np.asarray(
                self.state.levels[level + 1].counts)[idx, slots].sum()))
            self.stats["spills"] += int(mask.sum())
        else:   # COMPACT
            last = p.max_levels - 1
            rows_in = int(np.asarray(self.state.levels[last].counts)[idx].sum())
            new_state, raw = _compact_last_where(p, self.state, jm)
            raws = np.asarray(raw)[mask]
            cap = p.level_cap(last)
            if (raws > cap).any():
                # raise before committing: the compacted state silently
                # truncates the overflowing run (same order as engine.py)
                raise RuntimeError(
                    f"sLSM deepest level overflow ({int(raws.max())} > {cap} "
                    f"live elements in a shard): increase max_levels beyond "
                    f"{p.max_levels}")
            self.state = new_state
            self._book_merge(rows_in, int(raws.sum()))
            self.stats["compactions"] += int(mask.sum())

    def _step_masks(self, kind: str, level: int, occs) -> np.ndarray:
        """(pending, ready) per-shard masks for one step kind."""
        p, policy = self.p_active, self.policy
        pend = np.array([SCH.step_pending(kind, level, o, p, policy)
                         for o in occs], dtype=bool)
        ready = np.array([SCH.step_ready(kind, level, o, p, policy)
                          for o in occs], dtype=bool)
        return pend, pend & ready

    def _apply_retune(self) -> None:
        """Lockstep allocation switch: swap the fleet's active params and
        rebuild every shard's filters in one vmapped dispatch. A retune
        is a *global static swap* (the stacked pytree runs one program),
        so unlike merges it cannot be per-shard masked — it applies at
        the round boundary that decided it, whatever the pacing budget.
        With durability on the applied switch is WAL-logged and synced
        (SLSM.apply_retune's contract)."""
        t = self.tuner
        log = self.durability is not None and not self._replaying
        if log:
            self.durability.log_retune(t.target)
        self.p_active = t.allocation(t.target).apply(self.p)
        self.state = _retune_filters_sharded(self.p_active, self.state)
        t.applied()
        self.stats["retunes"] += 1
        if log:
            self.durability.sync()

    def _maintain(self) -> None:
        """Per-round scheduler pass: tuner decision (adaptive mode),
        backlog telemetry, budgeted voluntary steps (merge_budget > 0),
        then the forced chain."""
        self.tuner.decide()
        if self.tuner.pending:
            self._apply_retune()
        occs = self._occupancies()
        p, policy = self.p_active, self.policy
        peak = max(len(SCH.pending_steps(p, policy, o)) for o in occs)
        self.stats["backlog_peak"] = max(self.stats["backlog_peak"], peak)
        if p.merge_budget > 0:
            self._voluntary_pass()
        self._forced_pass()

    def _voluntary_pass(self) -> None:
        """Up to merge_budget steps per shard, deepest-first: each masked
        vmapped op advances every shard with that step pending, ready, and
        budget left. One occupancy snapshot per applied op (the snapshot
        is a device->host sync on the insert hot path); the backlog is
        re-derived after each op, the same fixpoint semantics as the
        single-tree pass. Termination: every iteration that runs an op
        spends at least one unit of a finite budget."""
        budget = np.full(self.S, self.p_active.merge_budget, np.int64)
        while (budget > 0).any():
            occs = self._occupancies()
            ran = False
            for kind, level in SCH.step_order(self.p_active):
                _, ready = self._step_masks(kind, level, occs)
                mask = ready & (budget > 0)
                if mask.any():
                    self._apply_step(kind, level, mask)
                    budget[mask] -= 1
                    ran = True
                    break   # state changed: re-snapshot before the next op
            if not ran:
                return

    def _forced_pass(self) -> None:
        """Seal/flush/cascade every shard the next round structurally
        requires (the legacy lockstep Do-Merge — the whole of maintenance
        when merge_budget == 0)."""
        p = self.p_active
        while True:
            need_seal = np.asarray(self.state.stage_count) >= p.Rn
            if not need_seal.any():
                return
            need_flush = need_seal & (np.asarray(self.state.run_count) >= p.R)
            if need_flush.any():
                self._cascade(need_flush)
                self._apply_step(SCH.FLUSH, -1, need_flush)
            self._apply_step(SCH.SEAL, -1, need_seal)

    def _cascade(self, flush_mask: np.ndarray) -> None:
        """Forced deepest-first spill chain: shard s spills level l+1 only
        if its level-l spill is about to push a run into a full level l+1."""
        p = self.p_active
        spill, mask = [], flush_mask
        for lvl in range(p.max_levels):
            mask = mask & (np.asarray(self.state.levels[lvl].n_runs) >= p.D)
            spill.append(mask.copy())
        last = p.max_levels - 1
        if spill[last].any():
            self._apply_step(SCH.COMPACT, last, spill[last])
        for lvl in range(last - 1, -1, -1):
            if spill[lvl].any():
                self._apply_step(SCH.SPILL, lvl, spill[lvl])

    def warm(self) -> None:
        """Precompile the sharded maintenance program set (one program
        per step kind — the stacked pytree has a single structure, unlike
        the single tree's lazily grown levels) plus the range-scan
        program grid (`RANGE_BUCKETS` batched widths and the legacy
        per-shard scan), so no insert round or first scan pays a
        first-use jit compile. Masks are all-False: the vmapped ops still
        compile fully, the dummy state passes through unchanged. With
        adaptive tuning each preset allocation is its own static-param
        program set, so every preset (plus its retune rebuild) warms."""
        base = MT.init_state(self.p, n_levels=self.p.max_levels)
        if self.tuner.enabled:
            param_sets = [alloc.apply(self.p)
                          for alloc in self.tuner.presets.values()]
        else:
            param_sets = [self.p]

        def stacked():
            return jax.tree.map(lambda x: jnp.stack([x] * self.S), base)

        no = jnp.zeros((self.S,), bool)
        outs = []
        for p in param_sets:
            outs.append(_stage_append_sharded(  # donates: own dummy
                p, stacked(), jnp.zeros((self.S, p.Rn), jnp.int32),
                jnp.zeros((self.S, p.Rn), jnp.int32),
                jnp.zeros((self.S, p.Rn), jnp.int32),
                jnp.zeros((self.S,), jnp.int32)))
            if len(param_sets) > 1:             # donates: own dummy
                outs.append(_retune_filters_sharded(p, stacked()))
            dummy = stacked()
            outs.append(_seal_where(p, dummy, no))
            outs.append(_flush_where(p, dummy, no))
            for lvl in range(p.max_levels - 1):
                outs.append(_merge_level_down_where(p, dummy, lvl,
                                                    p.disk_runs_merged, no))
            outs.append(_compact_last_where(p, dummy, no))
            # the batched range-scan grid + the legacy per-shard program
            for b in RANGE_BUCKETS:
                z = jnp.zeros((b,), jnp.int32)
                outs.append(_range_many_sharded(p, dummy, z, z,
                                                jnp.int32(0)))
            outs.append(_range_sharded(p, dummy, jnp.int32(0), jnp.int32(0)))
        jax.block_until_ready(outs)

    def drain(self) -> None:
        """Merge barrier: retire every shard's pending steps (see
        SLSM.drain — reads are exact without draining; drain completes the
        deferred maintenance so budgeted and synchronous engines can be
        compared at rest)."""
        if self.tuner.pending:   # a decided switch drains like any step
            self._apply_retune()
        while True:
            occs = self._occupancies()
            pending_any = progressed = False
            for kind, level in SCH.step_order(self.p_active):
                pend, ready = self._step_masks(kind, level, occs)
                pending_any |= bool(pend.any())
                if ready.any():
                    self._apply_step(kind, level, ready)
                    progressed = True
                    break   # state changed: re-snapshot before the next op
            if not pending_any:
                return
            if not progressed:   # pragma: no cover — invariant violation
                raise RuntimeError("sharded merge drain stalled")

    def voluntary_steps(self, budget: int) -> int:
        """Run up to `budget` ready maintenance steps per shard,
        deepest-first, re-deriving the masks after each applied op (the
        `_voluntary_pass` fixpoint, with an explicit budget): the
        maintenance governor's entry point (repro.serve), mirroring
        `MergeScheduler.voluntary_steps` on the single tree. A pending
        tuner allocation switch applies first (the lockstep swap cannot
        be per-shard masked) and counts as one step. Returns the total
        steps applied across the fleet."""
        self.tuner.decide()
        ran = 0
        if self.tuner.pending and budget > 0:
            self._apply_retune()
            ran, budget = 1, budget - 1
        per_shard = np.full(self.S, budget, np.int64)
        while (per_shard > 0).any():
            occs = self._occupancies()
            progressed = False
            for kind, level in SCH.step_order(self.p_active):
                _, ready = self._step_masks(kind, level, occs)
                mask = ready & (per_shard > 0)
                if mask.any():
                    self._apply_step(kind, level, mask)
                    per_shard[mask] -= 1
                    ran += int(mask.sum())
                    progressed = True
                    break   # state changed: re-snapshot before the next op
            if not progressed:
                break
        return ran

    # -- read path ----------------------------------------------------------
    def _on_reads(self, n: int) -> None:
        """Tuner signal on the read path (adaptive mode): reads feed and
        roll the controller but never execute maintenance — decisions
        bind at the next insert round's `_maintain` (or at `drain()`),
        mirroring the single-tree rule (MergeScheduler.on_read). The
        sharded tuner observes fleet-global counts — one allocation
        governs all shards, so per-shard mixes fold into one signal."""
        self.stats["reads"] += n
        t = self.tuner
        if not t.enabled:
            return
        t.note_reads(n)
        t.decide()

    def lookup(self, keys):
        """Batched multi-key lookup (paper 2.7, vmapped): route each query
        to its owner shard host-side, answer every shard's row in ONE
        fused device dispatch (`read_path.lookup_batch_impl` vmapped over
        shards — one Bloom-probe/fence-search pass per run for all
        queries), scatter results back.

        The per-shard row width is padded to a power-of-two bucket, so
        mixed batch sizes reuse O(log Q) compiled programs instead of
        recompiling on every distinct max-queries-per-shard value."""
        qs = np.asarray(keys, np.int32).reshape(-1)
        reject_reserved(qs, op="lookup")
        nq = len(qs)
        if nq == 0:
            return np.zeros(0, np.int32), np.zeros(0, bool)
        self._on_reads(nq)
        sid = shard_ids(qs, self.S)
        counts = np.bincount(sid, minlength=self.S)
        qmax = bucket_pow2(int(counts.max()))
        routed = np.full((self.S, qmax), KEY_EMPTY, np.int32)
        # vectorized routing: stable-sort by shard, then each query's slot
        # is its rank within its shard (index minus the shard's start)
        order = np.argsort(sid, kind="stable")
        starts = np.zeros(self.S + 1, np.int64)
        np.cumsum(counts, out=starts[1:])
        pos = np.empty(nq, np.int64)
        pos[order] = np.arange(nq, dtype=np.int64) - starts[sid[order]]
        routed[sid, pos] = qs
        vals, found = _lookup_sharded(self.p_active, self.state,
                                      jnp.asarray(routed),
                                      self.tuner.enabled)
        vals, found = np.asarray(vals), np.asarray(found)
        return vals[sid, pos], found[sid, pos]

    def lookup_many(self, keys, sparse: bool = False):
        """Alias for `lookup` — the sharded read path is already the
        batched fast path (one fused dispatch for all Q queries); the name
        and signature match `SLSM.lookup_many` so drivers can switch
        engines. `sparse` is accepted for that interchangeability but
        always served by the dense path (exact; the sparse candidate
        compaction does not vmap — see module docstring)."""
        return self.lookup(keys)

    def range(self, lo: int, hi: int, return_truncated: bool = False):
        """Global range = concat of per-shard ranges (disjoint key sets),
        re-sorted by key. Each shard contributes a correct sorted prefix
        of its live window (bounded by max_range and, when finite, the
        `range_cand` candidate budget): results are exact while no shard
        truncates, and with `return_truncated` the (S,) per-shard
        truncation flags are returned so callers can tell (shard s's
        flag set means its contribution is only a prefix — it held more
        than max_range live keys in [lo, hi), or its scan overflowed the
        candidate budget)."""
        k, v, c, trunc = _range_sharded(self.p_active, self.state,
                                        jnp.int32(lo), jnp.int32(hi))
        k, v, c = np.asarray(k), np.asarray(v), np.asarray(c)
        ks = np.concatenate([k[s, :c[s]] for s in range(self.S)])
        vs = np.concatenate([v[s, :c[s]] for s in range(self.S)])
        order = np.argsort(ks, kind="stable")
        out = ks[order], vs[order]
        return out + (np.asarray(trunc),) if return_truncated else out

    def range_device(self, lo: int, hi: int):
        """Device-resident global range query: one fused dispatch over
        all shards with the per-shard results merged on device (no host
        argsort, no per-scan sync). Returns jax arrays ``(keys
        (max_range,), vals, count, truncated)`` — the single-tree
        `SLSM.range_device` contract, with `truncated` already folded
        across shards. The single scan rides the smallest warmed
        `RANGE_BUCKETS` lane width, so it never pays a first-use
        compile after `warm()`."""
        width = range_bucket(1)
        los = np.zeros(width, np.int32)
        his = np.zeros(width, np.int32)
        los[0], his[0] = lo, hi
        k, v, c, tr = _range_many_sharded(
            self.p_active, self.state, jnp.asarray(los), jnp.asarray(his),
            jnp.int32(1))
        return k[0], v[0], c[0], tr[0]

    def range_many(self, ranges):
        """Batched multi-scan fast path over the shard fleet: all Q
        scans answered by every shard in ONE vmapped dispatch, with the
        disjoint per-shard rows merged per scan on device
        (`_range_many_sharded`) — same numpy return contract as
        `SLSM.range_many` (one shared pad/trim driver), padded to the
        `RANGE_BUCKETS` grid."""
        return range_many_host(
            lambda los, his, n: _range_many_sharded(
                self.p_active, self.state, los, his, n),
            self.p.max_range, ranges)

    def aggregate_many(self, ranges):
        """Batched windowed aggregates over the shard fleet: every shard
        reduces its own live rows in ONE vmapped dispatch and the
        disjoint partial counts/sums fold by addition
        (`_aggregate_many_sharded`) — same numpy return contract as
        `SLSM.aggregate_many` (``counts, sums, truncated``), exact past
        `max_range`, int32-wraparound sums."""
        r = np.asarray(ranges, np.int32).reshape(-1, 2)
        q = r.shape[0]
        if q == 0:
            return (np.zeros(0, np.int32), np.zeros(0, np.int32),
                    np.zeros(0, bool))
        width = range_bucket(q)
        los = np.zeros(width, np.int32)
        his = np.zeros(width, np.int32)
        los[:q], his[:q] = r[:, 0], r[:, 1]
        c, s, t = _aggregate_many_sharded(self.p_active, self.state,
                                          jnp.asarray(los), jnp.asarray(his),
                                          jnp.int32(q))
        return np.asarray(c)[:q], np.asarray(s)[:q], np.asarray(t)[:q]

    def count(self, lo: int, hi: int) -> int:
        """Live-key count over [lo, hi) across all shards (exact;
        one-window `aggregate_many`)."""
        c, _, _ = self.aggregate_many([(lo, hi)])
        return int(c[0])

    def sum(self, lo: int, hi: int) -> int:
        """Sum of live values over [lo, hi) across all shards (int32
        wraparound; one-window `aggregate_many`)."""
        _, s, _ = self.aggregate_many([(lo, hi)])
        return int(s[0])

    # -- mixed-op tape (repro.engine.tape, DESIGN.md §11) -------------------
    def _route_lanes(self, keys, vals=None, wts=None):
        """Route one chunk's lanes to their owner shards. Returns
        ``(k (S, Rn), v (S, Rn), w (S, Rn), n (S,), sid, pos)`` — sid/pos
        are each input lane's (shard, rank-within-shard) coordinates, the
        scatter map for lookup results (same vectorized routing as
        `lookup`)."""
        rn = self.p.Rn
        qs = np.asarray(keys, np.int32).reshape(-1)
        sid = shard_ids(qs, self.S)
        counts = np.bincount(sid, minlength=self.S)
        order = np.argsort(sid, kind="stable")
        starts = np.zeros(self.S + 1, np.int64)
        np.cumsum(counts, out=starts[1:])
        pos = np.empty(len(qs), np.int64)
        pos[order] = np.arange(len(qs), dtype=np.int64) - starts[sid[order]]
        k = np.full((self.S, rn), KEY_EMPTY, np.int32)
        k[sid, pos] = qs
        v = np.zeros((self.S, rn), np.int32)
        if vals is not None:
            v[sid, pos] = np.asarray(vals, np.int32).reshape(-1)
        w = np.zeros((self.S, rn), np.int32)
        if wts is not None:
            w[sid, pos] = np.asarray(wts, np.int32).reshape(-1)
        return k, v, w, counts.astype(np.int32), sid, pos

    def tape_write_capacity(self) -> int:
        """Max write keys the next `run_tape` call may carry — the
        single-tree bound (`SLSM.tape_write_capacity`) evaluated per
        shard and min-folded, since routing may land every key on the
        worst shard."""
        p = self.p_active
        rcs = np.asarray(self.state.run_count)
        scs = np.asarray(self.state.stage_count)
        caps = []
        for s in range(self.S):
            rc, sc = int(rcs[s]), int(scs[s])
            while sc >= p.Rn:
                if rc >= p.R:
                    rc -= p.runs_merged_eff
                rc += 1
                sc -= p.Rn
            free = p.R - rc % p.runs_merged_eff
            caps.append((free + 1) * p.Rn - 1 - sc)
        return min(caps)

    def _reserve_run_slots(self, need: np.ndarray) -> None:
        """Per-shard headroom for the tape's in-scan seals: masked
        flushes (cascading first when level 0 is full) until every shard
        has >= need[s] free run slots. Mirrors
        `MergeScheduler.reserve_run_slots`, lockstep-masked."""
        p = self.p_active
        rm = p.runs_merged_eff
        while True:
            rc = np.asarray(self.state.run_count)
            short = (p.R - rc) < need
            if not short.any():
                return
            mask = short & (rc >= rm)
            if not mask.any():
                floors = rc % rm
                raise ValueError(
                    f"cannot reserve {need.max()} run slots on every "
                    f"shard: worst shard reaches {p.R - int(floors.max())} "
                    f"(R={p.R})")
            self._cascade(mask)
            self._apply_step(SCH.FLUSH, -1, mask)

    def run_tape(self, chunks):
        """Execute a coalesced mixed-op window as ONE vmapped device
        dispatch — the sharded form of `SLSM.run_tape` (same chunk
        kinds, same per-chunk result contract, same headroom and
        window-segmentation behaviour, with every precondition enforced
        per shard). Write and lookup lanes are host-routed to their
        owner shards; range slots are answered by every shard and
        merged on device (`_merge_shard_ranges`)."""
        chunks = [c if isinstance(c, TP.TapeChunk) else TP.TapeChunk(*c)
                  for c in chunks]
        if not chunks:
            return []
        n_writes = n_reads = 0
        for ch in chunks:
            k = np.asarray(ch.keys, np.int32).reshape(-1)
            if ch.kind == "write":
                reject_reserved(k, op="tape write")
                n_writes += k.size
            elif ch.kind == "lookup":
                reject_reserved(k, op="tape lookup")
                n_reads += k.size
            elif ch.kind != "range":
                raise ValueError(f"unknown tape chunk kind {ch.kind!r}")
        if n_writes:
            self._guard_writes()
        # one WAL record per write chunk, pre-routing, group-committed
        # before the window's results are returned (log-before-ack —
        # SLSM.run_tape's contract, byte-identical records)
        log = self.durability is not None and not self._replaying
        if log:
            for ch in chunks:
                if ch.kind == "write":
                    k = np.asarray(ch.keys, np.int32).reshape(-1)
                    if k.size:
                        w = (np.ones_like(k) if ch.wts is None
                             else np.asarray(ch.wts, np.int32).reshape(-1))
                        self.durability.log_write(
                            k, np.asarray(ch.vals, np.int32).reshape(-1), w)
        rb = TP.range_lanes(self.p_active)
        results = [0] * len(chunks)
        work = list(enumerate(chunks))
        while work:
            self._forced_pass()   # every shard's stage absorbs a chunk
            budget = self.tape_write_capacity()
            seg, seg_idx = [], []
            while work:
                i, ch = work[0]
                if ch.kind == "write":
                    k = np.asarray(ch.keys, np.int32).reshape(-1)
                    v = np.asarray(ch.vals, np.int32).reshape(-1)
                    w = (np.ones_like(k) if ch.wts is None
                         else np.asarray(ch.wts, np.int32).reshape(-1))
                    if budget <= 0:
                        break
                    if k.size > budget:
                        seg.append(TP.TapeChunk("write", k[:budget],
                                                v[:budget], w[:budget]))
                        seg_idx.append(i)
                        work[0] = (i, TP.TapeChunk("write", k[budget:],
                                                   v[budget:], w[budget:]))
                        budget = 0
                        continue
                    budget -= k.size
                seg.append(ch)
                seg_idx.append(i)
                work.pop(0)
            assert seg, "tape segmentation made no progress"
            self._run_tape_segment(seg, seg_idx, rb, results)
        self.stats["writes"] += n_writes
        self.stats["reads"] += n_reads
        if n_writes:
            self.tuner.note_writes(n_writes)
        if n_reads:
            self.tuner.note_reads(n_reads)
        if log:
            self.durability.sync()
        return results

    def _run_tape_segment(self, seg, seg_idx, rb, results) -> None:
        """Pack, reserve, dispatch, and scatter back one tape segment."""
        p = self.p_active
        rn, t = p.Rn, len(seg)
        t_pad = tape_bucket(t)
        ops = np.zeros(t_pad, np.int32)
        keys = np.full((t_pad, self.S, rn), KEY_EMPTY, np.int32)
        vals = np.zeros((t_pad, self.S, rn), np.int32)
        wts = np.zeros((t_pad, self.S, rn), np.int32)
        nv = np.zeros((t_pad, self.S), np.int32)
        scatter = [None] * t
        seal_need = np.asarray(self.state.stage_count).astype(np.int64)
        for i, ch in enumerate(seg):
            if ch.kind == "range":
                los = np.asarray(ch.keys, np.int32).reshape(-1)
                his = np.asarray(ch.vals, np.int32).reshape(-1)
                if len(los) > rb:
                    raise ValueError(
                        f"range chunk of {len(los)} scans exceeds its "
                        f"per-slot capacity {rb}")
                ops[i] = TP.OP_RANGE
                keys[i, :, :len(los)] = los[None, :]
                vals[i, :, :len(his)] = his[None, :]
                nv[i, :] = len(los)
                continue
            if ch.kind == "write":
                cw = (np.ones(len(np.asarray(ch.keys).reshape(-1)), np.int32)
                      if ch.wts is None else ch.wts)
                k, v, w, n, sid, pos = self._route_lanes(ch.keys, ch.vals, cw)
            else:
                k, v, w, n, sid, pos = self._route_lanes(ch.keys)
            ops[i] = TP.OPCODES[ch.kind]
            keys[i], vals[i], wts[i], nv[i] = k, v, w, n
            scatter[i] = (sid, pos)
            if ch.kind == "write":
                seal_need += np.bincount(sid, minlength=self.S)
        need = (seal_need // rn).astype(np.int64)
        if need.any():
            self._reserve_run_slots(need)
        self.state, ys = _tape_exec_sharded(
            p, self.state, jnp.asarray(ops), jnp.asarray(keys),
            jnp.asarray(vals), jnp.asarray(wts), jnp.asarray(nv),
            self.tuner.enabled)
        lv, lf, rk, rv, rc, rt, sealed = (np.asarray(y) for y in ys)
        for i, ch in enumerate(seg):
            j = seg_idx[i]
            if ch.kind == "write":
                results[j] += int(sealed[i])
                self.stats["seals"] += int(sealed[i])
            elif ch.kind == "lookup":
                sid, pos = scatter[i]
                results[j] = (lv[i, sid, pos], lf[i, sid, pos])
            else:
                n = len(np.asarray(ch.keys).reshape(-1))
                results[j] = (rk[i, :n], rv[i, :n], rc[i, :n], rt[i, :n])

    def warm_tape(self, buckets: tuple = TAPE_BUCKETS) -> None:
        """Precompile the sharded tape interpreter grid (one program per
        allocation x slot bucket — the stacked pytree has a single
        structure), mirroring `SLSM.warm_tape`: after this, steady-state
        serving windows never JIT."""
        base = MT.init_state(self.p, n_levels=self.p.max_levels)
        if self.tuner.enabled:
            param_sets = [alloc.apply(self.p)
                          for alloc in self.tuner.presets.values()]
        else:
            param_sets = [self.p]
        skip = self.tuner.enabled
        outs = []
        for p in param_sets:
            for t in buckets:
                st = jax.tree.map(lambda x: jnp.stack([x] * self.S), base)
                outs.append(_tape_exec_sharded(
                    p, st, jnp.zeros((t,), jnp.int32),
                    jnp.full((t, self.S, p.Rn), KEY_EMPTY, jnp.int32),
                    jnp.zeros((t, self.S, p.Rn), jnp.int32),
                    jnp.zeros((t, self.S, p.Rn), jnp.int32),
                    jnp.zeros((t, self.S), jnp.int32), skip))
        jax.block_until_ready(outs)

    # -- durability (repro.engine.wal, DESIGN.md §12) -----------------------
    def _wal_meta(self) -> dict:
        """Engine fingerprint for the WAL's META record (driver kind,
        params, shard count) — verified on every reattach so a
        durability directory can never be replayed into a mismatched
        fleet."""
        return {"driver": "sharded",
                "params": WAL.params_to_dict(self.p),
                "policy": "tiering", "n_shards": self.S,
                "wal": WAL.WAL_FORMAT}

    def _snapshot_meta(self) -> dict:
        """Host-side state riding a snapshot beside the stacked pytree
        leaves (see SLSM._snapshot_meta; the levels structure is always
        fully preallocated here, so n_levels is max_levels)."""
        return {**self._wal_meta(), "n_levels": self.p.max_levels,
                "tuner": {"active": self.tuner.active,
                          "read_frac": float(self.tuner.read_frac)},
                "stats": {k: int(v) for k, v in self.stats.items()}}

    def snapshot(self):
        """Serialize the whole fleet's stacked pytree as one atomic
        snapshot stamped with the WAL seqno watermark (see
        SLSM.snapshot). Requires a durability layer."""
        if self.durability is None:
            raise ValueError("snapshot() requires a durability layer: "
                             "construct with ShardedSLSM(..., "
                             "durability=path)")
        return self.durability.snapshot(self)

    def _adopt_snapshot(self, leaves, meta: dict) -> None:
        """Install snapshot `leaves` as the live stacked state and adopt
        the controller/stats position captured in `meta` (see
        SLSM._adopt_snapshot; the stacked template is structure-fixed at
        init, so it always matches)."""
        base = MT.init_state(self.p, n_levels=self.p.max_levels)
        template = jax.tree.map(lambda x: jnp.stack([x] * self.S), base)
        treedef = jax.tree_util.tree_structure(template)
        self.state = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(x) for x in leaves])
        for k, v in meta.get("stats", {}).items():
            self.stats[k] = int(v)
        t = meta.get("tuner")
        if t and self.tuner.enabled:
            name = t.get("active", self.tuner.active)
            self.tuner.active = self.tuner.target = name
            self.tuner.read_frac = float(t.get("read_frac",
                                               self.tuner.read_frac))
            self.p_active = self.tuner.allocation(name).apply(self.p)

    def _replay(self, records) -> None:
        """Re-apply a WAL tail through the existing chunk-apply programs
        with re-logging suppressed (see SLSM._replay: answer-exact by
        the scheduler invariant, not bitwise-state-exact)."""
        self._replaying = True
        try:
            n = 0
            for rec in records:
                if rec.kind in WAL.WRITE_KINDS:
                    k, v, w = WAL.decode_write(rec.payload, rec.kind)
                    self._insert(k, v, w)
                elif rec.kind == WAL.REC_RETUNE:
                    if self.tuner.enabled:
                        self.tuner.target = rec.payload.decode()
                        if self.tuner.pending:
                            self._apply_retune()
                else:
                    continue
                n += 1
            self.stats["replayed_records"] += n
        finally:
            self._replaying = False

    @classmethod
    def restore(cls, path, params: SLSMParams | None = None,
                n_shards: int | None = None, durability=None):
        """Recover a sharded fleet from a durability directory: newest
        valid snapshot + WAL-tail replay, exactly `SLSM.restore`'s
        contract (torn final record dropped cleanly; `params`/`n_shards`
        default to the recorded fingerprint; restore wall time and
        replay size reported as ``restore_us``/``replayed_records``)."""
        t0 = time.perf_counter()
        dur = WAL.as_durability(durability if durability is not None
                                else path)
        records = dur.read_records()
        header = next((json.loads(r.payload.decode()) for r in records
                       if r.kind == WAL.REC_META), None)
        snap = WAL.load_latest_snapshot(dur.dir)
        meta = snap[2] if snap is not None else header
        if meta is None and params is None:
            raise ValueError(f"nothing to restore in {dur.dir}: no valid "
                             "snapshot and no readable WAL header")
        if params is None:
            params = WAL.params_from_dict(meta["params"])
        if n_shards is None:
            # a foreign (single-tree) fingerprint has no shard count; let
            # the constructor's ensure_header raise the clear mismatch
            n_shards = (int(meta.get("n_shards", 4))
                        if meta is not None else 4)
        drv = cls(params, n_shards, durability=dur)
        watermark = -1
        if snap is not None:
            num, leaves, smeta = snap
            drv._adopt_snapshot(leaves, smeta)
            watermark = num
        drv._replay([r for r in records if r.seqno > watermark])
        drv.stats["restore_us"] += int((time.perf_counter() - t0) * 1e6)
        return drv

    @classmethod
    def open_replica(cls, path, *, fsync: bool = False):
        """Open a sharded replication follower over a bootstrapped
        directory — `SLSM.open_replica`'s contract: a plain `restore`
        under a replica-mode durability layer that never injects a
        local META record (the log is the leader's stream, verbatim).
        WAL records are pre-routing, so a sharded follower replays a
        sharded leader's stream byte-identically."""
        return cls.restore(path, durability=WAL.Durability(
            path, fsync=fsync, replica=True))

    def apply_replicated(self, records) -> int:
        """Apply decoded leader WAL records through the vmapped
        chunk-apply programs with re-logging suppressed (see
        `SLSM.apply_replicated`). Returns the records applied."""
        before = self.stats["replayed_records"]
        self._replay(records)
        return self.stats["replayed_records"] - before

    def promote(self) -> "ShardedSLSM":
        """Failover: turn this replica fleet into a writable leader —
        `SLSM.promote`'s contract (epoch bump + local logging
        re-enabled; seqnos resume after the last applied record)."""
        if self.durability is None:
            raise ValueError("promote() requires a durability layer")
        self.durability.writer.bump_epoch()
        self.durability.replica = False
        self.fenced = False
        self.stats["promotions"] += 1
        return self

    def demote(self) -> "ShardedSLSM":
        """Fence this fleet against writes (the deposed-leader exit,
        DESIGN.md §15) — `SLSM.demote`'s contract: reads stay served,
        writes raise until a future `promote()`. Returns self."""
        self.fenced = True
        self.stats["demotions"] += 1
        return self

    # -- stats ----------------------------------------------------------------
    @property
    def n_live(self) -> int:
        """Resident elements across all shards' stages, memory runs, and
        disk levels (duplicates and negative-weight delete records count
        until merges annihilate them) — the fleet-wide sibling of
        `SLSM.n_live`."""
        n = int(self.state.stage_count.sum()) + int(self.state.buf_counts.sum())
        for lv in self.state.levels:
            n += int(lv.counts.sum())
        return n

    def shard_occupancy(self) -> np.ndarray:
        """(S,) live elements per shard — routing-balance introspection."""
        per = np.asarray(self.state.stage_count).astype(np.int64)
        per = per + np.asarray(self.state.buf_counts).sum(axis=1)
        for lv in self.state.levels:
            per = per + np.asarray(lv.counts).sum(axis=1)
        return per
