"""The Skiplist-Based LSM Tree — layered TPU-native JAX engine.

Layer map (DESIGN.md has the full tour):
  backend.py    — ops dispatch: jnp reference vs Pallas kernels
  batching.py   — the pad/bucket grid every batched entry point shares
  memtable.py   — staging buffer (active run) + sealed memory runs
  levels.py     — disk-tier state: runs, Bloom filters, fences, min/max
  compaction.py — the Do-Merge cascade ops + tiering/leveling policies
  scheduler.py  — the cascade as paced, bounded MergeSteps (merge_budget)
  tuner.py      — adaptive memory/filter tuner: one byte budget moved
                  between write buffer, per-level Bloom bits, and fences
  read_path.py  — dense + Bloom-compacted lookups, ranges, aggregates
  tape.py       — device-resident mixed-op tape (lax.scan interpreter)
  wal.py        — durability: CRC-framed sequence-numbered WAL + atomic
                  pytree snapshots + the Durability manager (restore())
  replication.py— single-leader replication over the WAL: Leader ships
                  durable frames verbatim, Follower replays + acks,
                  promote() is the explicit failover
  engine.py     — the host-side `SLSM` driver
  sharded.py    — S hash-partitioned trees in one vmapped pytree

`repro.core.slsm` re-exports this package's public API for backward
compatibility.
"""
from repro.engine.backend import (BACKENDS, OpsBackend,  # noqa: F401
                                  get_backend, lookup_level_many)
from repro.engine.batching import (ADAPTIVE_BUCKETS,  # noqa: F401
                                   RANGE_BUCKETS, adaptive_bucket,
                                   bucket_pow2, pad_pow2, pad_to,
                                   range_bucket, range_many_host)
from repro.engine.compaction import (CompactionPolicy,  # noqa: F401
                                     LevelingPolicy, TieringPolicy,
                                     compact_last_level,
                                     merge_buffer_to_level0,
                                     merge_level_down)
from repro.engine.engine import SLSM  # noqa: F401
from repro.engine.levels import LevelState, empty_level  # noqa: F401
from repro.engine.memtable import (SLSMState, init_state,  # noqa: F401
                                   seal_run, stage_append)
from repro.engine.read_path import (aggregate_many,  # noqa: F401
                                    lookup_batch, lookup_many,
                                    range_many, range_query)
from repro.engine.scheduler import (MergeScheduler, MergeStep,  # noqa: F401
                                    Occupancy, backlog_cost, pending_steps,
                                    step_cost)
from repro.engine.sharded import ShardedSLSM, shard_ids  # noqa: F401
from repro.engine.tuner import (Allocation, ReadModePolicy,  # noqa: F401
                                Tuner, allocation_bytes, build_presets,
                                monkey_eps_per_level, retune_filters)
from repro.engine.wal import (Durability, SnapshotError,  # noqa: F401
                              WalRecord, WalTailer, WalWriter, as_durability,
                              check_frame, list_snapshots,
                              load_latest_snapshot, read_snapshot, read_wal,
                              record_offsets, write_snapshot)
from repro.engine.replication import (Follower, Leader,  # noqa: F401,E402
                                      QueueLink, SocketListener, converge)
