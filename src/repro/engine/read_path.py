"""Read path (paper 2.7/2.9): point lookups, range queries, aggregates.

Lookups walk newest -> oldest across every structure — staging buffer,
sealed memory runs, then each disk level — keeping the match with the
highest seqno. Records are weighted (DESIGN.md §13): presence is the
sign of the newest record's weight (each op retracts its predecessor, so
the per-key weight sum telescopes to the newest record's weight — a
negative weight IS the key's absence; no reserved value in the payload
domain). Disk levels are gated by min/max windows AND Bloom positives
(paper 2.3) before any page is touched.

Two disk-search strategies:
  dense  — every (run, query) pair does the fence+page work, gated after
           the fact. Exact; the default. Bloom probes AND the fence page
           search (paper 2.4) dispatch through the ops backend
           (`SLSMParams.backend`), so the same control flow drives the
           jnp reference or the Pallas kernels.
  sparse — Bloom-compacted: only gated pairs are expanded (statically
           bounded by cand_factor per query). The TPU realization of
           "skip the run on a Bloom miss"; can drop candidates if the
           gate overflows its static bound (see `search_level_sparse`).
           Only the Bloom gate dispatches through the backend here: the
           candidate-compacted gather is per-(run, query) pair, a shape
           the per-run fence kernel does not take.

Range queries run the fence-pruned scan engine (DESIGN.md §10): each
scan binary-searches every structure's window bounds through the fence
machinery, gathers the contiguous in-window extents front-compacted
into one candidate row of static budgeted width (`range_cand`), and
merges them through the backend's sorted-segment merge-dedup op — the
jnp row sort or the Pallas `range_merge` tournament kernel — so a
scan's device work tracks its window, not the tree's capacity.
`range_many` is the batched multi-scan form, padded and bucketed like
`lookup_many`. `aggregate_many` rides the same candidate machinery but
reduces the merged keep mask directly — count(lo, hi) and sum(lo, hi)
without materializing rows, and without the `max_range` cut.

All ops exist as pure `_impl` forms (vmappable — the sharded engine maps
the dense lookup over shards) plus jitted wrappers. `lookup_many` is the
batched multi-key fast path: a padded lane array + traced valid count,
so arbitrary query counts share O(log Q) compiled programs while all Q
queries ride one fused Bloom-probe/fence-search pass per structure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import KEY_EMPTY, SEQ_NONE, SLSMParams
from repro.engine.backend import (candidate_gate, fence_window_bounds,
                                  get_backend, lookup_level_many,
                                  strided_fences)
# re-export (PR 6 moved the bucketing policy to repro.engine.batching):
# callers historically import bucket_pow2 from here
from repro.engine.batching import bucket_pow2  # noqa: F401
from repro.engine.levels import LevelState
from repro.engine.memtable import SLSMState

I32 = jnp.int32


def consider(best_seq, best_val, best_wt, seq_c, val_c, wt_c):
    """Newest-wins fold (paper 2.7): keep the candidate iff its seqno is
    higher — the batched form of 'the highest-ranked run wins'. The
    weight rides with the winner: presence is decided once, at the end,
    from the newest record's weight sign."""
    take = seq_c > best_seq
    return (jnp.where(take, seq_c, best_seq),
            jnp.where(take, val_c, best_val),
            jnp.where(take, wt_c, best_wt))


def search_stage(state: SLSMState, qs: jax.Array):
    """Probe the staging buffer (the active run, paper 2.1) for Q queries;
    returns per-query (seq, val, wt) with seq=SEQ_NONE on miss."""
    eq = state.stage_keys[None, :] == qs[:, None]            # (Q, 2Rn)
    seqm = jnp.where(eq, state.stage_seqs[None, :], SEQ_NONE)
    j = jnp.argmax(seqm, axis=1)
    seq_c = jnp.take_along_axis(seqm, j[:, None], axis=1)[:, 0]
    hit = seq_c >= 0
    return (seq_c, jnp.where(hit, state.stage_vals[j], 0),
            jnp.where(hit, state.stage_wts[j], 0))


def search_sorted_run(keys, vals, wts, seqs, count, qs):
    """Binary search one sorted run for a batch of queries (paper 2.7:
    memory runs are searched directly — no fence pointers)."""
    i = jnp.searchsorted(keys, qs).astype(I32)
    ic = jnp.minimum(i, keys.shape[0] - 1)
    hit = (i < count) & (keys[ic] == qs)
    return (jnp.where(hit, seqs[ic], SEQ_NONE), jnp.where(hit, vals[ic], 0),
            jnp.where(hit, wts[ic], 0))


def search_memory_runs(state: SLSMState, qs: jax.Array):
    """All R sealed memory runs in one vmapped pass (paper 2.2/2.7);
    newest-wins across runs via the per-query argmax over seqnos."""
    seqs_r, vals_r, wts_r = jax.vmap(
        lambda k, v, w, s, c: search_sorted_run(k, v, w, s, c, qs)
    )(state.buf_keys, state.buf_vals, state.buf_wts, state.buf_seqs,
      state.buf_counts)
    j = jnp.argmax(seqs_r, axis=0)                            # (Q,)
    q_iota = jnp.arange(qs.shape[0])
    return seqs_r[j, q_iota], vals_r[j, q_iota], wts_r[j, q_iota]


def level_gate(p: SLSMParams, lv: LevelState, level: int, qs: jax.Array):
    """(D, Q) candidate mask: min/max window AND Bloom positive (paper
    2.3). Delegates to `backend.candidate_gate` — the same invariant the
    dense path's fused `lookup_level_many` applies. Probes at `level`'s
    *effective* bit width/k (the current allocation, DESIGN.md §9)."""
    be = get_backend(p.backend)
    bits, _, kk = p.bloom_geometry(p.level_cap(level), p.level_eps(level))
    return candidate_gate(be, qs, lv.blooms, lv.mins, lv.maxs, kk, bits)


def search_level_dense(p: SLSMParams, lv: LevelState, level: int,
                       qs: jax.Array):
    """Exact disk-level search: one fused Bloom-probe + fence-search pass
    over all (run, query) pairs (`backend.lookup_level_many`), then a
    per-query newest-wins argmax across the level's D runs (paper 2.7).

    The Bloom probe uses the level's effective bit allocation and the
    fence search the effective stride view (every stride-th fence, an
    (mu*stride)-wide page window) — both static per allocation, so a
    retune swaps compiled programs, never array shapes."""
    be = get_backend(p.backend)
    bits, _, kk = p.bloom_geometry(p.level_cap(level), p.level_eps(level))
    stride, mu_eff = p.fence_view(level)
    fences = strided_fences(lv.fences, stride)
    hit, idxc = lookup_level_many(be, qs, lv.blooms, lv.mins, lv.maxs,
                                  fences, lv.keys, lv.counts, kk, mu_eff,
                                  bits)
    seqs_d = jnp.where(hit, jnp.take_along_axis(lv.seqs, idxc, axis=1),
                       SEQ_NONE)
    vals_d = jnp.where(hit, jnp.take_along_axis(lv.vals, idxc, axis=1), 0)
    wts_d = jnp.where(hit, jnp.take_along_axis(lv.wts, idxc, axis=1), 0)
    j = jnp.argmax(seqs_d, axis=0)
    q_iota = jnp.arange(qs.shape[0])
    return seqs_d[j, q_iota], vals_d[j, q_iota], wts_d[j, q_iota]


def search_level_sparse(p: SLSMParams, lv: LevelState, level: int,
                        qs: jax.Array):
    """Bloom-compacted disk search: only gated (run, query) pairs do the
    fence+page work — the TPU realization of 'skip the run on a Bloom miss'.

    Static capacity: cand_factor candidates per query on average. An
    overflowing gate (pathologically hot key ranges + tiny cand_factor)
    drops candidates, which can miss a hit — size cand_factor >= eps*D*L
    plus true-hit headroom, or use the dense path (lookup_batch sparse=False)
    when exactness is mandatory. Property tests cross-check both paths.

    The per-candidate fence search below mirrors backend.fence_window_idx
    on a (run, query)-compacted index set; keep the two in sync."""
    q_n = qs.shape[0]
    gate = level_gate(p, lv, level, qs)                       # (D, Q)
    cap = q_n * p.cand_factor
    d_idx, q_idx = jnp.nonzero(gate, size=cap, fill_value=-1)
    ok = d_idx >= 0
    d_c, q_c = jnp.maximum(d_idx, 0), jnp.maximum(q_idx, 0)
    qk = qs[q_c]
    stride, mu_eff = p.fence_view(level)
    fences_v = strided_fences(lv.fences, stride)

    def one(d, q):
        f = jnp.searchsorted(fences_v[d], q, side="right").astype(I32) - 1
        st = jnp.clip(f, 0, fences_v.shape[1] - 1) * mu_eff
        # last effective fence of a non-divisible stride: pin the window
        # inside the run so dynamic_slice cannot silently shift it (the
        # widened window still covers the whole partial fence group)
        st = jnp.minimum(st, lv.keys.shape[1] - mu_eff)
        win = jax.lax.dynamic_slice(lv.keys, (d, st), (1, mu_eff))[0]
        off = jnp.searchsorted(win, q).astype(I32)
        offc = jnp.minimum(off, mu_eff - 1)
        hit = (off < mu_eff) & (win[offc] == q) & (st + offc < lv.counts[d])
        idx = st + offc
        return (jnp.where(hit, lv.seqs[d, idx], SEQ_NONE),
                jnp.where(hit, lv.vals[d, idx], 0),
                jnp.where(hit, lv.wts[d, idx], 0))

    seq_c, val_c, wt_c = jax.vmap(one)(d_c, qk)
    seq_c = jnp.where(ok, seq_c, SEQ_NONE)
    best_seq = jnp.full((q_n,), SEQ_NONE, I32).at[q_c].max(
        jnp.where(ok, seq_c, SEQ_NONE), mode="drop")
    win_mask = ok & (seq_c == best_seq[q_c]) & (seq_c >= 0)
    imin = np.iinfo(np.int32).min
    best_val = jnp.full((q_n,), imin, I32).at[q_c].max(
        jnp.where(win_mask, val_c, imin), mode="drop")
    best_wt = jnp.full((q_n,), imin, I32).at[q_c].max(
        jnp.where(win_mask, wt_c, imin), mode="drop")
    found = best_seq >= 0
    return (best_seq, jnp.where(found, best_val, 0),
            jnp.where(found, best_wt, 0))


def _skip_if_empty(occupied, search_fn, q_n: int):
    """Runtime gate around one structure's search: `lax.cond` skips the
    whole fused pass when the structure holds nothing *right now*.

    Exact — an empty structure can only contribute misses (every hit
    requires ``idx < count``) — and traced, so occupancy changes never
    recompile: one program serves every occupancy. The adaptive tuner's
    read-optimized maintenance folds structures empty precisely so this
    gate can skip them (DESIGN.md §9). Under vmap (the sharded path) the
    cond lowers to a select that computes both branches — no win, no
    loss vs the ungated pass."""
    return jax.lax.cond(
        occupied, search_fn,
        lambda: (jnp.full((q_n,), SEQ_NONE, I32), jnp.zeros((q_n,), I32),
                 jnp.zeros((q_n,), I32)))


def lookup_batch_impl(p: SLSMParams, state: SLSMState, qs: jax.Array,
                      sparse: bool = False, skip_empty: bool = False):
    """Point lookups, newest-to-oldest across every structure (paper 2.7).

    Returns (vals, found). Deleted keys report found=False (paper 2.8):
    the newest record's weight is negative — the telescoped Z-set weight
    sum — so presence is its sign, and every int32 value (any payload
    bit pattern) is storable and retrievable.

    ``skip_empty`` (static; the adaptive tuner's read path sets it) wraps
    the memory-run search and each disk level's pass in a traced
    occupancy gate (`_skip_if_empty`) so a collapsed structure costs
    nothing at run time. False — the static-mode default — emits exactly
    the pre-tuner program.
    """
    qs = qs.astype(I32)
    q_n = qs.shape[0]
    best_seq, best_val, best_wt = search_stage(state, qs)
    if skip_empty:
        s2, v2, w2 = _skip_if_empty(state.run_count > 0,
                                    lambda: search_memory_runs(state, qs),
                                    q_n)
    else:
        s2, v2, w2 = search_memory_runs(state, qs)
    best_seq, best_val, best_wt = consider(best_seq, best_val, best_wt,
                                           s2, v2, w2)
    for level, lv in enumerate(state.levels):
        fn = search_level_sparse if sparse else search_level_dense
        if skip_empty:
            s3, v3, w3 = _skip_if_empty(
                lv.n_runs > 0,
                functools.partial(fn, p, lv, level, qs), q_n)
        else:
            s3, v3, w3 = fn(p, lv, level, qs)
        best_seq, best_val, best_wt = consider(best_seq, best_val, best_wt,
                                               s3, v3, w3)
    found = (best_seq >= 0) & (best_wt > 0)
    return jnp.where(found, best_val, 0), found


lookup_batch = functools.partial(
    jax.jit, static_argnums=(0, 3, 4))(lookup_batch_impl)


def lookup_many_impl(p: SLSMParams, state: SLSMState, qs: jax.Array,
                     n_valid: jax.Array, sparse: bool = False,
                     skip_empty: bool = False):
    """Padded-batch point lookup: the batched multi-key fast path.

    Semantically `lookup_batch_impl` over ``qs[:n_valid]``, but ``qs`` is
    a fixed-size (padded) lane array and ``n_valid`` is *traced* — so one
    compiled program serves any query count up to the pad width. The host
    drivers (`SLSM.lookup_many`, `ShardedSLSM.lookup`) pad to power-of-two
    buckets, giving O(log Q) distinct programs instead of one per Q.

    All Q lanes share each structure's single fused Bloom-probe +
    fence-search dispatch (paper 2.3/2.4 via `backend.lookup_level_many`);
    padded lanes report ``found=False, val=0``.
    """
    vals, found = lookup_batch_impl(p, state, qs, sparse, skip_empty)
    lane = jnp.arange(qs.shape[0], dtype=I32) < n_valid
    found = found & lane
    return jnp.where(found, vals, 0), found


lookup_many = functools.partial(
    jax.jit, static_argnums=(0, 4, 5))(lookup_many_impl)


def level_probe_stats_impl(p: SLSMParams, state: SLSMState, qs: jax.Array):
    """Per-level read telemetry for the tuner (DESIGN.md §9).

    Returns ``(candidates, hits)``, each ``(max_levels,)`` int32: per disk
    level, how many (run, query) pairs passed the min/max + Bloom gate
    (paper 2.3) and how many of those were true key matches. The gap is
    the level's observed false-positive traffic. The tuner uses the
    totals to gate its read-optimized switch (folding structure only
    pays when reads actually reach the disk levels) and exports the
    per-level FP fractions in the BENCH tuner telemetry. Levels not yet
    materialized report zeros. Dispatched on a *sample* of the query
    stream at write boundaries (the hot lookup path stays untouched).
    """
    qs = qs.astype(I32)
    be = get_backend(p.backend)
    cands = [jnp.zeros((), I32)] * p.max_levels
    hits = [jnp.zeros((), I32)] * p.max_levels
    for level, lv in enumerate(state.levels):
        bits, _, kk = p.bloom_geometry(p.level_cap(level), p.level_eps(level))
        stride, mu_eff = p.fence_view(level)
        fences = strided_fences(lv.fences, stride)
        gate = candidate_gate(be, qs, lv.blooms, lv.mins, lv.maxs, kk, bits)
        idx = be.fence_lookup_many(qs, fences, lv.keys, lv.counts, mu_eff)
        cands[level] = gate.sum(dtype=I32)
        hits[level] = (gate & (idx >= 0)).sum(dtype=I32)
    return jnp.stack(cands), jnp.stack(hits)


level_probe_stats = functools.partial(
    jax.jit, static_argnums=0)(level_probe_stats_impl)


# --------------------------------------------------------------------------
# range queries (paper 2.9) — the fence-pruned scan engine (DESIGN.md §10)
# --------------------------------------------------------------------------

def _range_group_bounds(p: SLSMParams, state: SLSMState, los: jax.Array,
                        his: jax.Array):
    """Per-structure [start, end) window bounds for Q scans.

    Returns a list of groups, one per structure family — the staging
    buffer, the sealed memory runs, then each materialized disk level —
    each a tuple ``(keys2d (N, cap), vals2d, wts2d, seqs2d, starts (Q, N),
    ends (Q, N))``. Memory structures are bounded by plain binary
    search; disk runs go through the fence pointers
    (`backend.fence_window_bounds`) under the level's effective stride
    view. Every disk level sits behind a min/max + occupancy `lax.cond`
    gate (the `skip_empty` pattern): a level no scan's window touches
    contributes zero-extent parts without doing any fence work.
    """
    q_n = los.shape[0]

    def sorted_bounds(keys, count):
        start = jnp.searchsorted(keys, los).astype(I32)
        end = jnp.minimum(jnp.searchsorted(keys, his).astype(I32), count)
        return jnp.minimum(start, end), end

    groups = []
    st, en = sorted_bounds(state.stage_keys, state.stage_count)
    groups.append((state.stage_keys[None], state.stage_vals[None],
                   state.stage_wts[None], state.stage_seqs[None],
                   st[:, None], en[:, None]))
    st, en = jax.vmap(sorted_bounds)(state.buf_keys, state.buf_counts)
    groups.append((state.buf_keys, state.buf_vals, state.buf_wts,
                   state.buf_seqs, st.T, en.T))
    for level, lv in enumerate(state.levels):
        stride, mu_eff = p.fence_view(level)
        fences = strided_fences(lv.fences, stride)

        def level_bounds(lv=lv, fences=fences, mu_eff=mu_eff):
            st, en = jax.vmap(
                lambda f, kk, c: fence_window_bounds(los, his, f, kk, c,
                                                     mu_eff)
            )(fences, lv.keys, lv.counts)
            return st.T, en.T                      # (Q, D)

        touched = ((lv.mins[None, :] < his[:, None])
                   & (lv.maxs[None, :] >= los[:, None])
                   & (lv.counts[None, :] > 0))
        zeros = jnp.zeros((q_n, lv.keys.shape[0]), I32)
        st, en = jax.lax.cond(jnp.any(touched), level_bounds,
                              lambda: (zeros, zeros))
        groups.append((lv.keys, lv.vals, lv.wts, lv.seqs, st, en))
    return groups


def _gather_candidates(p: SLSMParams, state: SLSMState, los: jax.Array,
                       his: jax.Array):
    """Front-compacted candidate gather shared by the range and aggregate
    engines: fence-prune every structure to its in-window extent, fill
    the static ``range_cand_eff`` budget sequentially, and apply the
    budget-overflow cut (everything at or past the first key any
    structure's extent was cut at is dropped, so dedup over the
    survivors is exact — PR 3's contract, budgeted).

    Returns ``(k, v, w, s, offsets, partial)``: (Q, C) candidate lanes
    (KEY_EMPTY / zero past each row's fill), (Q, P+1) exclusive segment
    boundaries, and the (Q, P) per-part overflow flags.
    """
    cand = p.range_cand_eff(len(state.levels))
    q_n = los.shape[0]

    groups = _range_group_bounds(p, state, los, his)
    starts = jnp.concatenate([g[4] for g in groups], axis=1)   # (Q, P)
    ends = jnp.concatenate([g[5] for g in groups], axis=1)
    exts = jnp.maximum(ends - starts, 0)
    n_parts = starts.shape[1]

    # sequential budget fill: part p gets taken_p = clip(C - cum_p) slots
    cum_full = jnp.cumsum(exts, axis=1)
    cum_full_ex = jnp.concatenate([jnp.zeros((q_n, 1), I32),
                                   cum_full[:, :-1]], axis=1)
    taken = jnp.clip(cand - cum_full_ex, 0, exts)
    partial = taken < exts
    offsets = jnp.concatenate([jnp.zeros((q_n, 1), I32),
                               jnp.cumsum(taken, axis=1)], axis=1)
    total = offsets[:, -1]

    # gather candidates front-compacted: lane j of a row belongs to the
    # part whose [offsets[p], offsets[p+1]) span covers j
    j = jnp.arange(cand, dtype=I32)
    part = jax.vmap(
        lambda off: jnp.searchsorted(off, j, side="right").astype(I32) - 1
    )(offsets)                                                  # (Q, C)
    part_c = jnp.clip(part, 0, n_parts - 1)
    src = (jnp.take_along_axis(starts, part_c, axis=1)
           + j[None, :] - jnp.take_along_axis(offsets, part_c, axis=1))

    k = jnp.full((q_n, cand), KEY_EMPTY, I32)
    v = jnp.zeros((q_n, cand), I32)
    w = jnp.zeros((q_n, cand), I32)
    s = jnp.zeros((q_n, cand), I32)
    # per-part key at the first excluded in-window element (the cut
    # boundary a budget overflow imposes); KEY_EMPTY where nothing is cut
    cut_keys = jnp.full((q_n, n_parts), KEY_EMPTY, I32)
    g0 = 0
    for gk, gv, gw, gs, gst, _ in groups:
        n_g, cap_g = gk.shape
        in_g = (part >= g0) & (part < g0 + n_g) & (j[None, :] < total[:, None])
        d = jnp.clip(part - g0, 0, n_g - 1)
        srcc = jnp.clip(src, 0, cap_g - 1)
        k = jnp.where(in_g, gk[d, srcc], k)
        v = jnp.where(in_g, gv[d, srcc], v)
        w = jnp.where(in_g, gw[d, srcc], w)
        s = jnp.where(in_g, gs[d, srcc], s)
        cut_idx = jnp.clip(gst + taken[:, g0:g0 + n_g], 0, cap_g - 1)
        d_iota = jnp.broadcast_to(jnp.arange(n_g), (q_n, n_g))
        cut_keys = cut_keys.at[:, g0:g0 + n_g].set(
            jnp.where(partial[:, g0:g0 + n_g], gk[d_iota, cut_idx],
                      KEY_EMPTY))
        g0 += n_g
    cut = cut_keys.min(axis=1)                                  # (Q,)

    # budget-overflow cut: drop everything at or past the first key any
    # structure's extent was cut at — below it every structure is fully
    # represented, so dedup over the survivors is exact
    ok = k < cut[:, None]
    k = jnp.where(ok, k, KEY_EMPTY)
    v = jnp.where(ok, v, 0)
    w = jnp.where(ok, w, 0)
    s = jnp.where(ok, s, 0)
    return k, v, w, s, offsets, partial


def range_scan_impl(p: SLSMParams, state: SLSMState, los: jax.Array,
                    his: jax.Array):
    """Q range scans [lo, hi) in one fused pass (paper 2.9, DESIGN.md §10).

    Per scan: fence-prune every structure to its contiguous in-window
    extent, gather the extents front-compacted into one candidate row of
    static width ``range_cand_eff`` (a budget, not per-structure
    padding — a scan's device work is O(its window), never O(capacity)),
    then one backend-dispatched sorted-segment merge applies the weighted
    survivor rule (newest-wins dedup + annihilation of negative-weight
    keys) before the single ``max_range`` cut.

    Returns ``(keys (Q, max_range), vals, counts (Q,), truncated (Q,))``,
    rows key-sorted and KEY_EMPTY-padded past their count. Exactness
    contract: a result row is always a correct sorted *prefix* of the
    window's live keys; ``truncated`` is False iff the row is the whole
    window — it is raised when the live keys exceed ``max_range`` or
    when the candidate budget overflowed (a structure's in-window extent
    was cut; the result then stops at the first key the cut could have
    affected, so stale versions and delete records still cancel exactly
    — PR 3's full-window dedup contract, budgeted).
    """
    be = get_backend(p.backend)
    mr = p.max_range
    los, his = los.astype(I32), his.astype(I32)
    q_n = los.shape[0]

    k, v, w, s, offsets, partial = _gather_candidates(p, state, los, his)

    k, v, w, s, keep = be.range_merge(k, v, w, s, offsets, True)
    live = keep.sum(axis=1, dtype=I32)
    pos = jnp.cumsum(keep, axis=1, dtype=I32) - 1
    idx = jnp.where(keep, pos, mr)
    row = jnp.broadcast_to(jnp.arange(q_n)[:, None], idx.shape)
    out_k = jnp.full((q_n, mr), KEY_EMPTY, I32).at[row, idx].set(
        k, mode="drop")
    out_v = jnp.zeros((q_n, mr), I32).at[row, idx].set(v, mode="drop")
    return (out_k, out_v, jnp.minimum(live, mr),
            (live > mr) | jnp.any(partial, axis=1))


def range_query_impl(p: SLSMParams, state: SLSMState, lo: jax.Array,
                     hi: jax.Array):
    """All live (key, value) with lo <= key < hi, newest-wins, deletes
    annihilated — the single-scan form of `range_scan_impl` (one row of
    the batched engine; same exactness contract).

    Returns (keys, vals, count, truncated): up to max_range results,
    key-sorted; `truncated` False guarantees the result is the whole
    window (it is raised past max_range live keys, or — with a finite
    `range_cand` budget — when a scan's candidate gather overflowed and
    the result is a cut-bounded prefix).
    """
    k, v, cnt, trunc = range_scan_impl(
        p, state, jnp.reshape(lo, (1,)), jnp.reshape(hi, (1,)))
    return k[0], v[0], cnt[0], trunc[0]


range_query = functools.partial(jax.jit, static_argnums=0)(range_query_impl)


def range_many_impl(p: SLSMParams, state: SLSMState, los: jax.Array,
                    his: jax.Array, n_valid: jax.Array):
    """Padded-batch range scans: the batched multi-scan fast path.

    Semantically `range_scan_impl` over ``(los, his)[:n_valid]``, but the
    window arrays are fixed-size (padded) lanes and ``n_valid`` is
    *traced*, so one compiled program serves any scan count up to the pad
    width (the drivers pad to the `RANGE_BUCKETS` grid, mirroring
    `lookup_many`). Padded lanes report count 0, truncated False.
    """
    k, v, cnt, trunc = range_scan_impl(p, state, los, his)
    lane = jnp.arange(los.shape[0], dtype=I32) < n_valid
    return (jnp.where(lane[:, None], k, KEY_EMPTY),
            jnp.where(lane[:, None], v, 0),
            jnp.where(lane, cnt, 0), jnp.where(lane, trunc, False))


range_many = functools.partial(jax.jit, static_argnums=0)(range_many_impl)


# --------------------------------------------------------------------------
# aggregates — count / sum over a window, riding the scan machinery
# --------------------------------------------------------------------------

def aggregate_many_impl(p: SLSMParams, state: SLSMState, los: jax.Array,
                        his: jax.Array, n_valid: jax.Array):
    """Q windowed aggregates in one fused pass: ``count(lo, hi)`` and
    ``sum(lo, hi)`` over the live keys of each window [lo, hi).

    Rides the exact same fence-pruned candidate gather + backend
    merge-dedup as `range_scan_impl` (DESIGN.md §10), but reduces the
    keep mask directly instead of scattering rows — so there is no
    ``max_range`` cut at all: an aggregate is exact whenever the
    candidate budget held (``truncated`` False), however wide the
    window. Sums are int32 with wraparound (the engine's value domain).

    Returns ``(counts (Q,), sums (Q,), truncated (Q,))``; padded lanes
    (>= n_valid) report zeros / False.
    """
    be = get_backend(p.backend)
    los, his = los.astype(I32), his.astype(I32)

    k, v, w, s, offsets, partial = _gather_candidates(p, state, los, his)
    k, v, w, s, keep = be.range_merge(k, v, w, s, offsets, True)
    counts = keep.sum(axis=1, dtype=I32)
    sums = jnp.where(keep, v, 0).sum(axis=1, dtype=I32)
    trunc = jnp.any(partial, axis=1)
    lane = jnp.arange(los.shape[0], dtype=I32) < n_valid
    return (jnp.where(lane, counts, 0), jnp.where(lane, sums, 0),
            jnp.where(lane, trunc, False))


aggregate_many = functools.partial(
    jax.jit, static_argnums=0)(aggregate_many_impl)
