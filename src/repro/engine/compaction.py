"""The Do-Merge cascade (paper Algorithm 2 / 2.5) as explicit policy + ops.

Device side: three jitted merge ops (buffer flush, level spill, deepest
compaction), all built on the backend-dispatched k-way merge — so the
paper's HeapMerge runs either as the XLA sort network or as the Pallas
merge-path tournament (`SLSMParams.backend`). Records are weighted
(DESIGN.md §13): merges move (key, weight, seq) lanes and gather
payloads only for surviving rows.

Host side: a `CompactionPolicy` decides *when* a level spills and *how
many* runs move — the axis along which real LSM systems specialize
(tiering vs leveling, cf. the Luo & Carey survey):

  TieringPolicy  — the paper's rule: wait until a level holds D runs,
                   then merge the ceil(m*D) oldest into the next level.
                   Lowest write amplification.
  LevelingPolicy — eager variant: merge a level's runs down as soon as
                   two coexist, keeping read amplification at ~1 run per
                   level at the cost of more merge work.

Annihilation stays a host decision (`scheduler.drop_annihilated_into`):
negative-weight records are elided only when a merge's output becomes
the deepest data (paper 2.5/2.8: deletes are committed there). *When*
these ops run is the merge scheduler's call (`repro.engine.scheduler`):
each op here is exactly one bounded `MergeStep`, dispatched either
synchronously (merge_budget=0) or paced across insert chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.params import KEY_EMPTY, SLSMParams
from repro.engine.backend import get_backend
from repro.engine.levels import (_KEY_MIN, empty_level, index_new_run,
                                 set_level_run, shift_level)
from repro.engine.memtable import SLSMState


# --------------------------------------------------------------------------
# host-driven merge policies
# --------------------------------------------------------------------------

class CompactionPolicy:
    """Decides when a disk level spills and how many runs move down."""

    name = "abstract"

    def validate(self, p: SLSMParams) -> None:
        """Raise if the parameter geometry cannot support this policy."""

    def needs_spill(self, p: SLSMParams, n_runs: int,
                    level: int = 0) -> bool:
        """Should a level holding `n_runs` runs be merged down? `level`
        lets depth-aware policies (the tuner's read-mode overlay) treat
        shallow and deep tiers differently; the paper's policies ignore
        it."""
        raise NotImplementedError

    def runs_to_spill(self, p: SLSMParams, n_runs: int) -> int:
        """How many of the level's oldest runs one spill moves down
        (jit-static: each distinct value is its own merge program)."""
        raise NotImplementedError

    def spill_sizes(self, p: SLSMParams) -> tuple:
        """Every distinct `runs_to_spill` value this policy can produce.

        The merge scheduler's warm() precompiles one spill program per
        (level, size, annihilation-flag) — `n_merge` is a jit-static
        argument, so each size is its own compiled program and an
        unwarmed size would stall the first insert chunk that needs it.
        """
        raise NotImplementedError


class TieringPolicy(CompactionPolicy):
    """The paper's policy (2.5): spill ceil(m*D) runs once a level is full."""

    name = "tiering"

    def needs_spill(self, p: SLSMParams, n_runs: int,
                    level: int = 0) -> bool:
        return n_runs >= p.D

    def runs_to_spill(self, p: SLSMParams, n_runs: int) -> int:
        """The paper's ceil(m*D) oldest runs (2.5), regardless of depth."""
        return p.disk_runs_merged

    def spill_sizes(self, p: SLSMParams) -> tuple:
        return (p.disk_runs_merged,)


class LevelingPolicy(CompactionPolicy):
    """Leveling variant: merge a level down as soon as `max_resident` runs
    coexist, so a level holds ~1 run at rest — fewer runs on the read
    path (each lookup probes at most `max_resident` runs per level)
    bought with more merge work, the classic tiering/leveling trade.
    Requires ceil(m*D) >= max_resident so a spill's output always fits
    one run of the next level."""

    name = "leveling"

    def __init__(self, max_resident: int = 2):
        if max_resident < 2:
            raise ValueError("max_resident must be >= 2")
        self.max_resident = max_resident

    def validate(self, p: SLSMParams) -> None:
        if p.D < self.max_resident:
            raise ValueError(
                f"LevelingPolicy(max_resident={self.max_resident}) needs "
                f"D >= {self.max_resident} run slots per level (D={p.D})")
        if p.disk_runs_merged < self.max_resident:
            raise ValueError(
                "LevelingPolicy needs ceil(m*D) >= max_resident so a spill "
                f"fits the next level's run capacity (ceil(m*D)="
                f"{p.disk_runs_merged}, max_resident={self.max_resident})")

    def needs_spill(self, p: SLSMParams, n_runs: int,
                    level: int = 0) -> bool:
        return n_runs >= self.max_resident

    def runs_to_spill(self, p: SLSMParams, n_runs: int) -> int:
        """All resident runs: a leveling spill leaves its level empty."""
        return n_runs

    def spill_sizes(self, p: SLSMParams) -> tuple:
        # a level spills at max_resident occupancy but can reach D runs
        # before the scheduler gets to it (forced chains, deferred steps)
        return tuple(range(self.max_resident, p.D + 1))


# --------------------------------------------------------------------------
# jitted merge ops (all k-way merges dispatch through the backend)
# --------------------------------------------------------------------------

def merge_buffer_to_level0_impl(p: SLSMParams, state: SLSMState,
                                drop_annihilated: bool) -> SLSMState:
    """Flush ceil(m*R_eff) oldest memory runs into disk level 0 (paper
    2.1/2.5). R_eff == R unless the tuner's write-buffer arm shrank the
    active buffer (DESIGN.md §9); level-0 capacity is sized from the
    physical R, so a smaller flush always fits."""
    be = get_backend(p.backend)
    mr = p.runs_merged_eff
    k, v, w, s, cnt = be.merge_runs(state.buf_keys[:mr], state.buf_vals[:mr],
                                    state.buf_wts[:mr], state.buf_seqs[:mr],
                                    drop_annihilated)
    k, v, w, s, filt, fences, mn, mx = index_new_run(p, 0, k, v, w, s, cnt)
    lv0 = set_level_run(state.levels[0], state.levels[0].n_runs,
                        k, v, w, s, cnt, filt, fences, mn, mx)

    def roll(a, fill):
        tail_shape = (mr,) + a.shape[1:]
        return jnp.concatenate([a[mr:], jnp.full(tail_shape, fill, a.dtype)])

    return state._replace(
        buf_keys=roll(state.buf_keys, KEY_EMPTY),
        buf_vals=roll(state.buf_vals, 0),
        buf_wts=roll(state.buf_wts, 0),
        buf_seqs=roll(state.buf_seqs, 0),
        buf_counts=roll(state.buf_counts, 0),
        buf_mins=roll(state.buf_mins, KEY_EMPTY),
        buf_maxs=roll(state.buf_maxs, _KEY_MIN),
        buf_blooms=roll(state.buf_blooms, 0),
        run_count=state.run_count - mr,
        levels=(lv0,) + state.levels[1:],
    )


merge_buffer_to_level0 = functools.partial(
    jax.jit, static_argnums=(0, 2), donate_argnums=1)(
        merge_buffer_to_level0_impl)


def merge_level_down_impl(p: SLSMParams, state: SLSMState, level: int,
                          n_merge: int, drop_annihilated: bool) -> SLSMState:
    """Merge the `n_merge` oldest runs of `level` into one run of `level+1`.

    `n_merge` is the policy's `runs_to_spill` (ceil(m*D) for tiering, the
    level's occupancy for leveling)."""
    be = get_backend(p.backend)
    src = state.levels[level]
    k, v, w, s, cnt = be.merge_runs(src.keys[:n_merge], src.vals[:n_merge],
                                    src.wts[:n_merge], src.seqs[:n_merge],
                                    drop_annihilated)
    k, v, w, s, filt, fences, mn, mx = index_new_run(p, level + 1,
                                                     k, v, w, s, cnt)
    dst = state.levels[level + 1]
    dst = set_level_run(dst, dst.n_runs, k, v, w, s, cnt, filt, fences,
                        mn, mx)
    src = shift_level(p, src, n_merge)
    levels = (state.levels[:level] + (src, dst)
              + state.levels[level + 2:])
    return state._replace(levels=levels)


merge_level_down = functools.partial(
    jax.jit, static_argnums=(0, 2, 3, 4), donate_argnums=1)(
        merge_level_down_impl)


def compact_last_level_impl(p: SLSMParams, state: SLSMState):
    """In-place compaction of the deepest level: merge all D runs into slot 0.

    This is always the deepest data, so annihilation commits here (paper
    2.5: 'keys flagged for delete are not written ... at all' — the
    newest record's weight sums to <= 0 and the row is dropped).
    Returns (state, raw_count); the host raises if raw_count exceeds the
    deepest run capacity (the TPU analogue of running out of disk)."""
    be = get_backend(p.backend)
    last = p.max_levels - 1
    lv = state.levels[last]
    k, v, w, s, cnt = be.merge_runs(lv.keys, lv.vals, lv.wts, lv.seqs, True)
    k, v, w, s, filt, fences, mn, mx = index_new_run(p, last, k, v, w, s, cnt)
    fresh = empty_level(p, last)
    fresh = set_level_run(fresh, 0, k, v, w, s,
                          jnp.minimum(cnt, p.level_cap(last)),
                          filt, fences, mn, mx)
    return state._replace(levels=state.levels[:last] + (fresh,)), cnt


compact_last_level = functools.partial(
    jax.jit, static_argnums=0)(compact_last_level_impl)
