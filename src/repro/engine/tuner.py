"""Adaptive memory/filter tuner: one byte budget, re-partitioned at runtime.

The paper's closing claim is that "the breadth of tuning parameters
inherent to the sLSM allows it broad flexibility for excellent
performance across a wide variety of workloads" — but a *static* choice
of those parameters serves exactly one workload. Two lines of follow-up
work say what to do instead: *Breaking Down Memory Walls* (Luo, 2020)
re-partitions the memory budget between the write buffer and the filter
memory as the workload shifts, and the Monkey line of work (via the
Luo & Carey LSM survey) allocates Bloom bits *per level* — shallow,
small, hot levels get dense filters, the deep bulk level gets few bits
per element — instead of one global eps.

This module is that controller, TPU-adapted (DESIGN.md §9):

  Allocation — one point in the tuning space the controller moves
      through: active memory runs (`r_eff`), memory-run filter FP
      (`eps_mem`), per-level filter FPs (`eps_per_level`, Monkey-style),
      and the fence-pointer stride. An allocation is *applied* by
      swapping the driver's active `SLSMParams` (a jit static argument)
      — array shapes never change, because the state is physically
      sized for the densest allocation the policy admits
      (`SLSMParams.bloom_words_physical`).

  byte model — `allocation_bytes` prices an allocation: 12 bytes per
      buffered element (key/value/seqno) plus 4 bytes per filter word
      plus 4 bytes per *consulted* fence. Presets must fit the policy's
      `budget_bytes` (default: what the static configuration already
      uses), so the tuner can only *move* memory, never grow it.

  Tuner — the host-side controller. It folds the read/write mix into an
      EWMA (counters the drivers already keep in `stats`), samples
      per-level probe/hit telemetry off the read path
      (`read_path.level_probe_stats`), and at each decision point picks
      the write-/balanced-/read-optimized preset. A decision is not
      applied inline: it becomes a pending `RETUNE` merge step
      (`repro.engine.scheduler`), so allocation switches ride the same
      pacing/drain machinery as every other piece of maintenance work.

A `RETUNE` step rebuilds every resident filter under the new allocation
(`retune_filters`) in one jitted dispatch; runs written afterwards get
the new geometry for free (`levels.index_new_run` builds at the active
allocation — the rebuild-on-spill path). Reads stay exact at every
point: filters are only ever *rebuilt from the keys they cover*, so no
probe can see a filter built under a different geometry than the probe
uses.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom as BL
from repro.core.params import KEY_EMPTY, SLSMParams
from repro.engine.compaction import CompactionPolicy

I32 = jnp.int32

ELEM_BYTES = 12          # key + value + seqno, int32 each
WORD_BYTES = 4           # Bloom filters are uint32 word arrays
FENCE_BYTES = 4          # one int32 key per consulted fence
EPS_CEIL = 0.5           # never allocate a filter worse than a coin flip

BALANCED, WRITE, READ = "balanced", "write", "read"


@dataclass(frozen=True)
class Allocation:
    """One point in the tuner's search space (hashable: it becomes part
    of a jit-static `SLSMParams` via `apply`)."""

    name: str
    r_eff: int                     # active memory runs (<= physical R)
    eps_mem: float                 # memory-run filter FP rate
    eps_per_level: tuple           # per-disk-level FP rates (Monkey-style)
    fence_stride: int = 1          # read-side fence subsampling

    def apply(self, p: SLSMParams) -> SLSMParams:
        """The active parameter set realizing this allocation. Only
        effective fields change — physical geometry (R, Rn, level caps,
        filter word widths, fence arrays) is identical to `p`'s, so the
        state pytree built under `p` serves every allocation."""
        return dataclasses.replace(
            p, r_eff=self.r_eff, eps_mem=self.eps_mem,
            eps_per_level=self.eps_per_level,
            fence_stride=self.fence_stride)


def _words(p: SLSMParams, n: int, eps: float) -> int:
    return p.bloom_geometry(n, eps)[1]


def allocation_bytes(p: SLSMParams, alloc: Allocation) -> int:
    """Modeled resident bytes of an allocation: write buffer (staging +
    active runs' payload), filter words (memory + disk), and consulted
    fences. This is the paper's memory story made explicit: R*Rn buys
    insert slack, filter bits buy read gating (paper 2.3), fences buy
    page granularity (2.4) — one budget, three arms."""
    mem = p.stage_cap * ELEM_BYTES + alloc.r_eff * p.Rn * ELEM_BYTES
    filt = alloc.r_eff * _words(p, p.Rn, alloc.eps_mem) * WORD_BYTES
    fences = 0
    for lvl in range(p.max_levels):
        cap = p.level_cap(lvl)
        filt += p.D * _words(p, cap, alloc.eps_per_level[lvl]) * WORD_BYTES
        n_f = p.n_fences(lvl)
        fences += p.D * -(-n_f // alloc.fence_stride) * FENCE_BYTES
    return mem + filt + fences


def monkey_eps_per_level(p: SLSMParams, filter_budget_bytes: int,
                         floor: float) -> tuple:
    """Monkey-style per-level FP allocation under a filter byte budget.

    The optimal allocation gives deeper (geometrically larger) levels
    proportionally *higher* FP rates — a bit spent on a small shallow
    level gates more lookups per byte than one spent on the bulk level.
    We realize the shape as eps_l = base * T^l (T = the level growth
    factor ceil(m*D)) and binary-search `base` so the densest profile
    that fits the budget is chosen, clamped to [floor, EPS_CEIL].
    """
    growth = max(2, p.disk_runs_merged)

    def profile(base: float) -> tuple:
        return tuple(min(EPS_CEIL, max(floor, base * growth ** lvl))
                     for lvl in range(p.max_levels))

    def cost(eps_levels: tuple) -> int:
        return sum(p.D * _words(p, p.level_cap(lvl), e) * WORD_BYTES
                   for lvl, e in enumerate(eps_levels))

    lo, hi = math.log(floor), math.log(EPS_CEIL)   # log-space bisection
    if cost(profile(floor)) <= filter_budget_bytes:
        return profile(floor)                       # budget covers densest
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if cost(profile(math.exp(mid))) <= filter_budget_bytes:
            hi = mid
        else:
            lo = mid
    return profile(math.exp(hi))


def build_presets(p: SLSMParams) -> dict:
    """The three allocations the controller moves between, all priced
    within the policy budget (default: the static configuration's own
    bytes — the tuner may only move memory, never grow it).

      balanced — exactly the configured static parameters (the identity
                 allocation; applying it is a no-op by construction).
      write    — full write buffer, sparse `eps_write` filters (cheap to
                 build: every seal/flush/spill builds filters, so filter
                 density is *write-path* cost), coarser fence view.
      read     — half the write buffer given back to the budget and
                 spent on dense Monkey-allocated per-level filters;
                 finest fence view. Flushes come twice as often but the
                 read path gets maximum gating accuracy.
    """
    floor = min(p.eps, p.tuning.eps_floor)
    eps_levels_now = tuple(p.level_eps(lvl) for lvl in range(p.max_levels))
    balanced = Allocation(BALANCED, p.R_eff, p.mem_eps, eps_levels_now,
                          p.fence_stride)
    budget = (p.tuning.budget_bytes if p.tuning.budget_bytes is not None
              else allocation_bytes(p, balanced))

    # write preset: filters never DENSER than the configured statics —
    # filter density is write-path cost, so each site takes the sparser
    # of eps_write and its balanced rate (a user already running eps=0.1
    # gets eps=0.1, not a denser 2e-2 that would bust the byte budget)
    write = Allocation(
        WRITE, p.R,
        min(EPS_CEIL, max(p.tuning.eps_write, floor, p.mem_eps)),
        tuple(min(EPS_CEIL, max(p.tuning.eps_write, floor, p.level_eps(lvl)))
              for lvl in range(p.max_levels)),
        fence_stride=max(2, p.fence_stride))

    # read-optimized: collapse the write buffer to ONE active run (every
    # sealed run flushes straight to disk, so the R-run memory search
    # empties out and the read path's occupancy gate skips it) and
    # reshape the per-level filter bits Monkey-style at the *balanced*
    # filter budget. Monkey's shape — deeper, larger levels get fewer
    # bits per element — is kept; maximal density is not: in this TPU
    # adaptation a probe's cost scales with k and filter footprint while
    # a hit saves no I/O, so spending the freed write-buffer bytes on
    # denser filters would buy FP-rate at the price of wall-clock. The
    # freed bytes stay headroom under the budget cap; an I/O-backed
    # deployment would spend them (Monkey proper, Luo 2020).
    r_read = 1
    balanced_filter_bytes = sum(
        p.D * _words(p, p.level_cap(lvl), p.level_eps(lvl)) * WORD_BYTES
        for lvl in range(p.max_levels))
    read = Allocation(
        READ, r_read, p.mem_eps,
        monkey_eps_per_level(p, balanced_filter_bytes, floor),
        fence_stride=1)

    presets = {BALANCED: balanced, WRITE: write, READ: read}
    for alloc in presets.values():
        used = allocation_bytes(p, alloc)
        if used > budget:
            raise ValueError(
                f"tuner preset {alloc.name!r} needs {used} bytes, over the "
                f"{budget}-byte budget — raise TuningPolicy.budget_bytes "
                "or eps_floor")
    return presets


class ReadModePolicy(CompactionPolicy):
    """Depth-aware eager compaction overlay for the read allocation.

    While the READ allocation is active, the single-tree scheduler swaps
    its compaction policy for this one (`SLSM.policy_active`): level 0
    spills as soon as two runs coexist (and spills all of them), so the
    read-side voluntary maintenance (`MergeScheduler.on_read`) steadily
    *empties* the shallow structure the write phase left behind — and an
    emptied structure drops out of the lookup at run time
    (read_path._skip_if_empty), which is where the read win comes from.

    Depth-aware on purpose: a lookup pays per *level pass* (one fused
    vmapped dispatch over a level's D run slots), not per run, so
    folding level l into level l+1 only helps when it leaves l empty and
    l+1 was already live — and deep-level merges touch geometrically
    more elements (paper 2.4). Eager folding is therefore confined to
    level 0; deeper tiers keep the paper's tiering rule. This trades
    bounded write amplification for read latency — the classic
    tiering->leveling move (Luo & Carey's survey axis) executed at
    runtime, on the one level where it pays.
    """

    name = "read-mode"

    def needs_spill(self, p: SLSMParams, n_runs: int,
                    level: int = 0) -> bool:
        if level == 0:
            # even a single resident run folds down: level 0 then stays
            # empty between write trickles and its pass is skipped at
            # run time by every lookup in the read phase
            return n_runs >= 1
        return n_runs >= p.D

    def runs_to_spill(self, p: SLSMParams, n_runs: int) -> int:
        """All resident runs — a read-mode fold leaves its level empty,
        which is the whole point (the emptied pass is skipped)."""
        return n_runs

    def spill_sizes(self, p: SLSMParams) -> tuple:
        return tuple(range(1, p.D + 1))


# --------------------------------------------------------------------------
# filter rebuild (the device half of a RETUNE step)
# --------------------------------------------------------------------------

def retune_filters_impl(p: SLSMParams, state):
    """Rebuild every resident Bloom filter under `p`'s (new) effective
    allocation, in place of the old ones — one jitted dispatch.

    Identical build rules to the original construction sites
    (`memtable.seal_run` for memory runs, `levels.index_new_run` for
    disk runs), so retuning to the active allocation is a bitwise no-op
    and probes always see filters built at the geometry they probe with.
    Fences and run payloads are untouched: fences are built at finest
    granularity once and strided at read time.
    """
    rn = p.Rn
    bits_m, _, k_m = p.bloom_geometry(rn, p.mem_eps)
    wb = p.bloom_words_physical(rn, p.mem_eps)

    def rebuild_mem(keys, count):
        valid = jnp.arange(rn, dtype=I32) < count
        return BL.bloom_build(keys, valid, wb, k_m, bits_m)

    buf_blooms = jax.vmap(rebuild_mem)(state.buf_keys, state.buf_counts)
    levels = []
    for lvl, lv in enumerate(state.levels):
        cap = p.level_cap(lvl)
        bits, _, kk = p.bloom_geometry(cap, p.level_eps(lvl))
        w = p.bloom_words_physical(cap, p.level_eps(lvl))
        blooms = jax.vmap(
            lambda kx: BL.bloom_build(kx, kx != KEY_EMPTY, w, kk, bits)
        )(lv.keys)
        levels.append(lv._replace(blooms=blooms))
    return state._replace(buf_blooms=buf_blooms, levels=tuple(levels))


retune_filters = functools.partial(jax.jit, static_argnums=0,
                                   donate_argnums=1)(retune_filters_impl)


# --------------------------------------------------------------------------
# the controller
# --------------------------------------------------------------------------

class Tuner:
    """Host-side workload observer + allocation chooser.

    Owns no device state: it reads the op counters the drivers feed it,
    keeps EWMAs, and exposes `pending`/`target` to the merge scheduler,
    which applies decisions as `RETUNE` steps (so pacing budgets and the
    `drain()` barrier govern allocation switches exactly like merges).
    With a static policy (the default) every method is an inert no-op
    and the driver's behaviour is bit-identical to a tuner-less engine.
    """

    def __init__(self, drv):
        self.drv = drv                      # driver: .p, .p_active, .stats
        p = drv.p
        self.policy = p.tuning
        self.enabled = self.policy.mode == "adaptive"
        self.presets = build_presets(p) if self.enabled else {}
        self.active = BALANCED
        self.target = BALANCED
        self.budget_bytes = (allocation_bytes(p, self.presets[BALANCED])
                             if self.enabled else None)
        self.read_frac = 0.5                # EWMA of the read share
        self._win_reads = 0
        self._win_writes = 0
        self._since_decision = 0
        self._windows = 0
        self._probe_sampled = False
        # per-level probe telemetry (sampled at write boundaries from the
        # most recent read batch, so the instrumented dispatch never
        # rides a latency-sensitive lookup): gate passes vs true hits —
        # the gap is observed FP traffic per level
        self.last_queries: np.ndarray | None = None
        self.level_candidates = np.zeros(p.max_levels, np.int64)
        self.level_hits = np.zeros(p.max_levels, np.int64)
        self._n_samples = 0

    # -- observation hooks (called by the drivers) -------------------------
    def note_writes(self, n: int) -> None:
        """Fold `n` write ops into the current observation window."""
        if self.enabled and n:
            self._win_writes += int(n)
            self._since_decision += int(n)

    def note_reads(self, n: int) -> None:
        """Fold `n` read ops into the current observation window."""
        if self.enabled and n:
            self._win_reads += int(n)
            self._since_decision += int(n)

    def take_probe_sample(self) -> bool:
        """At most one per-level probe-telemetry sample every fourth
        decision window — the instrumented lookup costs a device
        dispatch on the read path, so the driver asks before paying for
        it and the controller keeps the duty cycle low."""
        if not self.enabled or self._probe_sampled or self._windows % 4:
            return False
        self._probe_sampled = True
        return True

    def note_probe_stats(self, candidates, hits) -> None:
        """Fold one sampled `read_path.level_probe_stats` result in."""
        if self.enabled:
            self.level_candidates += np.asarray(candidates, np.int64)
            self.level_hits += np.asarray(hits, np.int64)
            self._n_samples += 1

    def _disk_traffic_observed(self) -> bool:
        """Do sampled reads actually reach the disk levels? The
        read-optimized fold only pays off when lookups probe disk
        structure — a memtable-answered read mix gains nothing from
        collapsing it. No samples yet = assume yes (don't block the
        first shift on sampling luck)."""
        return self._n_samples == 0 or int(self.level_candidates.sum()) > 0

    @property
    def level_fp_observed(self) -> np.ndarray:
        """Per-level observed false-positive fraction of gate passes
        (candidates that were not hits; NaN-free: 0 where unprobed)."""
        c = np.maximum(self.level_candidates, 1)
        return (self.level_candidates - self.level_hits) / c

    # -- decisions ---------------------------------------------------------
    @property
    def pending(self) -> bool:
        """True when a decided allocation switch awaits its RETUNE step."""
        return self.enabled and self.target != self.active

    def allocation(self, name: str) -> Allocation:
        """The preset `Allocation` registered under `name`
        (balanced | write | read)."""
        return self.presets[name]

    def decide(self) -> None:
        """Fold the observation window into the EWMA and (re)pick the
        target preset. Called at chunk boundaries and on the read path;
        acts at most once per `policy.interval` observed ops."""
        if not self.enabled or self._since_decision < self.policy.interval:
            return
        total = self._win_reads + self._win_writes
        if total == 0:
            return
        frac = self._win_reads / total
        a = self.policy.ewma
        self.read_frac = (1 - a) * self.read_frac + a * frac
        self._win_reads = self._win_writes = 0
        self._since_decision = 0
        self._windows += 1
        self._probe_sampled = False
        if (self.read_frac >= self.policy.read_heavy
                and self._disk_traffic_observed()):
            self.target = READ
        elif (1.0 - self.read_frac) >= self.policy.write_heavy:
            self.target = WRITE
        # middle zone: hysteresis — keep the current target rather than
        # bouncing through `balanced` while the EWMA crosses between the
        # extremes (each switch costs a full filter rebuild; a dead zone
        # means a shift pays for exactly one)

    def applied(self) -> None:
        """The scheduler ran the RETUNE step: the target is now active."""
        self.active = self.target
