"""Device-resident mixed-op tape: one `lax.scan` over a tagged op window.

The host-side drivers execute a mixed op stream as one device dispatch
per operation — every insert chunk, lookup batch, and range scan pays a
host->device launch and (for reads) a device->host sync before the next
op can even be issued. For a *serving* workload, where a coalescing
window holds a few dozen small heterogeneous chunks, that per-op
ping-pong dominates the wall clock.

This module lowers a whole window to ONE jitted program: a `lax.scan`
whose carry is the engine state and whose xs are T tagged slots —

  opcode (T,) i32        OP_NOP | OP_WRITE | OP_LOOKUP | OP_RANGE
  keys   (T, Rn) i32     write keys / lookup queries / range los lanes
  vals   (T, Rn) i32     write values / range his
  wts    (T, Rn) i32     write record weights (+1 insert, -1 delete)
  n_valid (T,) i32       live lanes in the slot

Each slot's body `lax.switch`es on the opcode into the engine's own
pure `_impl` ops (memtable.stage_append_impl + seal_run_impl,
read_path.lookup_many_impl / range_many_impl), so tape semantics are
the host path's semantics by construction — same ops, same order. A
WRITE slot seals in-scan (`lax.cond` on the staged count) when it fills
the staging buffer; the host precondition (`SLSM.run_tape`'s headroom
pass) guarantees a free run slot exists for every seal the tape can
trigger, because `seal_run_impl` at run_count == R would silently
overwrite the newest run.

Slot counts quantize to `batching.TAPE_BUCKETS` (NOP-padded), so the
whole serving grid is a handful of precompiled interpreters
(`SLSM.warm_tape`); steady-state windows never JIT and never sync
per-op — results come back as stacked per-slot lanes, one transfer per
tape.

Range slots carry `range_lanes(p)` (lo, hi) pairs in their first lanes
(los in `keys`, his in `vals`); write and lookup slots carry up to Rn
lanes. Maintenance beyond the in-scan seal (flush/spill/compact/retune)
stays a host decision between tapes — the serving layer's maintenance
governor (repro.serve) spends that budget at window boundaries.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import KEY_EMPTY, SLSMParams
from repro.engine import memtable as MT
from repro.engine import read_path as RP
from repro.engine.batching import tape_bucket

I32 = jnp.int32

# slot opcodes (the scan body's switch index; NOP pads tapes to their
# bucket width and contributes nothing)
OP_NOP, OP_WRITE, OP_LOOKUP, OP_RANGE = 0, 1, 2, 3

OPCODES = {"write": OP_WRITE, "lookup": OP_LOOKUP, "range": OP_RANGE}


def range_lanes(p: SLSMParams) -> int:
    """Range (lo, hi) lanes per tape slot: a small static width — range
    slots are rare next to write/lookup slots, and each lane is a whole
    `max_range`-wide result row in the tape's output."""
    return min(4, p.Rn)


class TapeChunk(NamedTuple):
    """One coalesced same-kind op chunk, host-side.

    kind: 'write' | 'lookup' | 'range'. For writes, `keys`/`vals` are
    the staged pairs and `wts` the record weights (+1 insert, -1
    delete; None means all +1) — at most Rn of them. For lookups,
    `keys` are the queries (vals/wts unused) — at most Rn. For ranges,
    `keys` are the lo bounds and `vals` the hi bounds — at most
    `range_lanes(p)` scans.
    """
    kind: str
    keys: np.ndarray
    vals: np.ndarray
    wts: np.ndarray | None = None


def chunk_capacity(p: SLSMParams, kind: str) -> int:
    """Max ops one tape slot of `kind` carries (the coalescer's chunk
    split bound): Rn lanes for writes/lookups, `range_lanes` scans for
    ranges."""
    return range_lanes(p) if kind == "range" else p.Rn


def build_tape(p: SLSMParams, chunks: Sequence[TapeChunk],
               slots: int | None = None):
    """Pack host chunks into the tape's padded slot arrays.

    Returns ``(opcodes (T,), keys (T, Rn), vals (T, Rn), wts (T, Rn),
    n_valid (T,))`` numpy arrays with ``T = tape_bucket(len(chunks))``
    (or the explicit `slots` override, which must hold them); slots past
    the chunk list are NOP. Each chunk must respect `chunk_capacity`. A
    write chunk with ``wts=None`` stages all-insert (+1) weights.
    """
    n = len(chunks)
    t = tape_bucket(n) if slots is None else slots
    if n > t:
        raise ValueError(f"{n} chunks exceed the {t}-slot tape")
    rn = p.Rn
    ops = np.zeros(t, np.int32)
    keys = np.full((t, rn), KEY_EMPTY, np.int32)
    vals = np.zeros((t, rn), np.int32)
    wts = np.zeros((t, rn), np.int32)
    nv = np.zeros(t, np.int32)
    for i, ch in enumerate(chunks):
        cap = chunk_capacity(p, ch.kind)
        k = np.asarray(ch.keys, np.int32).reshape(-1)
        v = np.asarray(ch.vals, np.int32).reshape(-1)
        if len(k) > cap:
            raise ValueError(
                f"{ch.kind} chunk of {len(k)} ops exceeds its per-slot "
                f"capacity {cap}")
        ops[i] = OPCODES[ch.kind]
        keys[i, :len(k)] = k
        vals[i, :len(v)] = v
        if ch.kind == "write":
            w = (np.ones(len(k), np.int32) if ch.wts is None
                 else np.asarray(ch.wts, np.int32).reshape(-1))
            wts[i, :len(w)] = w
        nv[i] = len(k)
    return ops, keys, vals, wts, nv


def _slot_zeros(p: SLSMParams, width: int):
    """The all-miss per-slot output pytree (what NOP slots — and the
    lanes a slot's kind does not produce — report)."""
    rb, mr = range_lanes(p), p.max_range
    return (jnp.zeros((width,), I32),                 # lookup vals
            jnp.zeros((width,), bool),                # lookup found
            jnp.full((rb, mr), KEY_EMPTY, I32),       # range keys
            jnp.zeros((rb, mr), I32),                 # range vals
            jnp.zeros((rb,), I32),                    # range counts
            jnp.zeros((rb,), bool),                   # range truncated
            jnp.zeros((), I32))                       # seals this slot


def tape_exec_impl(p: SLSMParams, state, opcodes: jax.Array,
                   keys: jax.Array, vals: jax.Array, wts: jax.Array,
                   n_valid: jax.Array,
                   sparse: bool = False, skip_empty: bool = False):
    """Run a T-slot mixed-op tape as one `lax.scan` (pure; vmappable).

    Returns ``(state, ys)`` where ys is the per-slot output tuple of
    `_slot_zeros` shapes stacked along a leading T axis: lookup slots
    fill lanes ``[:n_valid]`` of the (T, Rn) val/found planes, range
    slots fill rows ``[:n_valid]`` of the (T, rb, max_range) planes,
    write slots report their in-scan seal count. Slot semantics are
    exactly the host driver's op sequence: state flows through the scan
    carry, so every slot reads its predecessors' writes.

    `sparse`/`skip_empty` are the read path's static mode flags
    (read_path.lookup_batch_impl), applied to every lookup slot.
    """
    rb = range_lanes(p)
    width = keys.shape[1]

    def nop(st, k, v, w, n):
        return st, _slot_zeros(p, width)

    def write(st, k, v, w, n):
        st = MT.stage_append_impl(p, st, k, v, w, n)
        do_seal = st.stage_count >= p.Rn
        st = jax.lax.cond(do_seal, lambda s: MT.seal_run_impl(p, s),
                          lambda s: s, st)
        out = _slot_zeros(p, width)
        return st, out[:6] + (do_seal.astype(I32),)

    def lookup(st, k, v, w, n):
        lv, lf = RP.lookup_many_impl(p, st, k, n, sparse, skip_empty)
        out = _slot_zeros(p, width)
        return st, (lv, lf) + out[2:]

    def range_(st, k, v, w, n):
        rk, rv, rc, rt = RP.range_many_impl(p, st, k[:rb], v[:rb], n)
        out = _slot_zeros(p, width)
        return st, out[:2] + (rk, rv, rc, rt) + out[6:]

    def body(st, xs):
        op, k, v, w, n = xs
        return jax.lax.switch(jnp.clip(op, 0, 3),
                              [nop, write, lookup, range_], st, k, v, w, n)

    return jax.lax.scan(body, state,
                        (opcodes.astype(I32), keys.astype(I32),
                         vals.astype(I32), wts.astype(I32),
                         n_valid.astype(I32)))


tape_exec = functools.partial(
    jax.jit, static_argnums=(0, 7, 8), donate_argnums=1)(tape_exec_impl)


def unpack_tape(p: SLSMParams, chunks: Sequence[TapeChunk], ys) -> List:
    """Per-chunk host results from a tape's stacked device outputs.

    One `np.asarray` pass per output plane (the tape's single
    device->host sync), then slot i's lanes are trimmed to chunk i's op
    count. Returns one entry per chunk: writes -> the in-scan seal count
    (int); lookups -> ``(vals (n,), found (n,))``; ranges -> ``(keys
    (n, max_range), vals, counts (n,), truncated (n,))``.
    """
    lv, lf, rk, rv, rc, rt, sealed = (np.asarray(y) for y in ys)
    out = []
    for i, ch in enumerate(chunks):
        n = len(np.asarray(ch.keys).reshape(-1))
        if ch.kind == "write":
            out.append(int(sealed[i]))
        elif ch.kind == "lookup":
            out.append((lv[i, :n], lf[i, :n]))
        else:
            out.append((rk[i, :n], rv[i, :n], rc[i, :n], rt[i, :n]))
    return out


def tape_seal_bound(p: SLSMParams, stage_count: int,
                    chunks: Sequence[TapeChunk]) -> int:
    """Upper bound on the seals a tape can trigger in-scan: every Rn
    staged keys force one (dedup only ever lowers the true count). The
    headroom precondition (`SLSM.run_tape`) must reserve this many free
    run slots before dispatching the tape."""
    staged = stage_count + sum(
        len(np.asarray(c.keys).reshape(-1)) for c in chunks
        if c.kind == "write")
    return staged // p.Rn
