"""sLSM core: the paper's contribution as a composable JAX module."""
from repro.core.params import (KEY_EMPTY, SEQ_NONE, TOMBSTONE,  # noqa: F401
                               SLSMParams)
from repro.core.slsm import (SLSM, LevelState, SLSMState,  # noqa: F401
                             init_state, lookup_batch, range_query)
