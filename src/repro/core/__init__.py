"""sLSM core: the paper's contribution as a composable JAX module.

Engine symbols (`SLSM`, `SLSMState`, ...) resolve lazily (PEP 562):
`repro.core.slsm` is now a facade over the layered `repro.engine`
package, whose modules import the leaf modules here (params, bloom,
runs) — lazy resolution keeps that dependency acyclic regardless of
which package is imported first.
"""
from repro.core.params import (KEY_EMPTY, SEQ_NONE, TOMBSTONE,  # noqa: F401
                               SLSMParams, TuningPolicy)

_ENGINE_EXPORTS = ("SLSM", "ShardedSLSM", "LevelState", "SLSMState",
                   "init_state", "lookup_batch", "range_query")


def __getattr__(name: str):
    if name == "slsm":  # attribute-style submodule access after bare import
        import importlib
        return importlib.import_module("repro.core.slsm")
    if name in _ENGINE_EXPORTS:
        from repro.core import slsm
        return getattr(slsm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_ENGINE_EXPORTS) + ["slsm"])
