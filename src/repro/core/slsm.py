"""The Skiplist-Based LSM Tree — TPU-native JAX engine.

Paper structure (Szanto 2018) preserved exactly:
  * memory buffer of R runs x Rn elements, one active run (here: a sorted
    staging buffer — the dense-array form of the active skiplist, see
    DESIGN.md §2), sealed runs are sorted, Bloom-filtered, min/max-indexed;
  * when the buffer holds R runs, ceil(m*R) oldest runs merge to disk
    level 0; levels hold D runs each and cascade (Do-Merge, Algorithm 2);
  * newest-wins on duplicate keys, keyed on a global seqno (the paper keys
    recency on run index; seqnos are the batched generalization and give
    identical semantics — proven by the dict-oracle property tests);
  * deletes are tombstones, committed (elided) when a merge creates the
    deepest data (paper 2.5/2.8);
  * every run carries min/max keys + a Bloom filter; disk runs add fence
    pointers every mu slots (paper 2.3/2.4).

All state lives in a pytree of statically-shaped arrays; all hot paths are
jit-compiled. The host orchestrates *when* merges happen (the paper's merge
thread); devices execute *what* they do.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom as BL
from repro.core import runs as RU
from repro.core.params import KEY_EMPTY, SEQ_NONE, TOMBSTONE, SLSMParams

I32 = jnp.int32


class LevelState(NamedTuple):
    """One disk tier: D immutable sorted runs (paper 2.4)."""
    keys: jax.Array    # (D, cap_l) sorted ascending, KEY_EMPTY padded
    vals: jax.Array    # (D, cap_l)
    seqs: jax.Array    # (D, cap_l)
    counts: jax.Array  # (D,)
    mins: jax.Array    # (D,)
    maxs: jax.Array    # (D,)
    blooms: jax.Array  # (D, words_l) uint32
    fences: jax.Array  # (D, n_fences_l)
    n_runs: jax.Array  # () number of occupied run slots (oldest = slot 0)


class SLSMState(NamedTuple):
    # staging buffer == the active run (kept key-sorted, newest-wins deduped)
    stage_keys: jax.Array   # (2*Rn,)
    stage_vals: jax.Array
    stage_seqs: jax.Array
    stage_count: jax.Array  # ()
    # sealed memory runs
    buf_keys: jax.Array     # (R, Rn)
    buf_vals: jax.Array
    buf_seqs: jax.Array
    buf_counts: jax.Array   # (R,)
    buf_mins: jax.Array     # (R,)
    buf_maxs: jax.Array     # (R,)
    buf_blooms: jax.Array   # (R, words_buf) uint32
    run_count: jax.Array    # ()
    next_seq: jax.Array     # () global write counter == recency order
    levels: Tuple[LevelState, ...]


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------

def init_state(p: SLSMParams) -> SLSMState:
    _, wb, _ = p.bloom_geometry(p.Rn)
    return SLSMState(
        stage_keys=jnp.full((p.stage_cap,), KEY_EMPTY, I32),
        stage_vals=jnp.zeros((p.stage_cap,), I32),
        stage_seqs=jnp.zeros((p.stage_cap,), I32),
        stage_count=jnp.zeros((), I32),
        buf_keys=jnp.full((p.R, p.Rn), KEY_EMPTY, I32),
        buf_vals=jnp.zeros((p.R, p.Rn), I32),
        buf_seqs=jnp.zeros((p.R, p.Rn), I32),
        buf_counts=jnp.zeros((p.R,), I32),
        buf_mins=jnp.full((p.R,), KEY_EMPTY, I32),
        buf_maxs=jnp.full((p.R,), TOMBSTONE, I32),
        buf_blooms=jnp.zeros((p.R, wb), jnp.uint32),
        run_count=jnp.zeros((), I32),
        next_seq=jnp.zeros((), I32),
        levels=(),
    )


def empty_level(p: SLSMParams, level: int) -> LevelState:
    cap = p.level_cap(level)
    _, w, _ = p.bloom_geometry(cap)
    return LevelState(
        keys=jnp.full((p.D, cap), KEY_EMPTY, I32),
        vals=jnp.zeros((p.D, cap), I32),
        seqs=jnp.zeros((p.D, cap), I32),
        counts=jnp.zeros((p.D,), I32),
        mins=jnp.full((p.D,), KEY_EMPTY, I32),
        maxs=jnp.full((p.D,), TOMBSTONE, I32),
        blooms=jnp.zeros((p.D, w), jnp.uint32),
        fences=jnp.full((p.D, p.n_fences(level)), KEY_EMPTY, I32),
        n_runs=jnp.zeros((), I32),
    )


# --------------------------------------------------------------------------
# insertion path (paper Algorithm 2, batched)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
def stage_append(p: SLSMParams, state: SLSMState, keys: jax.Array,
                 vals: jax.Array, n_valid: jax.Array) -> SLSMState:
    """Append an Rn-sized chunk into the active run, then re-sort + dedup.

    The active skiplist's O(log Rn) ordered insert becomes a batched
    sort of the 2*Rn staging region; the paper's in-place update of
    duplicate keys (3.9.1) is the newest-wins dedup.
    """
    rn = p.Rn
    pos = jnp.arange(rn, dtype=I32)
    valid = pos < n_valid
    ck = jnp.where(valid, keys.astype(I32), KEY_EMPTY)
    cs = state.next_seq + pos
    sk = jax.lax.dynamic_update_slice(state.stage_keys, ck, (state.stage_count,))
    sv = jax.lax.dynamic_update_slice(state.stage_vals, vals.astype(I32),
                                      (state.stage_count,))
    ss = jax.lax.dynamic_update_slice(state.stage_seqs, cs, (state.stage_count,))
    k, v, s = RU.sort_by_key_seq(sk, sv, ss)
    ok = RU.newest_wins_mask(k, v, drop_tombstones=False)
    k, v, s, cnt = RU.compact(k, v, s, ok)
    return state._replace(stage_keys=k, stage_vals=v, stage_seqs=s,
                          stage_count=cnt, next_seq=state.next_seq + n_valid)


@functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
def seal_run(p: SLSMParams, state: SLSMState) -> SLSMState:
    """Seal Rn staged elements into memory run slot `run_count`.

    Builds the run's Bloom filter and min/max index (paper 2.3) — the
    moment the active skiplist becomes an immutable sorted run.
    """
    rn = p.Rn
    _, wb, kk = p.bloom_geometry(rn)
    rk, rv, rs = (state.stage_keys[:rn], state.stage_vals[:rn],
                  state.stage_seqs[:rn])
    slot = state.run_count
    filt = BL.bloom_build(rk, jnp.ones((rn,), bool), wb, kk)
    empty_tail = jnp.full((rn,), KEY_EMPTY, I32)
    return state._replace(
        stage_keys=jnp.concatenate([state.stage_keys[rn:], empty_tail]),
        stage_vals=jnp.concatenate([state.stage_vals[rn:], jnp.zeros_like(empty_tail)]),
        stage_seqs=jnp.concatenate([state.stage_seqs[rn:], jnp.zeros_like(empty_tail)]),
        stage_count=state.stage_count - rn,
        buf_keys=state.buf_keys.at[slot].set(rk),
        buf_vals=state.buf_vals.at[slot].set(rv),
        buf_seqs=state.buf_seqs.at[slot].set(rs),
        buf_counts=state.buf_counts.at[slot].set(rn),
        buf_mins=state.buf_mins.at[slot].set(rk[0]),
        buf_maxs=state.buf_maxs.at[slot].set(rk[rn - 1]),
        buf_blooms=state.buf_blooms.at[slot].set(filt),
        run_count=state.run_count + 1,
    )


def _index_new_run(p: SLSMParams, level: int, k, v, s, cnt):
    """Pad a merged run to level capacity; build bloom/fences/minmax."""
    cap = p.level_cap(level)
    _, w, kk = p.bloom_geometry(cap)
    pad = cap - k.shape[0]
    if pad > 0:
        k = jnp.concatenate([k, jnp.full((pad,), KEY_EMPTY, I32)])
        v = jnp.concatenate([v, jnp.zeros((pad,), I32)])
        s = jnp.concatenate([s, jnp.zeros((pad,), I32)])
    elif pad < 0:  # deepest-level compaction scratch is larger than cap
        k, v, s = k[:cap], v[:cap], s[:cap]
    filt = BL.bloom_build(k, k != KEY_EMPTY, w, kk)
    fences = RU.build_fences(k, p.mu, p.n_fences(level))
    mn, mx = RU.run_minmax(k, cnt)
    return k, v, s, filt, fences, mn, mx


def _set_level_run(lv: LevelState, slot, k, v, s, cnt, filt, fences, mn, mx,
                   bump: int = 1) -> LevelState:
    return lv._replace(
        keys=lv.keys.at[slot].set(k), vals=lv.vals.at[slot].set(v),
        seqs=lv.seqs.at[slot].set(s), counts=lv.counts.at[slot].set(cnt),
        mins=lv.mins.at[slot].set(mn), maxs=lv.maxs.at[slot].set(mx),
        blooms=lv.blooms.at[slot].set(filt),
        fences=lv.fences.at[slot].set(fences),
        n_runs=lv.n_runs + bump,
    )


def _shift_level(p: SLSMParams, lv: LevelState, n: int) -> LevelState:
    """Drop the n oldest runs (slots [0, n)), shifting the rest down."""
    def roll(a, fill):
        tail_shape = (n,) + a.shape[1:]
        return jnp.concatenate([a[n:], jnp.full(tail_shape, fill, a.dtype)])
    return LevelState(
        keys=roll(lv.keys, KEY_EMPTY), vals=roll(lv.vals, 0),
        seqs=roll(lv.seqs, 0), counts=roll(lv.counts, 0),
        mins=roll(lv.mins, KEY_EMPTY), maxs=roll(lv.maxs, TOMBSTONE),
        blooms=roll(lv.blooms, 0), fences=roll(lv.fences, KEY_EMPTY),
        n_runs=lv.n_runs - n,
    )


@functools.partial(jax.jit, static_argnums=(0, 2), donate_argnums=1)
def merge_buffer_to_level0(p: SLSMParams, state: SLSMState,
                           drop_tombstones: bool) -> SLSMState:
    """Flush ceil(m*R) oldest memory runs into disk level 0 (paper 2.1/2.5)."""
    mr = p.runs_merged
    k, v, s, cnt = RU.merge_runs(state.buf_keys[:mr], state.buf_vals[:mr],
                                 state.buf_seqs[:mr], drop_tombstones)
    k, v, s, filt, fences, mn, mx = _index_new_run(p, 0, k, v, s, cnt)
    lv0 = _set_level_run(state.levels[0], state.levels[0].n_runs,
                         k, v, s, cnt, filt, fences, mn, mx)

    def roll(a, fill):
        tail_shape = (mr,) + a.shape[1:]
        return jnp.concatenate([a[mr:], jnp.full(tail_shape, fill, a.dtype)])

    return state._replace(
        buf_keys=roll(state.buf_keys, KEY_EMPTY),
        buf_vals=roll(state.buf_vals, 0),
        buf_seqs=roll(state.buf_seqs, 0),
        buf_counts=roll(state.buf_counts, 0),
        buf_mins=roll(state.buf_mins, KEY_EMPTY),
        buf_maxs=roll(state.buf_maxs, TOMBSTONE),
        buf_blooms=roll(state.buf_blooms, 0),
        run_count=state.run_count - mr,
        levels=(lv0,) + state.levels[1:],
    )


@functools.partial(jax.jit, static_argnums=(0, 2, 3), donate_argnums=1)
def merge_level_down(p: SLSMParams, state: SLSMState, level: int,
                     drop_tombstones: bool) -> SLSMState:
    """Merge ceil(m*D) oldest runs of `level` into one run of `level+1`."""
    md = p.disk_runs_merged
    src = state.levels[level]
    k, v, s, cnt = RU.merge_runs(src.keys[:md], src.vals[:md], src.seqs[:md],
                                 drop_tombstones)
    k, v, s, filt, fences, mn, mx = _index_new_run(p, level + 1, k, v, s, cnt)
    dst = state.levels[level + 1]
    dst = _set_level_run(dst, dst.n_runs, k, v, s, cnt, filt, fences, mn, mx)
    src = _shift_level(p, src, md)
    levels = (state.levels[:level] + (src, dst)
              + state.levels[level + 2:])
    return state._replace(levels=levels)


@functools.partial(jax.jit, static_argnums=0)
def compact_last_level(p: SLSMParams, state: SLSMState):
    """In-place compaction of the deepest level: merge all D runs into slot 0.

    This is always the deepest data, so tombstones are committed here
    (paper 2.5: 'keys flagged for delete are not written ... at all').
    Returns (state, raw_count); the host raises if raw_count exceeds the
    deepest run capacity (the TPU analogue of running out of disk)."""
    last = p.max_levels - 1
    lv = state.levels[last]
    k, v, s, cnt = RU.merge_runs(lv.keys, lv.vals, lv.seqs,
                                 drop_tombstones=True)
    k, v, s, filt, fences, mn, mx = _index_new_run(p, last, k, v, s, cnt)
    fresh = empty_level(p, last)
    fresh = _set_level_run(fresh, 0, k, v, s,
                           jnp.minimum(cnt, p.level_cap(last)),
                           filt, fences, mn, mx)
    return state._replace(levels=state.levels[:last] + (fresh,)), cnt


# --------------------------------------------------------------------------
# lookup path (paper 2.7): newest -> oldest, min/max + Bloom gated
# --------------------------------------------------------------------------

def _consider(best_seq, best_val, seq_c, val_c):
    take = seq_c > best_seq
    return (jnp.where(take, seq_c, best_seq),
            jnp.where(take, val_c, best_val))


def _search_stage(state: SLSMState, qs: jax.Array):
    eq = state.stage_keys[None, :] == qs[:, None]            # (Q, 2Rn)
    seqm = jnp.where(eq, state.stage_seqs[None, :], SEQ_NONE)
    j = jnp.argmax(seqm, axis=1)
    seq_c = jnp.take_along_axis(seqm, j[:, None], axis=1)[:, 0]
    val_c = state.stage_vals[j]
    return seq_c, jnp.where(seq_c >= 0, val_c, 0)


def _search_sorted_run(keys, vals, seqs, count, qs):
    """Binary search one sorted run for a batch of queries."""
    i = jnp.searchsorted(keys, qs).astype(I32)
    ic = jnp.minimum(i, keys.shape[0] - 1)
    hit = (i < count) & (keys[ic] == qs)
    return (jnp.where(hit, seqs[ic], SEQ_NONE), jnp.where(hit, vals[ic], 0))


def _search_memory_runs(state: SLSMState, qs: jax.Array):
    seqs_r, vals_r = jax.vmap(
        lambda k, v, s, c: _search_sorted_run(k, v, s, c, qs)
    )(state.buf_keys, state.buf_vals, state.buf_seqs, state.buf_counts)
    j = jnp.argmax(seqs_r, axis=0)                            # (Q,)
    q_iota = jnp.arange(qs.shape[0])
    return seqs_r[j, q_iota], vals_r[j, q_iota]


def _fence_window_search(keys, vals, seqs, count, fences, mu, qs, active):
    """Fence-pointer lookup on one disk run (paper 2.4): binary-search the
    fences, then search the mu-wide page they bound."""
    f = jnp.searchsorted(fences, qs, side="right").astype(I32) - 1
    start = jnp.clip(f, 0, fences.shape[0] - 1) * mu

    def one(st, q):
        win = jax.lax.dynamic_slice(keys, (st,), (mu,))
        off = jnp.searchsorted(win, q).astype(I32)
        offc = jnp.minimum(off, mu - 1)
        hit = (off < mu) & (win[offc] == q)
        idx = st + offc
        return jnp.where(hit & (idx < count), idx, -1)

    idx = jax.vmap(one)(start, qs)
    hit = (idx >= 0) & active
    idxc = jnp.maximum(idx, 0)
    return (jnp.where(hit, seqs[idxc], SEQ_NONE), jnp.where(hit, vals[idxc], 0))


def _level_gate(lv: LevelState, qs: jax.Array, kk: int):
    """(D, Q) candidate mask: min/max window AND Bloom positive (paper 2.3)."""
    inwin = (qs[None, :] >= lv.mins[:, None]) & (qs[None, :] <= lv.maxs[:, None])
    pos = jax.vmap(lambda w: BL.bloom_probe(w, qs, kk))(lv.blooms)  # (D, Q)
    return inwin & pos


def _search_level_dense(p: SLSMParams, lv: LevelState, level: int,
                        qs: jax.Array):
    _, _, kk = p.bloom_geometry(p.level_cap(level))
    gate = _level_gate(lv, qs, kk)
    seqs_d, vals_d = jax.vmap(
        lambda k, v, s, c, fen, g: _fence_window_search(
            k, v, s, c, fen, p.mu, qs, g)
    )(lv.keys, lv.vals, lv.seqs, lv.counts, lv.fences, gate)
    j = jnp.argmax(seqs_d, axis=0)
    q_iota = jnp.arange(qs.shape[0])
    return seqs_d[j, q_iota], vals_d[j, q_iota]


def _search_level_sparse(p: SLSMParams, lv: LevelState, level: int,
                         qs: jax.Array):
    """Bloom-compacted disk search: only gated (run, query) pairs do the
    fence+page work — the TPU realization of 'skip the run on a Bloom miss'.

    Static capacity: cand_factor candidates per query on average. An
    overflowing gate (pathologically hot key ranges + tiny cand_factor)
    drops candidates, which can miss a hit — size cand_factor >= eps*D*L
    plus true-hit headroom, or use the dense path (lookup_batch sparse=False)
    when exactness is mandatory. Property tests cross-check both paths."""
    q_n = qs.shape[0]
    _, _, kk = p.bloom_geometry(p.level_cap(level))
    gate = _level_gate(lv, qs, kk)                            # (D, Q)
    cap = q_n * p.cand_factor
    d_idx, q_idx = jnp.nonzero(gate, size=cap, fill_value=-1)
    ok = d_idx >= 0
    d_c, q_c = jnp.maximum(d_idx, 0), jnp.maximum(q_idx, 0)
    qk = qs[q_c]

    def one(d, q):
        f = jnp.searchsorted(lv.fences[d], q, side="right").astype(I32) - 1
        st = jnp.clip(f, 0, lv.fences.shape[1] - 1) * p.mu
        win = jax.lax.dynamic_slice(lv.keys, (d, st), (1, p.mu))[0]
        off = jnp.searchsorted(win, q).astype(I32)
        offc = jnp.minimum(off, p.mu - 1)
        hit = (off < p.mu) & (win[offc] == q) & (st + offc < lv.counts[d])
        idx = st + offc
        return (jnp.where(hit, lv.seqs[d, idx], SEQ_NONE),
                jnp.where(hit, lv.vals[d, idx], 0))

    seq_c, val_c = jax.vmap(one)(d_c, qk)
    seq_c = jnp.where(ok, seq_c, SEQ_NONE)
    best_seq = jnp.full((q_n,), SEQ_NONE, I32).at[q_c].max(
        jnp.where(ok, seq_c, SEQ_NONE), mode="drop")
    win_mask = ok & (seq_c == best_seq[q_c]) & (seq_c >= 0)
    best_val = jnp.full((q_n,), np.iinfo(np.int32).min, I32).at[q_c].max(
        jnp.where(win_mask, val_c, np.iinfo(np.int32).min), mode="drop")
    best_val = jnp.where(best_seq >= 0, best_val, 0)
    return best_seq, best_val


@functools.partial(jax.jit, static_argnums=(0, 3))
def lookup_batch(p: SLSMParams, state: SLSMState, qs: jax.Array,
                 sparse: bool = False):
    """Point lookups, newest-to-oldest across every structure (paper 2.7).

    Returns (vals, found). Tombstoned keys report found=False (paper 2.8).
    """
    qs = qs.astype(I32)
    best_seq, best_val = _search_stage(state, qs)
    s2, v2 = _search_memory_runs(state, qs)
    best_seq, best_val = _consider(best_seq, best_val, s2, v2)
    for level, lv in enumerate(state.levels):
        fn = _search_level_sparse if sparse else _search_level_dense
        s3, v3 = fn(p, lv, level, qs)
        best_seq, best_val = _consider(best_seq, best_val, s3, v3)
    found = (best_seq >= 0) & (best_val != TOMBSTONE)
    return jnp.where(found, best_val, 0), found


# --------------------------------------------------------------------------
# range queries (paper 2.9)
# --------------------------------------------------------------------------

def _range_from_sorted(keys, vals, seqs, count, lo, hi, max_range):
    s = jnp.searchsorted(keys, lo, side="left").astype(I32)
    e = jnp.searchsorted(keys, hi, side="left").astype(I32)
    idx = s + jnp.arange(max_range, dtype=I32)
    ok = (idx < e) & (idx < count)
    idxc = jnp.minimum(idx, keys.shape[0] - 1)
    return (jnp.where(ok, keys[idxc], KEY_EMPTY),
            jnp.where(ok, vals[idxc], 0),
            jnp.where(ok, seqs[idxc], 0))


@functools.partial(jax.jit, static_argnums=0)
def range_query(p: SLSMParams, state: SLSMState, lo: jax.Array, hi: jax.Array):
    """All live (key, value) with lo <= key < hi, newest-wins, tombstones
    dropped. Sort-based dedup replaces the paper's hash table (DESIGN.md §2).

    Returns (keys, vals, count) with up to max_range results, key-sorted.
    """
    mr = p.max_range
    parts = [_range_from_sorted(state.stage_keys, state.stage_vals,
                                state.stage_seqs, state.stage_count,
                                lo, hi, mr)]
    part = jax.vmap(lambda k, v, s, c: _range_from_sorted(k, v, s, c, lo, hi, mr))(
        state.buf_keys, state.buf_vals, state.buf_seqs, state.buf_counts)
    parts.append(tuple(x.reshape(-1) for x in part))
    for lv in state.levels:
        part = jax.vmap(
            lambda k, v, s, c: _range_from_sorted(k, v, s, c, lo, hi, mr)
        )(lv.keys, lv.vals, lv.seqs, lv.counts)
        parts.append(tuple(x.reshape(-1) for x in part))
    k = jnp.concatenate([x[0] for x in parts])
    v = jnp.concatenate([x[1] for x in parts])
    s = jnp.concatenate([x[2] for x in parts])
    k, v, s = RU.sort_by_key_seq(k, v, s)
    ok = RU.newest_wins_mask(k, v, drop_tombstones=True)
    k, v, s, cnt = RU.compact(k, v, s, ok)
    return k[:mr], v[:mr], jnp.minimum(cnt, mr)


# --------------------------------------------------------------------------
# host orchestrator — the paper's insert/merge control flow (Algorithm 2)
# --------------------------------------------------------------------------

class SLSM:
    """Host-side driver: owns the state pytree, schedules seals and merges.

    `insert`/`delete`/`lookup`/`range` match the paper's API. The merge
    cascade (Do-Merge) runs here: recursion depth and level occupancy are
    host decisions; every data-touching op is a jitted device computation.
    """

    def __init__(self, params: SLSMParams | None = None):
        self.p = params or SLSMParams()
        self.state = init_state(self.p)

    # -- write path -------------------------------------------------------
    def insert(self, keys, vals) -> None:
        keys = np.asarray(keys, np.int32).reshape(-1)
        vals = np.asarray(vals, np.int32).reshape(-1)
        assert keys.shape == vals.shape
        rn = self.p.Rn
        for off in range(0, len(keys), rn):
            ck, cv = keys[off:off + rn], vals[off:off + rn]
            n = len(ck)
            if n < rn:
                ck = np.pad(ck, (0, rn - n), constant_values=KEY_EMPTY)
                cv = np.pad(cv, (0, rn - n))
            self.state = stage_append(self.p, self.state, jnp.asarray(ck),
                                      jnp.asarray(cv), jnp.int32(n))
            while int(self.state.stage_count) >= rn:
                if int(self.state.run_count) == self.p.R:
                    self._flush_buffer()
                self.state = seal_run(self.p, self.state)

    def delete(self, keys) -> None:
        keys = np.asarray(keys, np.int32).reshape(-1)
        self.insert(keys, np.full_like(keys, TOMBSTONE))

    # -- merge cascade (Do-Merge) ------------------------------------------
    def _flush_buffer(self) -> None:
        self._ensure_space(0)
        self.state = merge_buffer_to_level0(self.p, self.state,
                                            self._drop_tombstones_into(0))

    def _ensure_space(self, level: int) -> None:
        if level >= self.p.max_levels:
            raise RuntimeError(
                "sLSM capacity exceeded: increase max_levels "
                f"(currently {self.p.max_levels})")
        if level >= len(self.state.levels):
            self.state = self.state._replace(
                levels=self.state.levels + (empty_level(self.p, level),))
            return
        if int(self.state.levels[level].n_runs) == self.p.D:
            if level == self.p.max_levels - 1:
                new_state, raw = compact_last_level(self.p, self.state)
                cap = self.p.level_cap(level)
                if int(raw) > cap:
                    raise RuntimeError(
                        f"sLSM deepest level overflow ({int(raw)} > {cap} "
                        f"live elements): increase max_levels beyond "
                        f"{self.p.max_levels}")
                self.state = new_state
            else:
                self._ensure_space(level + 1)
                self.state = merge_level_down(
                    self.p, self.state, level,
                    self._drop_tombstones_into(level + 1))

    def _drop_tombstones_into(self, target_level: int) -> bool:
        """Deletes commit when the merge output becomes the deepest data."""
        for lv in self.state.levels[target_level:]:
            if int(lv.n_runs) > 0:
                return False
        return True

    # -- read path ----------------------------------------------------------
    def lookup(self, keys, sparse: bool = False):
        qs = jnp.asarray(np.asarray(keys, np.int32).reshape(-1))
        vals, found = lookup_batch(self.p, self.state, qs, sparse)
        return np.asarray(vals), np.asarray(found)

    def range(self, lo: int, hi: int):
        k, v, c = range_query(self.p, self.state, jnp.int32(lo), jnp.int32(hi))
        c = int(c)
        return np.asarray(k)[:c], np.asarray(v)[:c]

    # -- stats ----------------------------------------------------------------
    @property
    def n_live(self) -> int:
        n = int(self.state.stage_count) + int(self.state.buf_counts.sum())
        for lv in self.state.levels:
            n += int(lv.counts.sum())
        return n

    @property
    def n_levels(self) -> int:
        return len(self.state.levels)
