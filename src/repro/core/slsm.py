"""The Skiplist-Based LSM Tree — back-compat facade over `repro.engine`.

The engine now lives in the layered `repro.engine` package (memtable /
levels / compaction / read_path / engine / sharded, with an ops-dispatch
backend layer selecting jnp reference code or the Pallas kernels) — see
DESIGN.md for the module map and the paper-to-TPU adaptation notes.

Paper structure (Szanto 2018) preserved exactly:
  * memory buffer of R runs x Rn elements, one active run (here: a sorted
    staging buffer — the dense-array form of the active skiplist, see
    DESIGN.md §2), sealed runs are sorted, Bloom-filtered, min/max-indexed;
  * when the buffer holds R runs, ceil(m*R) oldest runs merge to disk
    level 0; levels hold D runs each and cascade (Do-Merge, Algorithm 2);
  * newest-wins on duplicate keys, keyed on a global seqno (the paper keys
    recency on run index; seqnos are the batched generalization and give
    identical semantics — proven by the dict-oracle property tests);
  * deletes are weight -1 records (the paper's tombstones recast as
    Z-set retractions, DESIGN.md §13), committed (annihilated) when a
    merge creates the deepest data (paper 2.5/2.8);
  * every run carries min/max keys + a Bloom filter; disk runs add fence
    pointers every mu slots (paper 2.3/2.4).

All state lives in a pytree of statically-shaped arrays; all hot paths are
jit-compiled. The host orchestrates *when* merges happen (the paper's merge
thread); devices execute *what* they do.
"""
from repro.engine.backend import OpsBackend, get_backend  # noqa: F401
from repro.engine.compaction import (CompactionPolicy,  # noqa: F401
                                     LevelingPolicy, TieringPolicy,
                                     compact_last_level,
                                     merge_buffer_to_level0,
                                     merge_level_down)
from repro.engine.engine import SLSM  # noqa: F401
from repro.engine.levels import LevelState, empty_level  # noqa: F401
from repro.engine.memtable import (SLSMState, init_state,  # noqa: F401
                                   seal_run, stage_append)
from repro.engine.read_path import (lookup_batch, lookup_many,  # noqa: F401
                                    range_query)
from repro.engine.sharded import ShardedSLSM  # noqa: F401
