"""Sorted-run primitives: sort, newest-wins dedup, k-way merge, fences.

TPU adaptation of the paper's run machinery:
  * a run is a dense sorted (keys, vals, seqs) triple padded with KEY_EMPTY;
  * HeapMerge (paper 2.5, O(n log k) serial heap) becomes either
      - a multi-operand stable `lax.sort` on (key, seq) — XLA's bitonic
        network, O(n log^2 n) comparisons but fully parallel; or
      - `merge_kway_ranked` — the rank-merge: every element's output slot is
        its own index plus its rank in every other run, computed with
        vectorized binary searches. O(n log k) *work*, data-independent
        control flow. Same asymptotics as the paper's heap, no heap.
  * newest-wins dedup: after a (key, seq)-ordered sort, the last element of
    every equal-key block carries the max seqno — a shift-compare mask.
  * tombstone elision happens only when merging into the deepest level
    (paper 2.5/2.8: deletes are "committed" there).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import KEY_EMPTY, TOMBSTONE


def sort_by_key_seq(keys, vals, seqs):
    """Stable lexicographic sort by (key, seq). Sentinels sort to the end."""
    keys, seqs, vals = jax.lax.sort((keys, seqs, vals), num_keys=2)
    return keys, vals, seqs


def newest_wins_mask(keys: jax.Array, vals: jax.Array,
                     drop_tombstones: bool) -> jax.Array:
    """Valid-mask over a (key, seq)-sorted run: keep the last (newest) copy
    of each key; drop padding; optionally commit deletes."""
    nxt = jnp.concatenate([keys[1:], jnp.full((1,), KEY_EMPTY, keys.dtype)])
    valid = (keys != KEY_EMPTY) & (keys != nxt)
    if drop_tombstones:
        valid &= vals != TOMBSTONE
    return valid


def compact(keys, vals, seqs, valid):
    """Stable-partition valid elements to the front; pad the rest.

    Returns (keys, vals, seqs, count). Order among valid elements is
    preserved (stable argsort on the invalid flag).
    """
    order = jnp.argsort((~valid).astype(jnp.int32), stable=True)
    keys = jnp.where(valid[order], keys[order], KEY_EMPTY)
    vals = jnp.where(valid[order], vals[order], 0)
    seqs = jnp.where(valid[order], seqs[order], 0)
    return keys, vals, seqs, valid.sum(dtype=jnp.int32)


def merge_runs(keys2d, vals2d, seqs2d, drop_tombstones: bool):
    """Merge k sorted runs (k, cap) -> one compacted run (k*cap,).

    Sort-based path (XLA bitonic network). Newest-wins is free because the
    sort is keyed on (key, seq) and dedup keeps the last copy — exactly the
    paper's "highest-ranked run's value is written" rule, with run recency
    generalized to global seqnos.
    """
    k, v, s = keys2d.reshape(-1), vals2d.reshape(-1), seqs2d.reshape(-1)
    k, v, s = sort_by_key_seq(k, v, s)
    valid = newest_wins_mask(k, v, drop_tombstones)
    return compact(k, v, s, valid)


def merge_two_ranked(ak, av, as_, bk, bv, bs):
    """Rank-merge of two sorted runs — the TPU HeapMerge step.

    out_pos(a[i]) = i + #{b[j] < a[i] by (key, seq)};  symmetrical for b.
    Both ranks come from two vectorized binary searches; the scatter is a
    permutation, so the result is sorted by (key, seq) and stable.
    Padding (KEY_EMPTY) naturally ranks to the tail.
    """
    n, mth = ak.shape[0], bk.shape[0]

    # rank = lexicographic lower_bound over (key, seq): runs are sorted by
    # (key, seq) — including intermediate tournament rounds, which may hold
    # duplicate keys — so a branch-free binary search with the pairwise
    # comparator is exact. O(n log m) work, fully lane-parallel.
    def rank_in(other_k, other_s, qk, qs):
        size = other_k.shape[0]
        steps = max(1, math.ceil(math.log2(size + 1)))
        lo = jnp.zeros(qk.shape, jnp.int32)
        hi = jnp.full(qk.shape, size, jnp.int32)

        def body(_, lohi):
            lo, hi = lohi
            mid = (lo + hi) // 2
            midc = jnp.clip(mid, 0, size - 1)
            ok_, os_mid = other_k[midc], other_s[midc]
            before = (ok_ < qk) | ((ok_ == qk) & (os_mid < qs))
            active = lo < hi
            new_lo = jnp.where(before, mid + 1, lo)
            new_hi = jnp.where(before, hi, mid)
            return (jnp.where(active, new_lo, lo),
                    jnp.where(active, new_hi, hi))

        lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
        return lo

    pa = jnp.arange(n, dtype=jnp.int32) + rank_in(bk, bs, ak, as_)
    pb = jnp.arange(mth, dtype=jnp.int32) + rank_in(ak, as_, bk, bs)
    total = n + mth
    ok = jnp.full((total,), KEY_EMPTY, ak.dtype).at[pa].set(ak).at[pb].set(bk)
    ov = jnp.zeros((total,), av.dtype).at[pa].set(av).at[pb].set(bv)
    os_ = jnp.zeros((total,), as_.dtype).at[pa].set(as_).at[pb].set(bs)
    return ok, ov, os_


def merge_kway_ranked(keys2d, vals2d, seqs2d, drop_tombstones: bool):
    """Tournament of rank-merges: log2(k) parallel passes (paper-equivalent
    O(n log k) work). Used by benchmarks to compare against `merge_runs`."""
    runs = [(keys2d[i], vals2d[i], seqs2d[i]) for i in range(keys2d.shape[0])]
    while len(runs) > 1:
        nxt = []
        for i in range(0, len(runs) - 1, 2):
            nxt.append(merge_two_ranked(*runs[i], *runs[i + 1]))
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    k, v, s = runs[0]
    valid = newest_wins_mask(k, v, drop_tombstones)
    return compact(k, v, s, valid)


def build_fences(keys: jax.Array, mu: int, n_fences: int) -> jax.Array:
    """Fence pointers (paper 2.4): the key at every mu-th slot."""
    idx = jnp.arange(n_fences, dtype=jnp.int32) * mu
    return keys[jnp.clip(idx, 0, keys.shape[0] - 1)]


def run_minmax(keys: jax.Array, count: jax.Array):
    """(min, max) key of a compacted sorted run (paper 2.3 max/min filter)."""
    mn = jnp.where(count > 0, keys[0], KEY_EMPTY)
    mx = jnp.where(count > 0, keys[jnp.maximum(count - 1, 0)], TOMBSTONE)
    return mn, mx
