"""Sorted-run primitives: sort, weighted survivor dedup, k-way merge, fences.

TPU adaptation of the paper's run machinery, on the Z-set record algebra
(DESIGN.md §13): a record is ``(key, weight, seq | payload)`` with weight
+1 for an insert and -1 for a delete — structure-of-arrays, the payload
lane separate from the merge lanes.

  * a run is a dense sorted (keys, vals, wts, seqs) quad padded with
    KEY_EMPTY;
  * HeapMerge (paper 2.5, O(n log k) serial heap) becomes either
      - a multi-operand stable `lax.sort` on (key, seq) — XLA's bitonic
        network, O(n log^2 n) comparisons but fully parallel; or
      - `merge_kway_ranked` — the rank-merge: every element's output slot is
        its own index plus its rank in every other run, computed with
        vectorized binary searches. O(n log k) *work*, data-independent
        control flow. Same asymptotics as the paper's heap, no heap.
  * weighted dedup: after a (key, seq)-ordered sort, the last element of
    every equal-key block carries the max seqno. Each op implicitly
    retracts its predecessor (an update is the Z-set -1/+1 pair fused
    into one record), so the per-key weight sum telescopes to the newest
    record's weight — presence is its sign, and the survivor mask is a
    shift-compare plus a sign test.
  * annihilation (zero-weight elision) happens only when merging into the
    deepest level (paper 2.5/2.8: deletes are "committed" there) —
    shallower merges keep the newest record per key even when its weight
    is negative, because it must still retract older copies below.
  * the Ghost property: merges move only the (key, weight, seq) lanes
    plus a provenance index through the sort/merge network; the payload
    lane is gathered once, at the end, for surviving rows only.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import KEY_EMPTY

# neutral "max key of an empty run" (min/max filters need a -inf)
_KEY_MIN = np.int32(np.iinfo(np.int32).min)


def sort_records(keys, vals, wts, seqs):
    """Stable lexicographic sort by (key, seq); vals/wts ride as payload.
    Sentinels sort to the end. Returns (keys, vals, wts, seqs)."""
    keys, seqs, vals, wts = jax.lax.sort((keys, seqs, vals, wts), num_keys=2)
    return keys, vals, wts, seqs


def survivor_mask(keys: jax.Array, wts: jax.Array,
                  drop_annihilated: bool) -> jax.Array:
    """Valid-mask over a (key, seq)-sorted run: keep the newest record of
    each key (the telescoped per-key weight sum); drop padding; when
    `drop_annihilated`, elide keys whose summed weight is <= 0 (deletes
    commit — the deepest-level merge)."""
    nxt = jnp.concatenate([keys[1:], jnp.full((1,), KEY_EMPTY, keys.dtype)])
    valid = (keys != KEY_EMPTY) & (keys != nxt)
    if drop_annihilated:
        valid &= wts > 0
    return valid


def compact(keys, vals, wts, seqs, valid):
    """Stable-partition valid elements to the front; pad the rest.

    Returns (keys, vals, wts, seqs, count). Order among valid elements is
    preserved (stable argsort on the invalid flag).
    """
    order = jnp.argsort((~valid).astype(jnp.int32), stable=True)
    ok = valid[order]
    keys = jnp.where(ok, keys[order], KEY_EMPTY)
    vals = jnp.where(ok, vals[order], 0)
    wts = jnp.where(ok, wts[order], 0)
    seqs = jnp.where(ok, seqs[order], 0)
    return keys, vals, wts, seqs, valid.sum(dtype=jnp.int32)


def merge_runs(keys2d, vals2d, wts2d, seqs2d, drop_annihilated: bool):
    """Merge k sorted runs (k, cap) -> one compacted run (k*cap,).

    Sort-based path (XLA bitonic network) over the (key, weight, seq,
    source-index) lanes only — the payload lane never enters the sort.
    The per-key weight sum telescopes to the newest record (the sort is
    keyed on (key, seq) and dedup keeps the last copy — the paper's
    "highest-ranked run's value is written" rule, with run recency
    generalized to global seqnos); payloads are gathered through the
    surviving rows' source indices in one final pass (the Ghost
    property). Returns (keys, vals, wts, seqs, count).
    """
    k, w, s = keys2d.reshape(-1), wts2d.reshape(-1), seqs2d.reshape(-1)
    idx = jnp.arange(k.shape[0], dtype=jnp.int32)
    k, s, w, idx = jax.lax.sort((k, s, w, idx), num_keys=2)
    valid = survivor_mask(k, w, drop_annihilated)
    order = jnp.argsort((~valid).astype(jnp.int32), stable=True)
    ok = valid[order]
    keys = jnp.where(ok, k[order], KEY_EMPTY)
    wts = jnp.where(ok, w[order], 0)
    seqs = jnp.where(ok, s[order], 0)
    # payload gather — survivors only (annihilated rows never touch vals)
    vals = jnp.where(ok, vals2d.reshape(-1)[idx[order]], 0)
    return keys, vals, wts, seqs, valid.sum(dtype=jnp.int32)


def merge_two_ranked(ak, av, aw, as_, bk, bv, bw, bs):
    """Rank-merge of two sorted runs — the TPU HeapMerge step.

    out_pos(a[i]) = i + #{b[j] < a[i] by (key, seq)};  symmetrical for b.
    Both ranks come from two vectorized binary searches; the scatter is a
    permutation, so the result is sorted by (key, seq) and stable.
    Padding (KEY_EMPTY) naturally ranks to the tail.
    """
    n, mth = ak.shape[0], bk.shape[0]

    # rank = lexicographic lower_bound over (key, seq): runs are sorted by
    # (key, seq) — including intermediate tournament rounds, which may hold
    # duplicate keys — so a branch-free binary search with the pairwise
    # comparator is exact. O(n log m) work, fully lane-parallel.
    def rank_in(other_k, other_s, qk, qs):
        size = other_k.shape[0]
        steps = max(1, math.ceil(math.log2(size + 1)))
        lo = jnp.zeros(qk.shape, jnp.int32)
        hi = jnp.full(qk.shape, size, jnp.int32)

        def body(_, lohi):
            lo, hi = lohi
            mid = (lo + hi) // 2
            midc = jnp.clip(mid, 0, size - 1)
            ok_, os_mid = other_k[midc], other_s[midc]
            before = (ok_ < qk) | ((ok_ == qk) & (os_mid < qs))
            active = lo < hi
            new_lo = jnp.where(before, mid + 1, lo)
            new_hi = jnp.where(before, hi, mid)
            return (jnp.where(active, new_lo, lo),
                    jnp.where(active, new_hi, hi))

        lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
        return lo

    pa = jnp.arange(n, dtype=jnp.int32) + rank_in(bk, bs, ak, as_)
    pb = jnp.arange(mth, dtype=jnp.int32) + rank_in(ak, as_, bk, bs)
    total = n + mth
    ok = jnp.full((total,), KEY_EMPTY, ak.dtype).at[pa].set(ak).at[pb].set(bk)
    ov = jnp.zeros((total,), av.dtype).at[pa].set(av).at[pb].set(bv)
    ow = jnp.zeros((total,), aw.dtype).at[pa].set(aw).at[pb].set(bw)
    os_ = jnp.zeros((total,), as_.dtype).at[pa].set(as_).at[pb].set(bs)
    return ok, ov, ow, os_


def merge_kway_ranked(keys2d, vals2d, wts2d, seqs2d, drop_annihilated: bool):
    """Tournament of rank-merges: log2(k) parallel passes (paper-equivalent
    O(n log k) work). Used by benchmarks to compare against `merge_runs`."""
    runs = [(keys2d[i], vals2d[i], wts2d[i], seqs2d[i])
            for i in range(keys2d.shape[0])]
    while len(runs) > 1:
        nxt = []
        for i in range(0, len(runs) - 1, 2):
            nxt.append(merge_two_ranked(*runs[i], *runs[i + 1]))
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    k, v, w, s = runs[0]
    valid = survivor_mask(k, w, drop_annihilated)
    return compact(k, v, w, s, valid)


def build_fences(keys: jax.Array, mu: int, n_fences: int) -> jax.Array:
    """Fence pointers (paper 2.4): the key at every mu-th slot."""
    idx = jnp.arange(n_fences, dtype=jnp.int32) * mu
    return keys[jnp.clip(idx, 0, keys.shape[0] - 1)]


def run_minmax(keys: jax.Array, count: jax.Array):
    """(min, max) key of a compacted sorted run (paper 2.3 max/min filter)."""
    mn = jnp.where(count > 0, keys[0], KEY_EMPTY)
    mx = jnp.where(count > 0, keys[jnp.maximum(count - 1, 0)], _KEY_MIN)
    return mn, mx
