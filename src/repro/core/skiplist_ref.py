"""Paper-faithful skiplist (Section 2.2) — the CPU oracle.

Implements the two optimizations exactly as published:
  * 2.2.1 Fast Random Levels: draw MAXLEVEL random bits, level =
    find-first-set => geometric(p=0.5) in O(1), MAXLEVEL = 16.
  * 2.2.2 Vertical Arrays, Horizontal Pointers: a node owns one key, one
    value and a dense *array* of forward pointers (the vertical column);
    descending a level reads the next array slot instead of chasing a
    pointer.

This is NOT the TPU execution path (pointer chasing does not map to the
VPU/MXU — see DESIGN.md §2); it exists to (a) document the paper's
structure precisely, (b) oracle-test the engine's buffer semantics, and
(c) validate `fast_geometric_levels` against an independent implementation.
"""
from __future__ import annotations

import numpy as np

MAXLEVEL = 16


def ffs_level(rng: np.random.Generator, maxlevel: int = MAXLEVEL) -> int:
    """Paper 2.2.1: MAXLEVEL random bits -> find-first-set (1-based)."""
    bits = int(rng.integers(0, 1 << maxlevel))
    if bits == 0:
        return maxlevel
    return min((bits & -bits).bit_length(), maxlevel)


class _Node:
    __slots__ = ("key", "val", "fwd")

    def __init__(self, key, val, level):
        self.key = key
        self.val = val
        self.fwd: list = [None] * level  # the vertical pointer column


class SkipListRef:
    """Ordered map with paper-exact insert/lookup/range (update-in-place on
    duplicate keys, per 3.9.1)."""

    def __init__(self, seed: int = 0, maxlevel: int = MAXLEVEL):
        self.maxlevel = maxlevel
        self.rng = np.random.default_rng(seed)
        self.head = _Node(None, None, maxlevel)
        self.level = 1
        self.n = 0

    def _find_update(self, key):
        update = [self.head] * self.maxlevel
        x = self.head
        for lvl in range(self.level - 1, -1, -1):
            while x.fwd[lvl] is not None and x.fwd[lvl].key < key:
                x = x.fwd[lvl]
            update[lvl] = x
        return update

    def insert(self, key: int, val: int) -> None:
        update = self._find_update(key)
        nxt = update[0].fwd[0]
        if nxt is not None and nxt.key == key:  # paper 3.9.1: update in place
            nxt.val = val
            return
        lvl = ffs_level(self.rng, self.maxlevel)
        self.level = max(self.level, lvl)
        node = _Node(key, val, lvl)
        for i in range(lvl):
            node.fwd[i] = update[i].fwd[i]
            update[i].fwd[i] = node
        self.n += 1

    def lookup(self, key: int):
        x = self.head
        for lvl in range(self.level - 1, -1, -1):
            while x.fwd[lvl] is not None and x.fwd[lvl].key < key:
                x = x.fwd[lvl]
        x = x.fwd[0]
        if x is not None and x.key == key:
            return x.val
        return None

    def range(self, lo: int, hi: int):
        """Paper 2.9: locate smallest key >= lo, walk level-0 until >= hi."""
        x = self.head
        for lvl in range(self.level - 1, -1, -1):
            while x.fwd[lvl] is not None and x.fwd[lvl].key < lo:
                x = x.fwd[lvl]
        x = x.fwd[0]
        out = []
        while x is not None and x.key < hi:
            out.append((x.key, x.val))
            x = x.fwd[0]
        return out

    def items(self):
        out = []
        x = self.head.fwd[0]
        while x is not None:
            out.append((x.key, x.val))
            x = x.fwd[0]
        return out
