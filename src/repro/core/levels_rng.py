"""Fast O(1) geometric skiplist levels (paper 2.2.1), TPU-native.

The paper replaces the iterative coin-flip loop with: draw MAXLEVEL random
bits, return find-first-set — P(level = n) = 2^-n, exactly geometric(p=.5).
x86 `bsf` has no TPU instruction, but the same O(1) trick is expressible in
vector ops: isolate the lowest set bit with `x & -x`, then
popcount((x & -x) - 1) counts the trailing zeros. `jax.lax.population_count`
lowers to a native VPU op, so one fused vector expression generates a whole
batch of levels — the batched analogue of the paper's hardware builtin.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

MAXLEVEL = 16  # paper 2.2.1: experimentally optimal; two cache lines of lanes


def fast_geometric_levels(key: jax.Array, shape: tuple[int, ...],
                          maxlevel: int = MAXLEVEL) -> jax.Array:
    """Levels in [1, maxlevel], P(level=n) = 2^-n (capped at maxlevel).

    Equivalent of the paper's `ffs(random_bits)` — O(1) per element and
    fully vectorized.
    """
    bits = jax.random.bits(key, shape, dtype=jnp.uint32)
    mask = np.uint32((1 << maxlevel) - 1)
    r = bits & mask
    lowest = r & (~r + np.uint32(1))  # x & -x, uint-safe
    ctz = jax.lax.population_count(lowest - np.uint32(1))
    # r == 0 (prob 2^-maxlevel) -> cap at maxlevel; ffs is 1-based.
    level = jnp.where(r == 0, np.uint32(maxlevel - 1), ctz) + np.uint32(1)
    return jnp.minimum(level, np.uint32(maxlevel)).astype(jnp.int32)


def express_lane_offsets(rn: int) -> list[int]:
    """Deterministic express lanes: lane l samples every 2^l-th key.

    This is the dense-array limit of the paper's 2.2.2 "vertical arrays"
    optimization: the geometric level distribution realized as strided
    samples over a sorted run, giving skiplist-descent search over
    contiguous memory (VMEM-tileable) instead of pointer chasing.
    """
    lanes = []
    stride = 1
    while stride < rn:
        lanes.append(stride)
        stride *= 2
    return lanes
