"""Bloom filters (paper 2.3) — Murmur3-style double hashing, vectorized.

The paper pairs one filter per run (memory and disk), uses Murmur3 and the
double-hashing trick h_i = h1 + i*h2 so k probe positions cost two hashes.
We keep all of that; the bitset is a uint32 word array and insert/probe are
batched scatter/gather ops (TPU-native form of "bitset + test").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

SEED1 = np.uint32(0x9E3779B9)
SEED2 = np.uint32(0x85EBCA77)

_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)


def fmix32(x: jax.Array) -> jax.Array:
    """Murmur3 32-bit finalizer (the avalanche core of Murmur3)."""
    x = x ^ (x >> np.uint32(16))
    x = x * _C1
    x = x ^ (x >> np.uint32(13))
    x = x * _C2
    x = x ^ (x >> np.uint32(16))
    return x


def _as_u32(keys: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(keys.astype(jnp.int32), jnp.uint32)


def probe_positions(keys: jax.Array, k: int, bits: int) -> jax.Array:
    """(..., k) uint32 bit positions via double hashing (paper 2.3)."""
    u = _as_u32(keys)
    h1 = fmix32(u ^ SEED1)
    h2 = fmix32(u ^ SEED2) | np.uint32(1)  # odd => full-period stride
    i = jnp.arange(k, dtype=jnp.uint32)
    pos = h1[..., None] + i * h2[..., None]
    return pos % np.uint32(bits)


def bloom_build(keys: jax.Array, valid: jax.Array, words: int, k: int,
                bits: int | None = None) -> jax.Array:
    """Build a (words,) uint32 filter over `keys` where `valid`.

    `bits` is the *effective* filter size; default words*32 (the whole
    array). The adaptive tuner (DESIGN.md §9) sizes arrays physically for
    its densest allocation and passes the current allocation's smaller
    `bits` here — probe positions then stay inside [0, bits) and the
    tail words are never touched, so probe (with the same `bits`) and
    build agree."""
    if bits is None:
        bits = words * 32
    assert bits <= words * 32, f"effective bits {bits} > {words} words"
    bits_phys = words * 32
    pos = probe_positions(keys, k, bits).astype(jnp.int32)
    # invalid keys -> out-of-range position, dropped by the scatter
    pos = jnp.where(valid[..., None], pos, bits_phys)
    hot = jnp.zeros((bits_phys,), jnp.bool_).at[pos.reshape(-1)].set(
        True, mode="drop")
    weights = jnp.left_shift(np.uint32(1), jnp.arange(32, dtype=jnp.uint32))
    return (hot.reshape(words, 32).astype(jnp.uint32) * weights).sum(
        axis=1, dtype=jnp.uint32
    )


def bloom_insert(filter_words: jax.Array, keys: jax.Array, valid: jax.Array,
                 k: int, bits: int | None = None) -> jax.Array:
    """OR new keys into an existing filter."""
    add = bloom_build(keys, valid, filter_words.shape[-1], k, bits)
    return filter_words | add


def bloom_probe(filter_words: jax.Array, keys: jax.Array, k: int,
                bits: int | None = None) -> jax.Array:
    """Membership test. No false negatives; false positives at rate ~eps.

    filter_words: (words,) uint32;  keys: (...,) int32  ->  (...,) bool
    `bits` = effective filter size (default: the whole array) — must
    match what `bloom_build` was given or probes read the wrong bits.
    """
    if bits is None:
        bits = filter_words.shape[-1] * 32
    pos = probe_positions(keys, k, bits).astype(jnp.int32)
    w = filter_words[pos // 32]
    bit = (w >> (pos % 32).astype(jnp.uint32)) & np.uint32(1)
    return jnp.all(bit == np.uint32(1), axis=-1)
