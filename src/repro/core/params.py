"""sLSM tuning parameters — Table 1 of the paper.

| Parm | Meaning                       | Range    |
|------|-------------------------------|----------|
| R    | Number of runs                | Z > 0    |
| Rn   | Elements per run              | Z > 0    |
| eps  | Bloom filter FP rate          | (0, 1)   |
| D    | Number of disk runs per level | Z > 0    |
| m    | Fraction of runs merged       | (0, 1]   |
| mu   | Fence pointer page size       | Z > 0    |

Paper baseline (Section 3): mu=512, eps=0.001, R=50, Rn=800, D=20, m=1.0.

TPU-adaptation-only knobs (static shapes require bounds):
  max_levels  — preallocated tier count (paper: levels grow unboundedly).
  max_range   — static bound on range-query result size.
  cand_factor — per-query candidate bound for the Bloom-compacted lookup.
  range_cand  — per-scan candidate budget of the range engine (DESIGN.md
                §10): how many in-window elements one scan gathers and
                merges across all structures. None (default) = the total
                resident capacity, i.e. every scan is exact at
                full-width cost; a finite budget bounds the scan's
                device work — a scan whose true in-window extent
                overflows it returns a correct sorted prefix with the
                `truncated` flag raised.
  backend     — ops-dispatch target for the hot primitives (Bloom probe,
                fence lookup, run merge): "jnp" reference implementations
                or "pallas" kernels (repro.kernels, interpret mode off-TPU).

Scheduling knob (this repro's merge-pacing subsystem, DESIGN.md §8):
  merge_budget — voluntary maintenance steps (seal/flush/spill/compact,
                 see repro.engine.scheduler) executed per staged insert
                 chunk. 0 (default) = legacy synchronous mode: the whole
                 Do-Merge cascade runs inline the moment an insert needs
                 space, reproducing the paper's write-stall pathology;
                 >0 paces the cascade one bounded step at a time across
                 subsequent chunks, flattening insert tail latency.

Tuning knobs (this repro's adaptive memory/filter tuner, DESIGN.md §9):
  eps_per_level — per-disk-level Bloom FP rates replacing the single
                  global eps (Monkey-style allocation: deeper, larger
                  levels get fewer bits per element). None = eps at
                  every level, the paper's uniform sizing.
  eps_mem       — FP rate of the sealed-memory-run filters (None = eps).
  r_eff         — memory runs actually used before a flush becomes
                  pending (None = R). Shrinking it frees write-buffer
                  bytes the tuner can spend on filters; the physical R
                  run slots stay allocated (static shapes).
  fence_stride  — fence-pointer subsampling factor (power of two):
                  lookups consult every stride-th fence with an
                  (mu*stride)-wide page window. Fences are always BUILT
                  at the finest granularity; the stride is a read-side
                  view, so retuning it costs nothing.
  tuning        — the TuningPolicy. mode="static" (default): the knobs
                  above are fixed for the run and behaviour is
                  bit-identical to an engine without the tuner.
                  mode="adaptive": `repro.engine.tuner` re-partitions
                  one byte budget across these knobs at merge
                  boundaries as the observed workload shifts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

# Key/value sentinels. Keys are int32 (paper: 32-bit integer keys).
KEY_EMPTY = np.int32(np.iinfo(np.int32).max)   # reserved KEY: empty slot/pad
# Historical reserved value: pre-weighted engines marked deletes by storing
# this value. The Z-set record algebra (DESIGN.md §13) made deletion a
# -1-weight record instead, so every int32 payload is legal; the constant
# survives only for legacy WAL decode (wal.decode_write on REC_WRITE).
TOMBSTONE = np.int32(np.iinfo(np.int32).min)
SEQ_NONE = np.int32(-1)                        # "no match" sequence number


@dataclass(frozen=True)
class TuningPolicy:
    """Controller policy for the adaptive memory/filter tuner (DESIGN.md §9).

    Hashable (it rides inside `SLSMParams`, a jit static argument). With
    ``mode="static"`` (the default) the tuner never acts and the engine
    behaves bit-identically to one without a tuner. With
    ``mode="adaptive"`` the `repro.engine.tuner.Tuner` observes the
    read/write mix and re-partitions one byte budget — write-buffer
    capacity vs per-level Bloom bits vs fence granularity — at merge
    boundaries, applying each decision as a scheduler `RETUNE` step.
    """

    mode: str = "static"          # "static" | "adaptive"
    budget_bytes: int | None = None  # byte budget; None = the engine's own
    #                                  static allocation (nothing to gain or
    #                                  lose until the tuner moves bytes)
    eps_floor: float = 1e-4       # densest per-level FP rate any allocation
    #                               may emit — sizes the physical filter
    #                               arrays (static shapes need a bound)
    eps_write: float = 2e-2       # filter FP rate of the write-optimized
    #                               allocation (cheap builds, fast merges)
    interval: int = 2048          # ops between tuner decisions (cooldown)
    read_heavy: float = 0.7       # EWMA read fraction that triggers the
    write_heavy: float = 0.7      # read-/write-optimized allocation
    ewma: float = 0.4             # smoothing of the read/write mix signal

    def __post_init__(self):
        if self.mode not in ("static", "adaptive"):
            raise ValueError(f"unknown tuning mode {self.mode!r}; "
                             "expected 'static' or 'adaptive'")
        if not 0.0 < self.eps_floor < 1.0 or not 0.0 < self.eps_write < 1.0:
            raise ValueError("eps_floor and eps_write must lie in (0, 1)")
        if self.interval < 1:
            raise ValueError("interval must be >= 1")
        if not (0.0 < self.read_heavy <= 1.0 and 0.0 < self.write_heavy <= 1.0
                and 0.0 < self.ewma <= 1.0):
            raise ValueError("read_heavy/write_heavy/ewma must lie in (0, 1]")


@dataclass(frozen=True)
class SLSMParams:
    """Hashable (usable as a jit static argument) parameter set."""

    R: int = 50          # number of memory-buffer runs
    Rn: int = 800        # elements per memory run
    eps: float = 1e-3    # Bloom filter false-positive rate
    D: int = 20          # runs per disk level
    m: float = 1.0       # fraction of runs merged
    mu: int = 512        # fence-pointer page size
    max_levels: int = 3  # preallocated disk tiers (grown lazily host-side)
    max_range: int = 4096
    cand_factor: int = 8
    range_cand: int | None = None  # per-scan candidate budget (None = total
    #                                capacity: every scan is exact; a finite
    #                                budget bounds the scan's sort/merge
    #                                width — overflowing scans return a
    #                                correct prefix with `truncated` set)
    backend: str = "jnp"  # hot-primitive dispatch: "jnp" | "pallas"
    merge_budget: int = 0  # paced merge steps per insert chunk (0 = sync)
    # -- tuning knobs (DESIGN.md §9; all default to the paper's behaviour) --
    eps_per_level: tuple | None = None  # per-level FP rates (None = eps)
    eps_mem: float | None = None        # memory-run filter FP (None = eps)
    r_eff: int | None = None            # memory runs in active use (None = R)
    fence_stride: int = 1               # fence subsampling (read-side view)
    tuning: TuningPolicy = TuningPolicy()

    def __post_init__(self):
        assert self.R > 0 and self.Rn > 0 and self.D > 0 and self.mu > 0
        assert 0.0 < self.eps < 1.0 and 0.0 < self.m <= 1.0
        if self.merge_budget < 0:
            raise ValueError(
                f"merge_budget must be >= 0 (got {self.merge_budget}); "
                "0 = synchronous merges, >0 = steps per insert chunk")
        if self.backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}; "
                             "expected 'jnp' or 'pallas'")
        if self.range_cand is not None and self.range_cand < 1:
            raise ValueError(
                f"range_cand must be >= 1 or None (got {self.range_cand}); "
                "None = unbounded (exact scans at full-capacity cost)")
        if self.eps_per_level is not None:
            if len(self.eps_per_level) != self.max_levels:
                raise ValueError(
                    f"eps_per_level needs one rate per level "
                    f"(got {len(self.eps_per_level)}, max_levels="
                    f"{self.max_levels})")
            if not all(0.0 < e < 1.0 for e in self.eps_per_level):
                raise ValueError("eps_per_level rates must lie in (0, 1)")
        if self.eps_mem is not None and not 0.0 < self.eps_mem < 1.0:
            raise ValueError("eps_mem must lie in (0, 1)")
        if self.r_eff is not None and not 1 <= self.r_eff <= self.R:
            raise ValueError(
                f"r_eff must lie in [1, R={self.R}] (got {self.r_eff})")
        if self.fence_stride < 1 or (self.fence_stride
                                     & (self.fence_stride - 1)):
            raise ValueError(
                f"fence_stride must be a power of two >= 1 "
                f"(got {self.fence_stride})")

    # ---- derived geometry -------------------------------------------------
    @property
    def runs_merged(self) -> int:
        """ceil(m*R) memory runs flushed per buffer merge (paper 2.1).

        Physical geometry: sizes level 0 (`level_cap`), so it uses the
        full R regardless of the tuner's `r_eff` — see `runs_merged_eff`
        for the count a flush actually merges."""
        return max(1, math.ceil(self.m * self.R))

    @property
    def disk_runs_merged(self) -> int:
        """ceil(m*D) disk runs merged when a level spills (paper 2.5)."""
        return max(1, math.ceil(self.m * self.D))

    def level_cap(self, level: int) -> int:
        """Capacity (elements) of one run at `level`.

        cap(0) = ceil(m*R)*Rn rounded up to a mu multiple (fence pages must
        tile the run exactly); cap(l+1) = ceil(m*D)*cap(l) — the paper's
        geometric growth ("number of elements at level k is O((mD)^k)").
        The deepest preallocated level gets a x D bonus so a full-level
        in-place compaction fits.
        """
        c0 = self.runs_merged * self.Rn
        c = ((c0 + self.mu - 1) // self.mu) * self.mu  # mu-aligned
        c *= self.disk_runs_merged ** level
        if level == self.max_levels - 1:
            c *= self.D
        return c

    def n_fences(self, level: int) -> int:
        return self.level_cap(level) // self.mu

    @property
    def stage_cap(self) -> int:
        """Staging (active-run) capacity: 2*Rn so an Rn-chunk always fits."""
        return 2 * self.Rn

    def range_cand_eff(self, n_levels: int) -> int:
        """Per-scan candidate-buffer width for a tree with `n_levels`
        materialized disk levels (DESIGN.md §10): the configured
        `range_cand` budget, clamped to the total resident capacity — a
        scan can never yield more candidates than the structure holds,
        so None (unbounded) resolves to that total and stays exact."""
        total = self.stage_cap + self.R * self.Rn + sum(
            self.D * self.level_cap(lvl) for lvl in range(n_levels))
        return total if self.range_cand is None else min(self.range_cand,
                                                         total)

    @property
    def max_candidates(self) -> int:
        """Static bound used by the Bloom-compacted (sparse) disk lookup."""
        return self.cand_factor

    # ---- effective tuning views (what the current allocation uses) --------
    @property
    def R_eff(self) -> int:
        """Memory runs in active use: a flush becomes *pending* at this
        occupancy (the tuner's write-buffer arm); physical slots stay R."""
        return self.R if self.r_eff is None else self.r_eff

    @property
    def runs_merged_eff(self) -> int:
        """ceil(m*R_eff) memory runs a flush actually merges."""
        return max(1, math.ceil(self.m * self.R_eff))

    @property
    def mem_eps(self) -> float:
        """Effective FP rate of the sealed-memory-run filters."""
        return self.eps if self.eps_mem is None else self.eps_mem

    def level_eps(self, level: int) -> float:
        """Effective FP rate of `level`'s run filters (paper 2.3; Monkey-
        style per-level allocation when `eps_per_level` is set)."""
        if self.eps_per_level is None:
            return self.eps
        return self.eps_per_level[min(level, len(self.eps_per_level) - 1)]

    def bloom_geometry(self, n: int, eps: float | None = None
                       ) -> tuple[int, int, int]:
        """(bits, words, k) for an n-element run at FP rate `eps` (default:
        the global eps).

        bits = ceil(-n ln eps / ln(2)^2), k = round(-log2 eps) — standard
        Bloom sizing; the paper's double-hashing needs only two base hashes.
        """
        e = self.eps if eps is None else eps
        bits = int(math.ceil(-n * math.log(e) / (math.log(2.0) ** 2)))
        bits = max(64, ((bits + 31) // 32) * 32)
        k = max(1, int(round(-math.log(e) / math.log(2.0))))
        return bits, bits // 32, k

    def bloom_words_physical(self, n: int, eff_eps: float) -> int:
        """Allocated filter width (uint32 words) for an n-element run.

        Static shapes force a bound: in adaptive mode the arrays are sized
        for the densest allocation the tuner may ever emit
        (`tuning.eps_floor`, or the configured eps if even denser), so an
        allocation switch never restructures the state pytree — only the
        *effective* bits/k used inside the fixed-width array change. In
        static mode physical == effective, byte-for-byte today's layout.
        """
        if self.tuning.mode == "adaptive":
            return self.bloom_geometry(n, min(self.eps,
                                              self.tuning.eps_floor))[1]
        return self.bloom_geometry(n, eff_eps)[1]

    def fence_view(self, level: int) -> tuple[int, int]:
        """(stride, mu_eff) — the read-side fence view of `level`.

        Fences are built at the finest granularity (every mu slots); a
        stride > 1 consults every stride-th fence with an (mu*stride)-wide
        page window. Clamped so the window never exceeds the level
        capacity."""
        stride = min(self.fence_stride, max(1, self.n_fences(level)))
        return stride, self.mu * stride
