"""sLSM tuning parameters — Table 1 of the paper.

| Parm | Meaning                       | Range    |
|------|-------------------------------|----------|
| R    | Number of runs                | Z > 0    |
| Rn   | Elements per run              | Z > 0    |
| eps  | Bloom filter FP rate          | (0, 1)   |
| D    | Number of disk runs per level | Z > 0    |
| m    | Fraction of runs merged       | (0, 1]   |
| mu   | Fence pointer page size       | Z > 0    |

Paper baseline (Section 3): mu=512, eps=0.001, R=50, Rn=800, D=20, m=1.0.

TPU-adaptation-only knobs (static shapes require bounds):
  max_levels  — preallocated tier count (paper: levels grow unboundedly).
  max_range   — static bound on range-query result size.
  cand_factor — per-query candidate bound for the Bloom-compacted lookup.
  backend     — ops-dispatch target for the hot primitives (Bloom probe,
                fence lookup, run merge): "jnp" reference implementations
                or "pallas" kernels (repro.kernels, interpret mode off-TPU).

Scheduling knob (this repro's merge-pacing subsystem, DESIGN.md §8):
  merge_budget — voluntary maintenance steps (seal/flush/spill/compact,
                 see repro.engine.scheduler) executed per staged insert
                 chunk. 0 (default) = legacy synchronous mode: the whole
                 Do-Merge cascade runs inline the moment an insert needs
                 space, reproducing the paper's write-stall pathology;
                 >0 paces the cascade one bounded step at a time across
                 subsequent chunks, flattening insert tail latency.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

# Key/value sentinels. Keys are int32 (paper: 32-bit integer keys).
KEY_EMPTY = np.int32(np.iinfo(np.int32).max)   # reserved: empty slot / padding
TOMBSTONE = np.int32(np.iinfo(np.int32).min)   # reserved value: deleted key
SEQ_NONE = np.int32(-1)                        # "no match" sequence number


@dataclass(frozen=True)
class SLSMParams:
    """Hashable (usable as a jit static argument) parameter set."""

    R: int = 50          # number of memory-buffer runs
    Rn: int = 800        # elements per memory run
    eps: float = 1e-3    # Bloom filter false-positive rate
    D: int = 20          # runs per disk level
    m: float = 1.0       # fraction of runs merged
    mu: int = 512        # fence-pointer page size
    max_levels: int = 3  # preallocated disk tiers (grown lazily host-side)
    max_range: int = 4096
    cand_factor: int = 8
    backend: str = "jnp"  # hot-primitive dispatch: "jnp" | "pallas"
    merge_budget: int = 0  # paced merge steps per insert chunk (0 = sync)

    def __post_init__(self):
        assert self.R > 0 and self.Rn > 0 and self.D > 0 and self.mu > 0
        assert 0.0 < self.eps < 1.0 and 0.0 < self.m <= 1.0
        if self.merge_budget < 0:
            raise ValueError(
                f"merge_budget must be >= 0 (got {self.merge_budget}); "
                "0 = synchronous merges, >0 = steps per insert chunk")
        if self.backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}; "
                             "expected 'jnp' or 'pallas'")

    # ---- derived geometry -------------------------------------------------
    @property
    def runs_merged(self) -> int:
        """ceil(m*R) memory runs flushed per buffer merge (paper 2.1)."""
        return max(1, math.ceil(self.m * self.R))

    @property
    def disk_runs_merged(self) -> int:
        """ceil(m*D) disk runs merged when a level spills (paper 2.5)."""
        return max(1, math.ceil(self.m * self.D))

    def level_cap(self, level: int) -> int:
        """Capacity (elements) of one run at `level`.

        cap(0) = ceil(m*R)*Rn rounded up to a mu multiple (fence pages must
        tile the run exactly); cap(l+1) = ceil(m*D)*cap(l) — the paper's
        geometric growth ("number of elements at level k is O((mD)^k)").
        The deepest preallocated level gets a x D bonus so a full-level
        in-place compaction fits.
        """
        c0 = self.runs_merged * self.Rn
        c = ((c0 + self.mu - 1) // self.mu) * self.mu  # mu-aligned
        c *= self.disk_runs_merged ** level
        if level == self.max_levels - 1:
            c *= self.D
        return c

    def n_fences(self, level: int) -> int:
        return self.level_cap(level) // self.mu

    @property
    def stage_cap(self) -> int:
        """Staging (active-run) capacity: 2*Rn so an Rn-chunk always fits."""
        return 2 * self.Rn

    @property
    def max_candidates(self) -> int:
        """Static bound used by the Bloom-compacted (sparse) disk lookup."""
        return self.cand_factor

    def bloom_geometry(self, n: int) -> tuple[int, int, int]:
        """(bits, words, k) for an n-element run at FP rate eps.

        bits = ceil(-n ln eps / ln(2)^2), k = round(-log2 eps) — standard
        Bloom sizing; the paper's double-hashing needs only two base hashes.
        """
        bits = int(math.ceil(-n * math.log(self.eps) / (math.log(2.0) ** 2)))
        bits = max(64, ((bits + 31) // 32) * 32)
        k = max(1, int(round(-math.log(self.eps) / math.log(2.0))))
        return bits, bits // 32, k
