"""Reference semantics model: a plain dict with LSM-visible behaviour.

Property tests drive identical op sequences through SLSM and this model
and require identical observable results (lookup values / found flags,
range contents). The model is the ground truth for *what* the structure
stores; `skiplist_ref.py` is the ground truth for *how* the paper's
in-memory component behaves.
"""
from __future__ import annotations

import numpy as np

from repro.core.params import TOMBSTONE


class DictOracle:
    def __init__(self):
        self.d: dict[int, int] = {}

    def insert(self, keys, vals) -> None:
        for k, v in zip(np.asarray(keys).reshape(-1).tolist(),
                        np.asarray(vals).reshape(-1).tolist()):
            self.d[int(k)] = int(v)

    def delete(self, keys) -> None:
        self.insert(keys, [int(TOMBSTONE)] * len(np.asarray(keys).reshape(-1)))

    def lookup(self, keys):
        vals, found = [], []
        for k in np.asarray(keys).reshape(-1).tolist():
            v = self.d.get(int(k))
            ok = v is not None and v != int(TOMBSTONE)
            vals.append(v if ok else 0)
            found.append(ok)
        return np.asarray(vals, np.int32), np.asarray(found, bool)

    def range(self, lo: int, hi: int):
        items = sorted((k, v) for k, v in self.d.items()
                       if lo <= k < hi and v != int(TOMBSTONE))
        if not items:
            return np.zeros(0, np.int32), np.zeros(0, np.int32)
        ks, vs = zip(*items)
        return np.asarray(ks, np.int32), np.asarray(vs, np.int32)
