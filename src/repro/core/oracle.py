"""Reference semantics model: a plain dict with LSM-visible behaviour.

Property tests drive identical op sequences through SLSM and this model
and require identical observable results (lookup values / found flags,
range contents, windowed aggregates). The model is the ground truth for
*what* the structure stores; `skiplist_ref.py` is the ground truth for
*how* the paper's in-memory component behaves.

Presence is tracked explicitly (the Z-set view, DESIGN.md §13): a
delete removes the key rather than storing a reserved value, so every
int32 — including the engine's historical TOMBSTONE bit pattern — is a
legal, round-trippable payload.
"""
from __future__ import annotations

import numpy as np


class DictOracle:
    def __init__(self):
        self.d: dict[int, int] = {}

    def insert(self, keys, vals) -> None:
        for k, v in zip(np.asarray(keys).reshape(-1).tolist(),
                        np.asarray(vals).reshape(-1).tolist()):
            self.d[int(k)] = int(v)

    def delete(self, keys) -> None:
        for k in np.asarray(keys).reshape(-1).tolist():
            self.d.pop(int(k), None)

    def apply(self, keys, vals, wts) -> None:
        """Weighted write chunk (the WAL replay form): weight +1 inserts
        the pair, weight <= 0 deletes the key."""
        for k, v, w in zip(np.asarray(keys).reshape(-1).tolist(),
                           np.asarray(vals).reshape(-1).tolist(),
                           np.asarray(wts).reshape(-1).tolist()):
            if int(w) > 0:
                self.d[int(k)] = int(v)
            else:
                self.d.pop(int(k), None)

    def lookup(self, keys):
        vals, found = [], []
        for k in np.asarray(keys).reshape(-1).tolist():
            v = self.d.get(int(k))
            ok = v is not None
            vals.append(v if ok else 0)
            found.append(ok)
        return np.asarray(vals, np.int32), np.asarray(found, bool)

    def range(self, lo: int, hi: int):
        items = sorted((k, v) for k, v in self.d.items() if lo <= k < hi)
        if not items:
            return np.zeros(0, np.int32), np.zeros(0, np.int32)
        ks, vs = zip(*items)
        return np.asarray(ks, np.int32), np.asarray(vs, np.int32)

    def aggregate(self, lo: int, hi: int):
        """(count, sum) over the live keys in [lo, hi); the sum matches
        the engine's int32 wraparound arithmetic."""
        total = np.int32(0)
        count = 0
        for k, v in self.d.items():
            if lo <= k < hi:
                count += 1
                total = np.int32(total + np.int32(v))
        return count, int(total)
