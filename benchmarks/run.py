"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--fig figNN`` runs one;
default runs the full suite (Figs 2-12 + kernel micro-benches).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    from benchmarks import figs

    ap = argparse.ArgumentParser()
    ap.add_argument("--fig", default="all",
                    help="e.g. fig05 | fig12 | kernels | all")
    args = ap.parse_args()

    fns = figs.ALL_FIGS
    if args.fig != "all":
        fns = [f for f in figs.ALL_FIGS if f.__name__.startswith(args.fig)]
        if not fns:
            sys.exit(f"unknown figure {args.fig}")

    print("name,us_per_call,derived")
    for fn in fns:
        t0 = time.perf_counter()
        for line in fn():
            print(line, flush=True)
        print(f"# {fn.__name__} took {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
