"""Benchmark entry point: workload scenarios (BENCH_*.json) + figure benches.

Scenario mode — the machine-readable perf trajectory (DESIGN.md §7):

    python -m benchmarks.run --scenario all --out .
    python -m benchmarks.run --scenario sweep-R,sweep-eps --out bench_out
    python -m benchmarks.run --scenario zipfian --profile smoke --out /tmp/b
    python -m benchmarks.run --check --out bench_out   # validate existing files
    python -m benchmarks.run --list

Each scenario emits one schema-versioned ``BENCH_<name>.json``
(`repro.bench.schema`) and prints a one-line summary including the
batched-vs-per-query lookup speedup.

Figure mode (legacy per-paper-figure CSV benches, Figs 2-12 + kernels):

    python -m benchmarks.run --fig fig05
    python -m benchmarks.run --fig all
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def _summary(doc: dict) -> str:
    m = doc["metrics"]
    if m.get("serving"):
        s = m["serving"]
        return (f"{doc['name']}: coalesced "
                f"{s['coalesced']['ops_per_s']:.0f} ops/s "
                f"(p99 {s['coalesced']['p99_us']:.0f}us) vs per-request "
                f"{s['per_request']['ops_per_s']:.0f} ops/s "
                f"({s['coalesced_speedup']:.1f}x) at "
                f"{s['coalesced']['clients']} clients, sustained@SLO "
                f"{s['sustained_ops_at_slo']:.0f} ops/s, "
                f"governor {s['governor']['steps']} steps")
    parts = [
        f"{doc['name']}:",
        f"insert {m['insert']['ops_per_s']:.0f} ops/s,",
        f"lookup batched {m['lookup_batched']['ops_per_s']:.0f} ops/s",
        f"vs per-query {m['lookup_per_query']['ops_per_s']:.0f} ops/s",
        f"({m['batched_speedup']:.1f}x),",
        f"merges s/f/s/c="
        f"{m['maintenance']['seals']}/{m['maintenance']['flushes']}/"
        f"{m['maintenance']['spills']}/{m['maintenance']['compactions']},",
        f"bloom fp {m['bloom']['fp_rate_measured']:.2e}",
    ]
    if m.get("tuner"):
        parts[-1] += ","
        parts.append(f"tuner {m['tuner']['active']} "
                     f"({m['maintenance']['retunes']} retunes)")
    if m["range"]:
        parts[-1] += ","
        parts.append(f"range p50 {m['range']['p50_us']:.0f}us")
    if m["delete"]:
        parts[-1] += ","
        parts.append(f"delete {m['delete']['ops_per_s']:.0f} ops/s")
    return " ".join(parts)


def run_scenarios(selector: str, out_dir: str, profile: str) -> None:
    from repro.bench.runner import run_scenario
    from repro.bench.scenarios import scenarios_for

    scenarios = scenarios_for(selector)
    print(f"# {len(scenarios)} scenario(s), profile={profile}, "
          f"out={out_dir}", file=sys.stderr)
    for sc in scenarios:
        t0 = time.perf_counter()
        path, doc = run_scenario(sc, out_dir, profile=profile)
        print(_summary(doc), flush=True)
        print(f"#   wrote {path} in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)
        # compiled executables accumulate memory mappings; a long
        # multi-scenario run (each scenario warms its own parameter
        # set, so there is no cross-scenario cache reuse to lose) can
        # hit the kernel's vm.max_map_count ceiling and segfault XLA's
        # next compile — release each scenario's programs before the
        # next one starts
        import jax
        jax.clear_caches()


def check_dir(out_dir: str) -> None:
    """Validate every BENCH_*.json in out_dir against the schema."""
    from repro.bench.schema import validate

    files = sorted(Path(out_dir).glob("BENCH_*.json"))
    if not files:
        sys.exit(f"no BENCH_*.json files found in {out_dir}")
    bad = 0
    for f in files:
        errs = validate(json.loads(f.read_text()))
        status = "ok" if not errs else "INVALID"
        print(f"{f.name}: {status}")
        for e in errs:
            print(f"  - {e}")
        bad += bool(errs)
    if bad:
        sys.exit(f"{bad}/{len(files)} documents failed schema validation")
    print(f"{len(files)} documents schema-valid "
          f"(schema_version pinned by repro.bench.schema)")


def list_scenarios() -> None:
    from repro.bench.scenarios import CANONICAL, SWEEPS

    print("canonical (--scenario all):")
    for sc in CANONICAL:
        print(f"  {sc.name:24s} workload={sc.workload}")
    for fam, group in sorted(SWEEPS.items()):
        print(f"{fam} (--scenario {fam}):")
        for sc in group:
            knobs = sc.params or {"policy": sc.policy,
                                  "n_shards": sc.n_shards}
            print(f"  {sc.name:24s} {knobs}")


def run_figs(fig: str) -> None:
    from benchmarks import figs

    fns = figs.ALL_FIGS
    if fig != "all":
        fns = [f for f in figs.ALL_FIGS if f.__name__.startswith(fig)]
        if not fns:
            sys.exit(f"unknown figure {fig}")
    print("name,us_per_call,derived")
    for fn in fns:
        t0 = time.perf_counter()
        for line in fn():
            print(line, flush=True)
        print(f"# {fn.__name__} took {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default=None,
                    help="scenario selector: all | sweeps | sweep-R | "
                         "<name> | comma-separated mix")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_*.json files (scenario mode)")
    ap.add_argument("--profile", default="default",
                    choices=("smoke", "default", "full"),
                    help="workload sizing (smoke = CI-scale seconds)")
    ap.add_argument("--check", action="store_true",
                    help="validate BENCH_*.json in --out (combined with "
                         "--scenario: run first, then validate)")
    ap.add_argument("--list", action="store_true",
                    help="list scenario names and exit")
    ap.add_argument("--fig", default=None,
                    help="figure mode: e.g. fig05 | fig12 | kernels | all")
    args = ap.parse_args()

    if args.fig is not None and (args.scenario is not None or args.check
                                 or args.list):
        ap.error("--fig is figure mode; it cannot be combined with "
                 "--scenario/--check/--list")
    if args.list:
        list_scenarios()
        return
    if args.scenario is not None:
        run_scenarios(args.scenario, args.out, args.profile)
    if args.check:
        check_dir(args.out)        # after --scenario: run, then validate
    if args.scenario is None and not args.check:
        run_figs(args.fig or "all")


if __name__ == "__main__":
    main()
