"""BENCH trajectory report: the committed BENCH_*.json files as a table.

The repo root's ``BENCH_<scenario>.json`` documents are the cross-PR
performance trajectory (DESIGN.md §7). This tool renders them as the
markdown table the README embeds, so "what are the current numbers"
never requires opening JSON by hand:

    PYTHONPATH=src python -m benchmarks.report                # print table
    PYTHONPATH=src python -m benchmarks.report --dir bench_out
    PYTHONPATH=src python -m benchmarks.report --update-readme

``--update-readme`` rewrites the block between the BENCH_TABLE markers
in README.md in place (the table is committed alongside regenerated
BENCH files, so the README and the JSON always tell the same story).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

MARK_START = "<!-- BENCH_TABLE_START -->"
MARK_END = "<!-- BENCH_TABLE_END -->"

# canonical scenarios first (trajectory headliners), then sweeps sorted
_CANONICAL_ORDER = ("uniform", "sequential", "zipfian", "delete_heavy",
                    "range_scan", "shifting", "serving", "replication")


def _fmt_ops(x: float) -> str:
    return f"{x / 1e3:.0f}k" if x >= 10_000 else f"{x:.0f}"


def _fmt_us(x: float) -> str:
    return f"{x / 1e3:.1f}ms" if x >= 10_000 else f"{x:.0f}µs"


def load_docs(bench_dir: Path) -> list:
    docs = []
    for path in sorted(bench_dir.glob("BENCH_*.json")):
        try:
            docs.append(json.loads(path.read_text()))
        except (OSError, json.JSONDecodeError) as exc:
            print(f"# skipping {path.name}: {exc}", file=sys.stderr)

    def key(doc):
        name = doc.get("name", "")
        if name in _CANONICAL_ORDER:
            return (0, _CANONICAL_ORDER.index(name), name)
        return (1, 0, name)

    return sorted(docs, key=key)


def render_table(docs: list) -> str:
    """One row per BENCH document; '-' where a scenario has no phase.

    Serving documents (schema v5: standard phases null) fill the lookup
    columns from their coalesced closed-loop point and the speedup
    column from the coalesced-vs-per-request ratio; the platform column
    comes from each document's ``env.platform`` (the jax backend the
    numbers were measured on — rows are only comparable within one
    platform)."""
    head = ("| scenario | insert ops/s | insert p99 | lookup ops/s "
            "| lookup p99 | speedup | range scans/s | annihilated "
            "| replication | bloom FP | tuner | platform |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|---|")
    rows = [head]
    for doc in docs:
        m = doc["metrics"]
        tun = m.get("tuner")
        tuner_cell = (f"{tun['active']} ({m['maintenance']['retunes']} "
                      "retunes)" if tun else "static")
        rb = m.get("range_batched")
        range_cell = _fmt_ops(rb["ops_per_s"]) if rb else "-"
        # v7+: annihilated rows / merge input rows (the weighted-merge
        # dedup+delete elision share, DESIGN.md §13); '-' on older docs
        zs = m.get("zset")
        if zs and zs.get("rows_merged_in"):
            ann_cell = (f"{zs['rows_annihilated'] / 1e3:.0f}k "
                        f"({100 * zs['rows_annihilated'] / zs['rows_merged_in']:.0f}%)")
        elif zs:
            ann_cell = "0"
        else:
            ann_cell = "-"
        platform = doc.get("env", {}).get("platform", "-")
        # v8+: follower apply throughput + failover wall time (the
        # metrics.replication block, DESIGN.md §14); '-' on older docs
        # and on scenarios that attach no followers
        rep = m.get("replication")
        if rep:
            exact = "exact" if rep["promoted_exact"] else "DIVERGED"
            rep_cell = (f"{rep['followers']}f {_fmt_ops(rep['apply_ops_per_s'])} "
                        f"apply/s, {rep['failover_ms']:.0f}ms {exact}")
        else:
            rep_cell = "-"
        srv = m.get("serving")
        if srv:
            co = srv["coalesced"]
            ins_ops, ins_p99 = "-", "-"
            lk_ops = _fmt_ops(co["ops_per_s"])
            lk_p99 = _fmt_us(co["p99_us"])
            speedup = f"{srv['coalesced_speedup']:.0f}x serve"
        else:
            ins_ops = _fmt_ops(m["insert"]["ops_per_s"])
            ins_p99 = _fmt_us(m["insert"]["p99_us"])
            lk_ops = _fmt_ops(m["lookup_batched"]["ops_per_s"])
            lk_p99 = _fmt_us(m["lookup_batched"]["p99_us"])
            speedup = f"{m['batched_speedup']:.0f}x"
        rows.append(
            f"| {doc['name']} "
            f"| {ins_ops} "
            f"| {ins_p99} "
            f"| {lk_ops} "
            f"| {lk_p99} "
            f"| {speedup} "
            f"| {range_cell} "
            f"| {ann_cell} "
            f"| {rep_cell} "
            f"| {m['bloom']['fp_rate_measured']:.1e} "
            f"| {tuner_cell} "
            f"| {platform} |")
    return "\n".join(rows)


def update_readme(readme: Path, table: str) -> None:
    text = readme.read_text()
    if MARK_START not in text or MARK_END not in text:
        raise SystemExit(f"{readme}: BENCH_TABLE markers not found")
    head, rest = text.split(MARK_START, 1)
    _, tail = rest.split(MARK_END, 1)
    readme.write_text(f"{head}{MARK_START}\n{table}\n{MARK_END}{tail}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=".",
                    help="directory holding BENCH_*.json (default: .)")
    ap.add_argument("--update-readme", action="store_true",
                    help="rewrite README.md's BENCH_TABLE block in place")
    args = ap.parse_args(argv)
    docs = load_docs(Path(args.dir))
    if not docs:
        raise SystemExit(f"no BENCH_*.json under {args.dir!r}")
    table = render_table(docs)
    if args.update_readme:
        readme = Path(args.dir) / "README.md"
        update_readme(readme, table)
        print(f"# README table updated ({len(docs)} scenarios)",
              file=sys.stderr)
    else:
        print(table)


if __name__ == "__main__":
    main()
