"""Benchmark harness shared by the per-figure benches.

CPU-hosted JAX measurements: the goal is reproducing the paper's *trends*
(Figs 2-12) — absolute ops/s on one CPU core is not comparable to the
paper's 32-core Xeon, and the TPU-absolute story lives in the roofline
analysis. Sizes are scaled so the full suite runs in minutes.

`bench_params` (the CPU-scaled paper baseline) is shared with the
scenario runner — one source of truth in `repro.bench.scenarios`, so the
figure benches and the BENCH_*.json trajectory measure the same engine
configuration.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.bench.scenarios import bench_params  # noqa: F401  (shared defaults)
from repro.core import SLSM
from repro.core.slsm import lookup_batch


def time_inserts(tree: SLSM, keys, vals) -> float:
    """Returns wall seconds for the insert stream (incl. merges)."""
    t0 = time.perf_counter()
    tree.insert(keys, vals)
    jax.block_until_ready(tree.state.stage_keys)
    return time.perf_counter() - t0


def time_lookups(tree: SLSM, queries, batch: int = 1024,
                 sparse: bool = True) -> float:
    """Wall seconds for all lookups, issued in fixed-size jit batches."""
    import jax.numpy as jnp
    n = (len(queries) // batch) * batch
    queries = queries[:n]
    # warm compile
    out = lookup_batch(tree.p, tree.state, jnp.asarray(queries[:batch]),
                       sparse)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for off in range(0, n, batch):
        out = lookup_batch(tree.p, tree.state,
                           jnp.asarray(queries[off:off + batch]), sparse)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
