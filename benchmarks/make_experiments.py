"""Generate EXPERIMENTS.md from dry-run results (baseline + optimized).

Run after a sweep:  PYTHONPATH=src python benchmarks/make_experiments.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))

from repro.launch.report import (OUT, before_after, dryrun_summary,  # noqa: E402
                                 load, roofline_table)

HILLCLIMB_CELLS = [("deepseek-7b", "decode_32k"),
                   ("qwen3-moe-30b-a3b", "train_4k"),
                   ("deepseek-7b", "long_500k")]

HEADER = """# EXPERIMENTS — sLSM-JAX

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI per chip. Meshes: single pod (data=16, model=16) = 256 chips;
multi-pod (pod=2, data=16, model=16) = 512 chips. This container is
CPU-only: every number below is derived from the *compiled* artifact
(`lower().compile()`), not wall-clock — see §Method.

## §Method

* `launch/dryrun.py` lowers + compiles every (arch x shape x mesh) cell
  with ShapeDtypeStruct inputs (no allocation) and records
  `memory_analysis()` / `cost_analysis()` / the optimized HLO.
* **Trip-count correction**: XLA's `cost_analysis()` counts `while`
  bodies once; every model here scans over layers, so flops/bytes/
  collectives are recomputed by `launch/hlo_cost.py`, a walker that
  multiplies loop bodies by their `known_trip_count` (validated against
  unrolled references; the raw XLA numbers are kept in the records as
  `xla_*`). Verified empirically: a 10-step scanned matmul reports 10x
  the flops under the walker and 1x under `cost_analysis`.
* All per-device quantities: the compiled module is SPMD-partitioned, so
  `cost_analysis`/HLO payloads/`memory_analysis` are per-device
  (verified: a 4-way-sharded input reports 1/4 the argument bytes).
* Roofline terms (seconds, per device):
  `t_compute = flops / 197e12`, `t_memory = bytes / 819e9`,
  `t_collective = collective_payload_bytes / 50e9`.
  `t_collective` treats every collective payload as crossing one ICI
  link — a deliberate upper bound (it ignores algorithm factors like
  ring all-reduce's 2(n-1)/n, and DCN for the pod axis would be slower);
  consistent across cells, so *relative* comparisons are meaningful.
* `useful-FLOP ratio` = analytic MODEL_FLOPs (6ND train / 2ND inference,
  N_active for MoE) / (per-device HLO flops x chips) — catches remat and
  routing waste. Values < 1 are expected (remat recompute, attention
  O(S^2) terms, MoE capacity slack); dense-train cells land at 0.35-0.97.

## §Dry-run

"""

ROOFLINE_INTRO = """
## §Roofline

Baseline = paper-faithful implementation, first full sweep (preserved in
`benchmarks/results/dryrun_baseline/`). Optimized = after the §Perf
iterations (current `benchmarks/results/dryrun/`). Single-pod (16,16)
mesh; the multi-pod (2,16,16) sweep compiles the same cells (that pass
proves the pod axis shards) and its records sit alongside.

`long_500k` cells: `sLSM-KV decode` marks the paper's technique standing
in for dense attention (hot window + summary-gated blocks — without it,
dense 524k decode for full-attention archs would not fit; the *baseline
skip* is thereby converted into a lowerable cell). mamba2/zamba2 run
long_500k natively (O(1)/hybrid state). whisper-tiny long_500k is skipped
by design (448-position decoder) — see DESIGN.md §4.

### Baseline (paper-faithful), single pod

"""

PERF = """
## §Perf — hypothesis -> change -> measure -> validate

The three hillclimbed cells (picked per the brief: worst roofline
fraction family, most collective-bound, most representative of the
paper's technique):

1. **deepseek-7b x decode_32k** (all dense-decode cells were collective-
   bound at fraction ~0)
2. **qwen3-moe-30b-a3b x train_4k** (most collective-bound overall:
   t_coll = 107 s/step)
3. **deepseek-7b x long_500k** (sLSM-KV tiered decode — the paper's
   technique)

### Iteration 1 — decode cache replication (CONFIRMED, 13.6x)

* **Hypothesis**: dense decode cells show 2 x 128.8 GB all-gathers/step.
  Napkin: the whole KV cache (1 TB global / 30 layers x 128 x 32k x 32 x
  128 bf16) is being replicated. Suspect the per-batch ragged cache
  write — `vmap(dynamic_update_slice)` is a data-dependent scatter GSPMD
  cannot partition — plus an `astype(f32)` that forces a full-cache
  f32 copy, and q's head-axis sharding landing on hd instead of kv.
* **Change**: (a) uniform-position cache writes (scalar-start
  `dynamic_update_slice` — static batching; continuous batching would use
  a paged layout instead); (b) contract in cache dtype with
  `preferred_element_type=f32` (no f32 cache copy); (c) pin q's layout
  with a sharding constraint so the kv axis carries the model sharding;
  (d) shard the cache's kv axis over model where divisible.
* **Measured** (deepseek-7b decode_32k, per device/step): collective
  257.7 GB -> 0.008 GB (32,233x); memory 0.72 GB/step halved (no f32
  copy). Step-time bound 5.15 s -> 0.378 s (**13.6x**). Bottleneck:
  collective -> memory, which is correct physics for decode (reading the
  cache IS the work). All dense-decode cells inherit the fix.
* **Validated**: teacher-forcing tests unchanged; remaining collectives
  are the per-layer TP all-reduces (0.1 MB x 30 x 2).

### Iteration 2 — MoE token all-gather (first attempt: REFUTED)

* **Hypothesis**: qwen3-moe train_4k t_coll = 107 s/step comes from
  global routing: argsort/gather over all 1M tokens forces token
  all-gathers. Predicted fix: split routing into DP-aligned groups via
  reshape+vmap so sorts/gathers are shard-local.
* **Change**: `moe_dp_groups=16` (batch-major groups + vmap).
* **Measured**: t_coll unchanged (107 s). **Refuted** — the forward
  gathers did become local, but the *backward* of the expert GEMM
  re-gathered dispatched tokens for weight gradients: 85.9 GB x 48
  layers of all-gather (diagnosed with `launch/diagnose.py`, which
  attributes per-op collective bytes x trip counts).

### Iteration 2b — explicit-collective MoE via shard_map (CONFIRMED, 25.5x on the dominant term)

* **Hypothesis**: the partitioner cannot be coaxed; make data motion
  structural. Inside `shard_map` over (dp, model): routing is computed
  per DP shard (replicated across model — cheap), each model shard
  slices its local experts' dispatch slots, gathers only local tokens,
  runs its (E/16, C, d) GEMMs, and the ONLY collective is the
  expert-output partial-sum all-reduce (537 MB x 48) plus its transpose
  in backward. Napkin: 48 x 0.54 GB / 50 GB/s ~ 0.5 s vs 107 s.
* **Measured** (qwen3-moe train_4k, per device/step): collective
  5,345 GB -> 209.7 GB (**25.5x**); what remains is attention/embedding
  TP all-reduce (1.6 GB x 48 — qwen3's kv=4 < 16 forces replicated-KV
  attention) and the designed MoE combine psum. Bonus: per-device
  compute dropped 9.4x (6.35 -> 0.68 s) because per-shard capacity
  (C_local = C_global/16) eliminates 16x of dispatch-padding GEMM work.
  Step-time bound 107 s -> 7.64 s (memory-bound now): **14.0x**.
* **Validated**: `test_perf_opts.py` — shard-local routing is
  bit-identical to global routing absent capacity overflow; per-shard
  capacity accounting is the standard EP policy.

### Iteration 3 — hierarchical sLSM block selection (REFUTED, kept as documentation)

* **Hypothesis**: long_500k's block top-k gather over data-sharded
  blocks would all-gather block payloads; a local-top-k-then-rerank
  (exact: global top-k is a subset of the union of local top-ks) should
  keep gathers local.
* **Measured**: 16x WORSE (t_coll 0.65 -> 10.3 s) — the (G, NBl) grouped
  gather triggered "involuntary full rematerialization" in the SPMD
  partitioner. Meanwhile the *baseline* selection was already fine once
  Iteration 1's uniform-position writes landed: the dominant long_500k
  collective had been the same cache-write pathology, not the block
  gather. **Kept the baseline selection** (`lsm_dp_groups=1`); the
  hierarchical path remains implemented + tested
  (`test_grouped_lsm_selection_exact`) for partitioners that handle
  batched gathers. A refuted hypothesis recorded per the method.
* After iteration 1 the cell was unchanged (0.646 s): with batch=1 the
  cache-write pathology never applied; the true cost was diagnosed as
  the *selected-block payload all-reduce*: GSPMD implements the
  data-dependent block gather as masked-local-gather + all-reduce of the
  gathered 268 MB x 30 layers — i.e. it ships the selected KV blocks to
  every shard.

### Iteration 4 — compute-at-data cold attention (CONFIRMED, 88x)

* **Hypothesis**: moving selected block *payloads* is the wrong
  dataflow; attention should run where the blocks live and only
  online-softmax stats (m, l, acc — O(KV x g x hd) ~ KBs) should cross
  shards. Napkin: payload all-reduce 0.65 s vs stats ~0.1 ms; the cell
  should become memory-bound at ~the cost of reading the selected
  blocks once.
* **Change**: `_lsm_cold_stats_shardmap` — shard_map over (data, model):
  each shard masks the global top-k ids to its local block range,
  gathers locally, computes partial softmax stats for its local kv
  heads, then pmax + 2 psums over 'data' merge the stats; the hot-window
  stats merge in at the end (standard flash combine).
* **Measured** (deepseek-7b long_500k, per device/step):
  collective 0.646 s -> 63 us (**10,252x lower**); memory 85 ms ->
  7.4 ms (only selected blocks + hot window are read); step-time bound
  0.646 s -> **7.4 ms (88x)**, now memory-bound — the physical floor
  for "read what the filter admits". All eligible long_500k cells
  (kv % |model| == 0) inherit the path; others keep the gather path.
* **Validated**: subprocess test `test_lsm_stats_merge_matches_dense_path`
  — sharded stats-merge logits == single-device gather-path logits.

### Stopping criterion

After iteration 4, the three cells are memory-bound with collectives
< 20% of the bound; further candidates (remat policy tuning, attention
KV-replication all-gather for kv<16 archs, fused one-hot dispatch) each
napkin-math to <5% on the dominant term of these cells — stopped per the
3-strike rule. The paper-faithful baseline AND the optimized runs are
both preserved.

### Paper-faithful vs beyond-paper summary

| | paper-faithful baseline | beyond-paper optimized | gain |
|---|---|---|---|
| decode_32k (deepseek) | 5.15 s/step, collective-bound | 0.378 s/step, memory-bound | 13.6x |
| train_4k (qwen3-moe) | 107 s/step, collective-bound | 7.6 s/step, memory-bound | 14.0x |
| long_500k (deepseek, sLSM) | 0.646 s/step, collective-bound | 0.0074 s/step, memory-bound | 87x |

## §Paper-reproduction benchmarks (Figs 2-12)

`python -m benchmarks.run` reproduces every figure's *trend* on CPU-hosted
JAX (absolute ops/s are not comparable to the paper's 32-core Xeon; the
TPU-absolute story is the roofline above). See `bench_output.txt` for the
full CSV. Highlights (from the committed run):

* Fig 2: insert throughput rises with R (fewer, later merges), as
  published (3.7k -> 7.7k ins/s over R=2..32 at bench scale).
* Fig 5: the filter's work-elimination is reproduced exactly: measured
  disk-run ADMIT RATE on absent keys tracks eps (off -> 1.0, 0.1 ->
  0.083, 0.01 -> 0.0092, 0.001 -> 4.9e-4, 1e-4 -> 0; no false
  negatives ever). Wall-time is flat on THIS engine because the batched
  vector lookup has no pointer-chasing skiplist walk to skip (the
  paper's build spent 98.9% of CPU there); on TPU the admit rate gates
  the mu-page HBM reads (kernels/fence_lookup, kernels/bloom_probe).
* Fig 7: lookups degrade gracefully as data grows (more levels/runs to
  consult), the paper's effect; insert throughput *rises* with n here
  because host-side merge orchestration amortizes — an artifact of the
  batched CPU harness, noted for honesty.
* Fig 9: low-variance (duplicate-heavy) insert streams are far faster
  (504k/s at var=1e2 vs 286k/s uniform) — update-in-place defers
  merges, as published.
* Fig 11: batched query lanes (the TPU analogue of lookup threads)
  scale near-linearly with batch size.
* Fig 12: async merge dispatch cuts max insert-chunk latency **60x** vs
  blocking on every merge — the paper's merge-threading tail-latency
  result (their Fig 12), reproduced via JAX async dispatch.
* Kernels: the Pallas merge-path HeapMerge beats the XLA sort-based
  merge even in interpret mode (3.3 vs 2.7 Melem/s) — on TPU the gap
  widens (O(n log k) work vs O(n log^2 n) bitonic comparisons).
"""


def main():
    base = load("dryrun_baseline")
    opt = load("dryrun")
    parts = [HEADER, dryrun_summary(opt), ROOFLINE_INTRO,
             roofline_table(base, "pod16x16"),
             "\n### Optimized (beyond-paper), single pod\n",
             roofline_table(opt, "pod16x16"),
             "\n### Hillclimbed cells, before/after (single pod)\n",
             before_after(base, opt, HILLCLIMB_CELLS),
             PERF]
    with open(OUT, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
