"""One benchmark per paper table/figure (Section 3).

Each function returns CSV rows: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_params, row, time_inserts, time_lookups
from repro.bench.workloads import make_kv_workload
from repro.core import SLSM
from repro.core.slsm import (compact_last_level, lookup_batch,
                             merge_buffer_to_level0, range_query)

N_DEFAULT = 60_000
N_LOOKUP = 8_192


def _fresh(params, n=N_DEFAULT, seed=0, kind="uniform", **wargs):
    """Build a store from the workload; time only the steady-state 75%
    (the first quarter warms jit caches and the level structure, so
    cross-size throughput comparisons are not dominated by compiles)."""
    w = make_kv_workload(kind, n, seed=seed, **wargs)
    t = SLSM(params)
    warm = n // 4
    time_inserts(t, w.keys[:warm], w.vals[:warm])
    ins_s = time_inserts(t, w.keys[warm:], w.vals[warm:])
    return t, w, ins_s * n / max(1, (n - warm))  # scale to per-n rate


def fig02_r_sweep():
    """Fig 2: insert/lookup throughput tradeoff vs number of runs R."""
    rows = []
    for r in (2, 4, 8, 16, 32):
        t, w, ins_s = _fresh(bench_params(R=r), seed=r)
        lk_s = time_lookups(t, w.lookups[:N_LOOKUP])
        rows.append(row(f"fig02/R={r}/insert", ins_s / N_DEFAULT * 1e6,
                        f"inserts_per_s={N_DEFAULT/ins_s:.0f}"))
        rows.append(row(f"fig02/R={r}/lookup", lk_s / N_LOOKUP * 1e6,
                        f"lookups_per_s={N_LOOKUP/lk_s:.0f}"))
    return rows


def fig03_buffer_grid():
    """Fig 3: R x Rn grid (small R x Rn cells need deeper trees)."""
    rows = []
    for r in (2, 8, 32):
        for rn in (64, 256, 1024):
            t, w, ins_s = _fresh(bench_params(R=r, Rn=rn, max_levels=5),
                                 n=30_000, seed=r * 100 + rn)
            lk_s = time_lookups(t, w.lookups[:4096], batch=1024)
            rows.append(row(
                f"fig03/R={r}/Rn={rn}", ins_s / 30_000 * 1e6,
                f"ins_per_s={30_000/ins_s:.0f};lk_per_s={4096/lk_s:.0f}"))
    return rows


def fig04_disk_grid():
    """Fig 4: D x m grid. Note the paper's own finding reappears
    structurally: m=0.5 with D=2 gives level growth factor ceil(mD)=1 —
    no geometric growth (the paper hit file-descriptor exhaustion; we hit
    level-count exhaustion), so deep trees are required."""
    rows = []
    import math as _m
    for d in (2, 4, 8):
        for m in (0.5, 1.0):
            dm = max(1, _m.ceil(m * d))
            n = 10_000 if dm == 1 else 20_000  # dm=1: linear capacity
            t, w, ins_s = _fresh(bench_params(D=d, m=m, max_levels=8),
                                 n=n, seed=int(d * 10 + m * 10))
            lk_s = time_lookups(t, w.lookups[:4096], batch=1024)
            rows.append(row(
                f"fig04/D={d}/m={m}", ins_s / n * 1e6,
                f"Dm={d*m:.0f};ins_per_s={n/ins_s:.0f};"
                f"lk_per_s={4096/lk_s:.0f};levels={t.n_levels}"))
    return rows


def fig05_bloom():
    """Fig 5: Bloom filter FP rate sweep (paper: 3.6k/s -> 340k/s).

    eps=0.9999 degenerates the filter (k=1, saturated bits) == 'off'.
    Derived column reports the measured disk-run ADMIT RATE on absent
    keys — the quantity the paper's speedup is made of. On this engine
    the wall-time effect is muted: the TPU-adapted lookup is a batched
    vector pipeline whose fixed costs dominate at bench scale, whereas
    the paper's CPU build pays a pointer-chasing skiplist walk per
    admitted run (98.9% of CPU time without filters). The filter's
    *work-elimination* is reproduced exactly (admit ~ eps); on TPU it
    gates the mu-page HBM reads (see kernels/fence_lookup)."""
    from repro.core import bloom as BL
    rows = []
    for eps, label in ((0.9999, "off"), (0.1, "0.1"), (0.01, "0.01"),
                       (0.001, "0.001"), (0.0001, "1e-4"), (0.00001, "1e-5")):
        t, w, _ = _fresh(bench_params(eps=eps, cand_factor=16), seed=5)
        absent = (w.lookups.astype(np.int64) + 2**30).astype(np.int32)
        lk_s = time_lookups(t, absent[:N_LOOKUP])  # misses: worst case
        # measured admit rate over disk runs for absent keys
        admits, runs = 0.0, 0
        _, _, kk = t.p.bloom_geometry(t.p.level_cap(0))
        for lv in t.state.levels:
            nr = int(lv.n_runs)
            for d in range(nr):
                pos = BL.bloom_probe(lv.blooms[d],
                                     jnp.asarray(absent[:2048]), kk)
                admits += float(pos.mean())
                runs += 1
        rate = admits / max(runs, 1)
        rows.append(row(f"fig05/eps={label}", lk_s / N_LOOKUP * 1e6,
                        f"lookups_per_s={N_LOOKUP/lk_s:.0f};"
                        f"admit_rate={rate:.2e}"))
    return rows


def fig06_range():
    """Fig 6: range query latency is linear in range size.

    range_cand=None (unbounded candidate budget): the figure's claim is
    about the span -> latency relation, so every scan must materialize
    its whole window rather than cut at the bench default's budget."""
    t, w, _ = _fresh(bench_params(max_range=16384, range_cand=None), seed=6,
                     key_space=1 << 20)
    rows = []
    rq = jax.jit(range_query, static_argnums=0)
    for span in (1 << 12, 1 << 14, 1 << 16, 1 << 18):
        lo = 1 << 10
        out = rq(t.p, t.state, jnp.int32(lo), jnp.int32(lo + span))
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for i in range(8):
            out = rq(t.p, t.state, jnp.int32(lo + i), jnp.int32(lo + i + span))
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 8
        hits = int(out[2])
        rows.append(row(f"fig06/span={span}", dt * 1e6,
                        f"hits={hits};us_per_hit={dt*1e6/max(hits,1):.2f}"))
    return rows


def fig07_data_size():
    """Fig 7: throughput vs dataset size (expect <= logarithmic slowdown)."""
    rows = []
    tputs = []
    for n in (20_000, 60_000, 180_000):
        t, w, ins_s = _fresh(bench_params(max_levels=4), n=n, seed=7)
        lk_s = time_lookups(t, w.lookups[:4096], batch=1024)
        tputs.append(n / ins_s)
        rows.append(row(f"fig07/n={n}", ins_s / n * 1e6,
                        f"ins_per_s={n/ins_s:.0f};lk_per_s={4096/lk_s:.0f}"))
    # slowdown factor across 9x data growth (paper: ~log)
    rows.append(row("fig07/slowdown_9x", 0.0,
                    f"tput_ratio={tputs[0]/max(tputs[-1],1e-9):.2f}"))
    return rows


def fig08_workload_mix():
    """Fig 8: completion time vs update:lookup ratio, R=4 vs R=32."""
    rows = []
    n = 40_000
    for r in (4, 32):
        for lf in (0.1, 0.5, 0.9):
            w = make_kv_workload("uniform", n, seed=8, lookup_frac=lf)
            t = SLSM(bench_params(R=r))
            t0 = time.perf_counter()
            t.insert(w.keys, w.vals)
            _ = time_lookups(t, w.lookups, batch=1024)
            total = time.perf_counter() - t0
            n_ops = n + len(w.lookups) // 1024 * 1024
            rows.append(row(f"fig08/R={r}/lookup_frac={lf}",
                            total / n_ops * 1e6,
                            f"total_s={total:.2f}"))
    return rows


def fig09_insert_skew():
    """Fig 9: insert throughput vs key variance (update-in-place on dups
    defers merges — low variance = fast)."""
    rows = []
    for var in (1e2, 1e4, 1e6, 1e10):
        t, w, ins_s = _fresh(bench_params(), n=40_000, seed=9,
                             kind="normal", variance=var)
        rows.append(row(f"fig09/var={var:.0e}", ins_s / 40_000 * 1e6,
                        f"ins_per_s={40_000/ins_s:.0f};live={t.n_live}"))
    return rows


def fig10_lookup_skew():
    """Fig 10: clustered lookups are faster (fewer candidate pages)."""
    rows = []
    for var in (1e2, 1e5, 1e8, 1e12):
        t, w, _ = _fresh(bench_params(cand_factor=16), n=40_000, seed=10,
                         kind="cluster-lookup", lookup_variance=var)
        lk_s = time_lookups(t, w.lookups[:N_LOOKUP])
        rows.append(row(f"fig10/lookup_var={var:.0e}",
                        lk_s / N_LOOKUP * 1e6,
                        f"lookups_per_s={N_LOOKUP/lk_s:.0f}"))
    return rows


def fig11_concurrency():
    """Fig 11: parallel lookup scaling. TPU analogue of lookup threads =
    batched query lanes per dispatch; near-linear scaling in batch."""
    t, w, _ = _fresh(bench_params(), seed=11)
    rows = []
    base = None
    for batch in (256, 1024, 4096):
        lk_s = time_lookups(t, w.lookups[:8192], batch=batch)
        tput = 8192 / lk_s
        base = base or tput
        rows.append(row(f"fig11/batch={batch}", lk_s / 8192 * 1e6,
                        f"lookups_per_s={tput:.0f};scale={tput/base:.2f}"))
    return rows


def fig12_merge_overlap():
    """Fig 12: merge threading cuts tail latency. JAX analogue: the merge
    is dispatched asynchronously; the host can issue lookups against the
    snapshot without blocking. We compare max per-chunk insert latency
    with eager blocking after each merge vs async overlap."""
    import repro.core.slsm as S

    def run(block_merges: bool):
        t = SLSM(bench_params(R=4, Rn=512, D=4, mu=64, max_levels=3))
        w = make_kv_workload("uniform", 60_000, seed=12)
        worst = 0.0
        for off in range(0, 60_000, 512):
            t0 = time.perf_counter()
            t.insert(w.keys[off:off + 512], w.vals[off:off + 512])
            if block_merges:
                jax.block_until_ready(t.state)  # wait for any merge now
            worst = max(worst, time.perf_counter() - t0)
        jax.block_until_ready(t.state)
        return worst

    worst_block = run(True)
    worst_async = run(False)
    return [
        row("fig12/blocking", worst_block * 1e6, "max_insert_chunk_latency"),
        row("fig12/async_merge", worst_async * 1e6,
            f"tail_reduction={worst_block/max(worst_async,1e-9):.2f}x"),
    ]


def kernels_bench():
    """Kernel-level: HeapMerge tournament vs XLA sort-merge; Bloom probe."""
    from repro.core import runs as RU
    from repro.core.params import KEY_EMPTY
    from repro.kernels.heap_merge import heap_merge_op

    rng = np.random.default_rng(0)
    k, cap = 4, 8192
    ks, vs, ss = [], [], []
    for i in range(k):
        kk = np.sort(rng.choice(1 << 22, cap, replace=False)).astype(np.int32)
        ks.append(kk)
        vs.append(rng.integers(0, 99, cap).astype(np.int32))
        ss.append((np.arange(cap) + i * cap).astype(np.int32))
    K, V, S = (jnp.asarray(np.stack(x)) for x in (ks, vs, ss))

    sort_fn = jax.jit(lambda a, b, c: RU.merge_runs(a, b, c, False))
    out = sort_fn(K, V, S); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(10):
        out = sort_fn(K, V, S)
    jax.block_until_ready(out)
    t_sort = (time.perf_counter() - t0) / 10

    out = heap_merge_op(K, V, S, False); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(10):
        out = heap_merge_op(K, V, S, False)
    jax.block_until_ready(out)
    t_rank = (time.perf_counter() - t0) / 10

    return [
        row("kernels/merge_sort_based", t_sort * 1e6,
            f"elems={k*cap};Melem_per_s={k*cap/t_sort/1e6:.1f}"),
        row("kernels/merge_rankpath_pallas", t_rank * 1e6,
            f"elems={k*cap};Melem_per_s={k*cap/t_rank/1e6:.1f}"),
    ]


def backends_bench():
    """Engine-level backend comparison: the same insert/lookup stream with
    every hot primitive (Bloom probe, fence lookup, k-way merge) dispatched
    to the jnp reference vs the Pallas kernels (SLSMParams.backend).

    Off-TPU the kernels run in interpret mode, so this measures the
    dispatch path's correctness-cost there — the TPU run of the same entry
    is the real speed comparison."""
    rows = []
    n, n_lk = 6_000, 1_024
    for backend in ("jnp", "pallas"):
        t, w, ins_s = _fresh(bench_params(R=4, Rn=256, D=4, mu=64,
                                          backend=backend),
                             n=n, seed=42)
        lk_s = time_lookups(t, w.lookups[:n_lk], batch=512, sparse=False)
        rows.append(row(f"backends/{backend}/insert", ins_s / n * 1e6,
                        f"ins_per_s={n/ins_s:.0f}"))
        rows.append(row(f"backends/{backend}/lookup", lk_s / n_lk * 1e6,
                        f"lk_per_s={n_lk/lk_s:.0f};levels={t.n_levels}"))
    return rows


ALL_FIGS = [fig02_r_sweep, fig03_buffer_grid, fig04_disk_grid, fig05_bloom,
            fig06_range, fig07_data_size, fig08_workload_mix,
            fig09_insert_skew, fig10_lookup_skew, fig11_concurrency,
            fig12_merge_overlap, kernels_bench, backends_bench]
