#!/usr/bin/env python
"""Replication failover smoke: SIGKILL a live replicating leader
process mid-stream, promote the follower, prove answer-exact failover
(CI's `replication-smoke` job, DESIGN.md §14).

Parent/child harness in one file (the replication twin of
`tools/recovery_smoke.py`):

  * child (``--child``): a durable continuous-batching leader server
    (`repro.serve.Server(role="leader")` over `SLSM` + fsync WAL) whose
    engine carries a `repro.engine.replication.Leader`. It bootstraps
    the follower directory, dials the parent's socket listener, and
    serves an unbounded deterministic op stream — every pump seam ships
    the window's durable frames. It never exits on its own.
  * parent (default): listens on a localhost socket, accepts the
    child's connection, opens a `Follower` over the bootstrapped
    directory, and applies the live stream. Once enough records have
    applied it SIGKILLs the child mid-stream — no shutdown hook, the
    honest leader death — pumps the torn remainder, and `promote()`s.
    The promoted engine must answer bitwise like a fresh non-durable
    engine fed the *decoded durable WRITE prefix of the follower's own
    WAL* (the acked prefix — exactly what clients were told happened),
    and must immediately accept writes at the bumped epoch.

Exit 0 == failover is answer-exact. Any mismatch, a follower that
applied records its WAL doesn't hold, or a promoted engine that
rejects writes is a hard failure.

``--partition`` runs the self-healing twin (CI's `failover-smoke` job,
DESIGN.md §15): the leader child is *partitioned, not killed* —
SIGSTOP freezes it mid-stream, so its lease heartbeats stop while the
process lives. The parent's follower (``auto_promote=True``, real
clock) must promote itself automatically within the lease bound. Then
SIGCONT: the revived old leader keeps serving until the promoted
successor's bumped-epoch fence ack reaches it, fences itself (writes
raise, ship inert), re-bootstraps from the new leader as a follower,
and must serve reads bitwise-equal to the new leader. Exit 0 == all of
automatic promotion, fencing, and the rejoined replica's answers hold.

Usage:
    python tools/replication_smoke.py [--kill-after-records N]
    python tools/replication_smoke.py --partition [--lease-s S]
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core.params import SLSMParams  # noqa: E402
from repro.engine import replication as R  # noqa: E402
from repro.engine import wal as WAL  # noqa: E402
from repro.engine.engine import SLSM  # noqa: E402

KEY_SPACE = 300
OP_SIZE = 48
BOOT_PREFIX = 6       # ops the child absorbs before bootstrapping


def params() -> SLSMParams:
    """Tiny geometry (as in tests/replication): a few hundred ops cover
    seals, flushes, and spills, so the kill lands on a busy tree."""
    return SLSMParams(R=2, Rn=32, eps=1e-2, D=2, m=1.0, mu=16, max_levels=3,
                      max_range=2048, merge_budget=1, backend="jnp")


def op(i: int):
    """The i-th op of the unbounded deterministic stream (same math in
    child and parent); every 4th op deletes. One op == one WAL WRITE
    record."""
    rng = np.random.default_rng(200_000 + i)
    keys = rng.integers(0, KEY_SPACE, OP_SIZE).astype(np.int32)
    if i % 4 == 3:
        return ("delete", keys[:OP_SIZE // 3], None)
    vals = rng.integers(0, 1 << 20, OP_SIZE).astype(np.int32)
    return ("insert", keys, vals)


def probe(drv):
    """Full-keyspace stride lookup + range sweep, as plain numpy."""
    qs = np.arange(0, KEY_SPACE, dtype=np.int32)
    v, f = drv.lookup_many(qs)
    ranges = [drv.range(lo, hi)
              for lo, hi in ((0, KEY_SPACE), (17, 80), (100, 250))]
    return (np.asarray(v), np.asarray(f),
            [(np.asarray(k), np.asarray(vv)) for k, vv in ranges])


def run_child(leader_dir: str, fol_dir: str, port: int) -> None:
    """Bootstrap the follower dir, dial the parent, then serve (and
    ship) the deterministic stream forever (until killed)."""
    from repro.serve.server import Server

    dur = WAL.Durability(leader_dir, fsync=True,
                         snapshot_every_bytes=1 << 30)
    drv = SLSM(params(), durability=dur)
    leader = R.Leader(drv)
    srv = Server(drv, role="leader")
    i = 0
    for i in range(BOOT_PREFIX):
        kind, keys, vals = op(i)
        if kind == "insert":
            srv.submit("smoke", "insert", keys, vals)
        else:
            srv.submit("smoke", "delete", keys)
        srv.pump(force=True)
    cursor = leader.bootstrap(fol_dir)
    leader.attach(R.connect("127.0.0.1", port), cursor)
    i = BOOT_PREFIX
    while True:
        kind, keys, vals = op(i)
        if kind == "insert":
            srv.submit("smoke", "insert", keys, vals)
        else:
            srv.submit("smoke", "delete", keys)
        srv.pump(force=True)       # serve + group-commit + ship
        if i % 8 == 7:
            srv.pump()             # idle gap: drain acks
        i += 1


def run_child_partition(leader_dir: str, fol_dir: str, rejoin_dir: str,
                        info_path: str, result_path: str, port: int,
                        lease_s: float) -> int:
    """The partition-mode leader child: serve + heartbeat until the
    successor's fence deposes us, then rejoin as a follower of the new
    leader and prove our reads match its bitwise."""
    from repro.serve.server import Server

    dur = WAL.Durability(leader_dir, fsync=True,
                         snapshot_every_bytes=1 << 30)
    drv = SLSM(params(), durability=dur)
    leader = R.Leader(drv, lease_s=lease_s)
    srv = Server(drv, role="leader")
    for i in range(BOOT_PREFIX):
        kind, keys, vals = op(i)
        if kind == "insert":
            srv.submit("smoke", "insert", keys, vals)
        else:
            srv.submit("smoke", "delete", keys)
        srv.pump(force=True)
    cursor = leader.bootstrap(fol_dir)
    leader.attach(R.connect("127.0.0.1", port), cursor)
    i = BOOT_PREFIX
    while True:
        kind, keys, vals = op(i)
        try:
            if kind == "insert":
                srv.submit("smoke", "insert", keys, vals)
            else:
                srv.submit("smoke", "delete", keys)
            srv.pump(force=True)       # serve + group-commit + ship
        except (ValueError, RuntimeError) as e:
            stop_reason = e
            break                      # fenced: the successor deposed us
        srv.pump()                     # idle: acks, heartbeat cadence
        i += 1
        time.sleep(0.002)
    if not (drv.fenced and leader.deposed and srv.stats()["role"]
            == "follower"):
        print(f"[child] stopped wrong: {stop_reason!r} fenced={drv.fenced} "
              f"deposed={leader.deposed} role={srv.stats()['role']}",
              file=sys.stderr, flush=True)
        return 3                       # writes stopped for a wrong reason
    # rejoin: the new leader bootstraps rejoin_dir and posts its
    # listener + target watermark in the info file
    deadline = time.time() + 300
    while not os.path.exists(info_path):
        if time.time() > deadline:
            return 4
        time.sleep(0.05)
    with open(info_path) as fh:
        cfg = json.load(fh)
    fol = R.Follower(rejoin_dir, R.connect("127.0.0.1", cfg["port"]))
    while fol.last_seqno < cfg["target"]:
        if time.time() > deadline:
            return 5
        fol.pump()
        time.sleep(0.005)
    gv, gf, gr = probe(fol.drv)
    arrays = {"v": gv, "f": gf}
    for j, (rk, rv) in enumerate(gr):
        arrays[f"r{j}k"], arrays[f"r{j}v"] = rk, rv
    np.savez(result_path + ".tmp.npz", **arrays)
    os.replace(result_path + ".tmp.npz", result_path)
    return 0


def run_parent_partition(d: str, kill_after_records: int,
                         lease_s: float) -> int:
    ldir = os.path.join(d, "leader")
    fdir = os.path.join(d, "follower")
    rdir = os.path.join(d, "rejoin")
    info = os.path.join(d, "rejoin.json")
    result = os.path.join(d, "probe.npz")
    os.makedirs(ldir, exist_ok=True)
    lis = R.SocketListener()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    child = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "--child",
         "--partition", "--dir", ldir, "--fol-dir", fdir,
         "--rejoin-dir", rdir, "--rejoin-info", info, "--result", result,
         "--port", str(lis.port), "--lease-s", str(lease_s)], env=env)
    try:
        end = lis.accept(timeout=300)
        lis.close()
        fol = R.Follower(fdir, end, auto_promote=True)
        deadline = time.time() + 300
        while time.time() < deadline:
            fol.pump()
            if fol.counters["applied_records"] >= kill_after_records:
                break
            if child.poll() is not None:
                print("FAIL: child exited before the partition "
                      f"(rc={child.returncode})")
                return 1
            time.sleep(0.01)
        else:
            print("FAIL: follower never applied enough of the stream")
            return 1
        if fol.lease_deadline is None:
            print("FAIL: lease never armed (no heartbeat reached the "
                  "follower)")
            return 1

        # the partition: freeze (NOT kill) the live leader mid-stream
        os.kill(child.pid, signal.SIGSTOP)
        t0 = time.time()
        bound_s = 2.0 * lease_s + 1.0   # lease + detection slack
        while fol.new_leader is None and time.time() - t0 < bound_s:
            fol.pump()
            time.sleep(0.005)
        if fol.new_leader is None:
            print(f"FAIL: no automatic promotion within {bound_s:.1f}s "
                  f"(lease_s={lease_s})")
            return 1
        auto_ms = (time.time() - t0) * 1e3
        new_lead = fol.new_leader
        if fol.counters["lease_expiries"] < 1:
            print("FAIL: promotion without an observed lease expiry")
            return 1

        # the stream continues on the new leader (post-failover writes)
        for j in range(4):
            keys = np.arange(j * 7, j * 7 + 5, dtype=np.int32)
            new_lead.drv.insert(keys, keys * 11 + 1)

        # heal the partition: the old leader must fence itself on the
        # first bumped-epoch fence ack, then rejoin through a fresh
        # bootstrap of the new leader
        os.kill(child.pid, signal.SIGCONT)
        cursor = new_lead.bootstrap(rdir)
        target = int(new_lead.drv.durability.writer.last_seqno)
        lis2 = R.SocketListener()
        with open(info + ".tmp", "w") as fh:
            json.dump({"port": lis2.port, "target": target}, fh)
        os.replace(info + ".tmp", info)
        end2 = None
        while end2 is None and time.time() < deadline:
            new_lead.pump()             # fence acks depose the child
            try:
                end2 = lis2.accept(timeout=0.2)
            except (R.TransportError, OSError):
                if child.poll() is not None:
                    print("FAIL: child exited before rejoining "
                          f"(rc={child.returncode})")
                    return 1
        lis2.close()
        if end2 is None:
            print("FAIL: deposed leader never dialed back in")
            return 1
        h = new_lead.attach(end2, cursor)
        while child.poll() is None and time.time() < deadline:
            new_lead.pump()
            time.sleep(0.005)
        if child.returncode != 0:
            print(f"FAIL: rejoined child exited rc={child.returncode} "
                  "(3=not fenced, 4=no rejoin info, 5=never converged)")
            return 1
        del h
    finally:
        if child.poll() is None:
            child.kill()
            child.wait()

    got = np.load(result)
    gv, gf, gr = probe(new_lead.drv)
    if not (np.array_equal(got["f"], gf) and np.array_equal(got["v"], gv)):
        print("FAIL: rejoined old leader's lookups diverge from the "
              "new leader")
        return 1
    for j, (rk, rv) in enumerate(gr):
        if not (np.array_equal(got[f"r{j}k"], rk)
                and np.array_equal(got[f"r{j}v"], rv)):
            print("FAIL: rejoined old leader's range scans diverge")
            return 1
    st = new_lead.stats()
    print(f"OK: automatic promotion in {auto_ms:.0f}ms "
          f"(lease {lease_s:.1f}s, bound {bound_s:.1f}s), "
          f"{st['fence_acks']} fence ack(s) deposed the live leader, "
          "rejoined replica reads bitwise-equal at epoch "
          f"{int(new_lead.drv.durability.writer.epoch)}")
    return 0


def run_parent(leader_dir: str, fol_dir: str,
               kill_after_records: int) -> int:
    lis = R.SocketListener()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    child = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "--child",
         "--dir", leader_dir, "--fol-dir", fol_dir,
         "--port", str(lis.port)], env=env)
    try:
        end = lis.accept(timeout=300)
        lis.close()
        fol = R.Follower(fol_dir, end)
        deadline = time.time() + 300
        while time.time() < deadline:
            fol.pump()
            if fol.counters["applied_records"] >= kill_after_records:
                break
            if child.poll() is not None:
                print("FAIL: child exited before the kill "
                      f"(rc={child.returncode})")
                return 1
            time.sleep(0.01)
        else:
            print("FAIL: follower never applied enough of the stream")
            return 1
        child.send_signal(signal.SIGKILL)   # leader dies mid-stream
        child.wait()
    finally:
        if child.poll() is None:
            child.kill()
            child.wait()

    fol.pump()                      # the torn remainder must not raise
    st = fol.stats()
    print(f"killed leader at follower watermark {st['applied_seqno']} "
          f"({st['applied_records']} applied, "
          f"{st['duplicates']} dups, {st['rejected']} rejected)")

    prom = fol.promote()
    if prom.durability.writer.epoch < 1:
        print("FAIL: promote did not bump the WAL epoch")
        return 1

    # the oracle: a fresh non-durable engine fed the decoded durable
    # WRITE prefix of the follower's own WAL, in log order
    records, _good = WAL.read_wal(os.path.join(fol_dir, "wal.log"))
    writes = [r for r in records if r.kind in WAL.WRITE_KINDS]
    if not writes:
        print("FAIL: nothing durable reached the follower before the kill")
        return 1
    if int(prom.durability.writer.last_seqno) != int(records[-1].seqno):
        print("FAIL: follower applied records its WAL does not hold")
        return 1
    n_neg = 0
    oracle = SLSM(params())
    for rec in writes:
        k, v, w = WAL.decode_write(rec.payload, rec.kind)
        is_del = w <= 0
        n_neg += int(is_del.sum())
        start = 0
        for i in range(1, len(k) + 1):
            if i == len(k) or is_del[i] != is_del[start]:
                if is_del[start]:
                    oracle.delete(k[start:i])
                else:
                    oracle.insert(k[start:i], v[start:i])
                start = i
    if n_neg == 0:
        print("FAIL: the durable prefix carries no negative-weight "
              "records — the kill landed before any delete shipped")
        return 1

    gv, gf, gr = probe(prom)
    wv, wf, wr = probe(oracle)
    if not (np.array_equal(gf, wf) and np.array_equal(gv, wv)):
        print("FAIL: promoted lookups diverge from the acked-prefix oracle")
        return 1
    for (gk, gvv), (wk, wvv) in zip(gr, wr):
        if not (np.array_equal(gk, wk) and np.array_equal(gvv, wvv)):
            print("FAIL: promoted range scans diverge from the oracle")
            return 1

    # the promoted node is a writable leader at the bumped epoch
    keys = np.array([1, 3, 5], np.int32)
    prom.insert(keys, keys * 7)
    v, f = prom.lookup_many(keys)
    if not (np.asarray(f).all()
            and np.array_equal(np.asarray(v), keys * 7)):
        print("FAIL: promoted engine rejected or lost a post-failover write")
        return 1
    print(f"OK: failover is answer-exact at write-chunk boundary "
          f"{len(writes)} ({n_neg} negative-weight lanes, epoch "
          f"{prom.durability.writer.epoch}, post-failover writes land)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--partition", action="store_true",
                    help="self-healing mode: SIGSTOP (not SIGKILL) the "
                         "leader; assert automatic lease promotion, "
                         "fencing, and bitwise rejoin")
    ap.add_argument("--dir", default=None)
    ap.add_argument("--fol-dir", default=None)
    ap.add_argument("--rejoin-dir", default=None)
    ap.add_argument("--rejoin-info", default=None)
    ap.add_argument("--result", default=None)
    ap.add_argument("--port", type=int, default=None)
    ap.add_argument("--lease-s", type=float, default=2.0,
                    help="leader lease duration in partition mode")
    ap.add_argument("--kill-after-records", type=int, default=40,
                    help="applied follower records that trigger the "
                         "kill (or the partition)")
    args = ap.parse_args()
    if args.child:
        if args.partition:
            return run_child_partition(args.dir, args.fol_dir,
                                       args.rejoin_dir, args.rejoin_info,
                                       args.result, args.port,
                                       args.lease_s)
        run_child(args.dir, args.fol_dir, args.port)
        return 0
    with tempfile.TemporaryDirectory(prefix="replication_smoke_") as d:
        if args.partition:
            return run_parent_partition(d, args.kill_after_records,
                                        args.lease_s)
        ldir = os.path.join(d, "leader")
        fdir = os.path.join(d, "follower")
        os.makedirs(ldir, exist_ok=True)
        return run_parent(ldir, fdir, args.kill_after_records)


if __name__ == "__main__":
    sys.exit(main())
