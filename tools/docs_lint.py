"""Docs lint: public-API docstrings + markdown link integrity.

Two checks, both run by the CI docs job and by tests/test_docs.py:

  1. every *public* module / class / function / method under
     ``repro.engine``, ``repro.bench``, and ``repro.serve`` carries a
     docstring — the paper-ref docstring convention those packages
     follow is only useful if it has no holes;
  2. every relative markdown link in README.md, DESIGN.md, and
     docs/*.md resolves: the target file exists, and a ``#fragment``
     matches a real heading (GitHub anchor slugs) in the target.

Usage:
    PYTHONPATH=src python tools/docs_lint.py           # lint repo root
    PYTHONPATH=src python tools/docs_lint.py --root .  # explicit root

Exit status 0 = clean; 1 = problems (each printed one per line).
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import pkgutil
import re
import sys
from pathlib import Path

LINT_PACKAGES = ("repro.engine", "repro.bench", "repro.serve")
DOC_FILES = ("README.md", "DESIGN.md")
DOC_GLOBS = ("docs/*.md",)

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


# -- docstring lint ---------------------------------------------------------

def _public_members(mod):
    """(kind, qualname, obj) for the module's own public API."""
    for name, obj in vars(mod).items():
        if name.startswith("_"):
            continue
        if getattr(obj, "__module__", None) != mod.__name__:
            continue   # re-exports are the defining module's problem
        if inspect.isclass(obj):
            yield "class", f"{mod.__name__}.{name}", obj
            for mname, meth in vars(obj).items():
                if mname.startswith("_"):
                    continue
                fn = getattr(meth, "__func__", meth)
                if isinstance(meth, property):
                    yield ("method", f"{mod.__name__}.{name}.{mname}",
                           meth.fget)
                elif inspect.isfunction(fn):
                    yield "method", f"{mod.__name__}.{name}.{mname}", fn
        elif inspect.isfunction(obj):
            yield "function", f"{mod.__name__}.{name}", obj


def lint_docstrings(packages=LINT_PACKAGES):
    """Names lacking docstrings across `packages` (empty list = clean)."""
    problems = []
    for pkg_name in packages:
        pkg = importlib.import_module(pkg_name)
        mod_names = [pkg_name] + [
            f"{pkg_name}.{m.name}"
            for m in pkgutil.iter_modules(pkg.__path__)]
        for mod_name in mod_names:
            mod = importlib.import_module(mod_name)
            if not (mod.__doc__ or "").strip():
                problems.append(f"{mod_name}: module docstring missing")
            for kind, qual, obj in _public_members(mod):
                doc = inspect.getdoc(obj)
                if not (doc or "").strip():
                    problems.append(f"{qual}: {kind} docstring missing")
    return problems


# -- markdown link check ----------------------------------------------------

def _slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def _anchors(md_path: Path) -> set:
    return {_slugify(h) for h in _HEADING_RE.findall(md_path.read_text())}


def lint_links(root: Path):
    """Broken relative links/anchors in the repo's markdown docs."""
    problems = []
    files = [root / f for f in DOC_FILES]
    for pattern in DOC_GLOBS:
        files.extend(sorted(root.glob(pattern)))
    for md in files:
        if not md.exists():
            problems.append(f"{md.relative_to(root)}: file missing")
            continue
        for target in _LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, fragment = target.partition("#")
            dest = (md.parent / path_part).resolve() if path_part else md
            where = f"{md.relative_to(root)} -> {target}"
            if not dest.exists():
                problems.append(f"{where}: target missing")
                continue
            if fragment and dest.suffix == ".md":
                if fragment not in _anchors(dest):
                    problems.append(f"{where}: anchor #{fragment} not found")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=".", help="repo root (default: .)")
    args = ap.parse_args(argv)
    problems = lint_docstrings() + lint_links(Path(args.root).resolve())
    for p in problems:
        print(p)
    if problems:
        print(f"# {len(problems)} docs problem(s)", file=sys.stderr)
        return 1
    print("# docs lint clean", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
