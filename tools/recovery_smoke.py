#!/usr/bin/env python
"""Crash-recovery smoke: SIGKILL a live serving process, restore, prove
oracle-exact answers (CI's `recovery-smoke` job, DESIGN.md §12).

Parent/child harness in one file:

  * child (``--child``): runs a durable continuous-batching server
    (`repro.serve.Server` over `SLSM` + `repro.engine.wal.Durability`,
    fsync on) against an unbounded deterministic op stream — one
    submitted request + one forced pump per op, a plain idle pump every
    few windows so the maintenance governor takes its snapshot trigger.
    It never exits on its own.
  * parent (default): spawns the child, waits until the WAL has real
    traffic, then SIGKILLs it mid-window — no shutdown hook, no flush,
    the honest crash. It then `SLSM.restore()`s the durability dir and
    replays the *decoded durable WRITE records* through a fresh
    non-durable engine's public insert/delete API (the serving tape
    re-chunks requests, so the WAL's record stream — not the submitted
    op stream — is the durable truth), asserting bitwise-equal
    full-keyspace lookups and range sweeps. The restore stall must be
    reported as first-class telemetry (``restore_us`` in the engine
    stats, surfaced through ``Server.stats()["engine"]``).

Exit 0 == recovery is crash-exact. Any mismatch, missing telemetry, or
unreadable-but-nonempty WAL is a hard failure.

Usage:
    python tools/recovery_smoke.py [--kill-after-bytes N] [--dir DIR]
"""
from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core.params import SLSMParams  # noqa: E402
from repro.engine import wal as WAL  # noqa: E402
from repro.engine.engine import SLSM  # noqa: E402

# the stream runs unbounded, so the live key set must stay well under
# the tiny tree's deepest-level capacity (512 at this geometry):
# newest-wins dedup bounds live elements by the keyspace + in-flight
# negative-weight delete records
KEY_SPACE = 300
OP_SIZE = 48


def params() -> SLSMParams:
    """Tiny geometry (as in tests/durability): a few hundred ops cover
    seals, flushes, and spills, so the kill lands on a busy tree."""
    return SLSMParams(R=2, Rn=32, eps=1e-2, D=2, m=1.0, mu=16, max_levels=3,
                      max_range=2048, merge_budget=1, backend="jnp")


def op(i: int):
    """The i-th op of the unbounded deterministic stream (same math in
    child and parent — the oracle replays exactly what the child fed).
    Every 4th op is a delete batch (weight -1 WAL records); one op ==
    one driver call == one WAL WRITE record."""
    rng = np.random.default_rng(100_000 + i)
    keys = rng.integers(0, KEY_SPACE, OP_SIZE).astype(np.int32)
    if i % 4 == 3:
        return ("delete", keys[:OP_SIZE // 3], None)
    vals = rng.integers(0, 1 << 20, OP_SIZE).astype(np.int32)
    return ("insert", keys, vals)


def probe(drv):
    """The oracle-comparison read set (full-keyspace stride lookup +
    range sweep), as plain numpy."""
    qs = np.arange(0, KEY_SPACE, dtype=np.int32)
    v, f = drv.lookup_many(qs)
    ranges = [drv.range(lo, hi)
              for lo, hi in ((0, KEY_SPACE), (17, 80), (100, 250))]
    return (np.asarray(v), np.asarray(f),
            [(np.asarray(k), np.asarray(vv)) for k, vv in ranges])


def run_child(durdir: str) -> None:
    """Serve the deterministic stream forever (until killed)."""
    from repro.serve.server import Server

    dur = WAL.Durability(durdir, fsync=True, snapshot_every_bytes=16_384)
    drv = SLSM(params(), durability=dur)
    srv = Server(drv)
    i = 0
    while True:
        kind, keys, vals = op(i)
        if kind == "insert":
            srv.submit("smoke", "insert", keys, vals)
        else:
            srv.submit("smoke", "delete", keys)
        srv.pump(force=True)       # one served + group-committed window
        if i % 8 == 7:
            srv.pump()             # idle gap: the governor may snapshot
        i += 1


def run_parent(durdir: str, kill_after_bytes: int) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    child = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "--child",
         "--dir", durdir], env=env)
    wal_path = os.path.join(durdir, "wal.log")
    try:
        deadline = time.time() + 300
        while time.time() < deadline:
            if (os.path.exists(wal_path)
                    and os.path.getsize(wal_path) >= kill_after_bytes):
                break
            if child.poll() is not None:
                print("FAIL: child exited before the kill "
                      f"(rc={child.returncode})")
                return 1
            time.sleep(0.05)
        else:
            print("FAIL: child never produced enough WAL traffic")
            return 1
        # land mid-window, not at a tidy boundary
        time.sleep(0.15)
        child.send_signal(signal.SIGKILL)
        child.wait()
    finally:
        if child.poll() is None:
            child.kill()
            child.wait()

    print(f"killed serving child at {os.path.getsize(wal_path)} WAL bytes")
    records, good = WAL.read_wal(wal_path)
    torn = os.path.getsize(wal_path) - good
    writes = [r for r in records if r.kind in WAL.WRITE_KINDS]
    snaps = WAL.list_snapshots(durdir)
    n_neg = 0
    print(f"durable prefix: {len(records)} records ({len(writes)} write "
          f"chunks), {torn} torn tail bytes, {len(snaps)} snapshot(s)")
    if not writes:
        print("FAIL: nothing durable reached the log before the kill")
        return 1

    t0 = time.perf_counter()
    restored = SLSM.restore(durdir)
    restore_ms = (time.perf_counter() - t0) * 1e3

    # the oracle: a fresh non-durable engine fed the decoded durable
    # chunks in log order through the public API (negative-weight lanes
    # are deletes — the engine's own on-log delete encoding)
    oracle = SLSM(params())
    for rec in writes:
        k, v, w = WAL.decode_write(rec.payload, rec.kind)
        is_del = w <= 0
        n_neg += int(is_del.sum())
        start = 0
        for i in range(1, len(k) + 1):       # runs of same op kind,
            if i == len(k) or is_del[i] != is_del[start]:   # order kept
                if is_del[start]:
                    oracle.delete(k[start:i])
                else:
                    oracle.insert(k[start:i], v[start:i])
                start = i
    if n_neg == 0:
        print("FAIL: the durable WAL prefix carries no negative-weight "
              "records — the kill landed before any delete was logged")
        return 1

    gv, gf, gr = probe(restored)
    wv, wf, wr = probe(oracle)
    if not (np.array_equal(gf, wf) and np.array_equal(gv, wv)):
        print("FAIL: restored lookups diverge from the oracle")
        return 1
    for (gk, gvv), (wk, wvv) in zip(gr, wr):
        if not (np.array_equal(gk, wk) and np.array_equal(gvv, wvv)):
            print("FAIL: restored range scans diverge from the oracle")
            return 1

    # the restore stall is first-class stats() telemetry
    from repro.serve.server import Server
    st = Server(restored).stats()
    reported_us = st["engine"].get("restore_us", 0)
    if not reported_us > 0:
        print("FAIL: restore_us missing from stats()")
        return 1
    print(f"OK: restore is oracle-exact at chunk boundary {len(writes)} "
          f"(replayed {restored.stats['replayed_records']} records, "
          f"{n_neg} negative-weight lanes, restore {restore_ms:.0f}ms, "
          f"stats restore_us={reported_us})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--dir", default=None)
    ap.add_argument("--kill-after-bytes", type=int, default=24_000,
                    help="WAL size that triggers the SIGKILL")
    args = ap.parse_args()
    if args.child:
        run_child(args.dir)
        return 0
    if args.dir is not None:
        os.makedirs(args.dir, exist_ok=True)
        return run_parent(args.dir, args.kill_after_bytes)
    with tempfile.TemporaryDirectory(prefix="recovery_smoke_") as d:
        return run_parent(d, args.kill_after_bytes)


if __name__ == "__main__":
    sys.exit(main())
