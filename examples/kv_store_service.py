"""End-to-end driver: serve a mixed key-value workload through the sLSM —
the paper's system under its intended load (Section 3.8's update:lookup
mixes), with batched requests, as a service loop.

Run:  PYTHONPATH=src python examples/kv_store_service.py [--requests 200000]
"""
import argparse
import time

import numpy as np

from repro.configs.slsm_paper import paper_params
from repro.core import SLSM
from repro.data import make_kv_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200_000)
    ap.add_argument("--lookup-frac", type=float, default=0.5)
    ap.add_argument("--batch", type=int, default=1024)
    args = ap.parse_args()

    params = paper_params(R=8, Rn=512, D=4, mu=64, max_levels=4,
                          max_range=4096)
    store = SLSM(params)
    w = make_kv_workload("uniform", args.requests, seed=0,
                         lookup_frac=args.lookup_frac)

    n_ins = len(w.keys)
    n_lkp = (len(w.lookups) // args.batch) * args.batch
    print(f"serving {n_ins:,} inserts + {n_lkp:,} lookups "
          f"(batch={args.batch}) ...")

    t0 = time.perf_counter()
    ins_done = lkp_done = 0
    lkp_off = 0
    # interleave: service loop alternates insert chunks and lookup batches
    for off in range(0, n_ins, args.batch * 4):
        store.insert(w.keys[off:off + args.batch * 4],
                     w.vals[off:off + args.batch * 4])
        ins_done += min(args.batch * 4, n_ins - off)
        if lkp_off + args.batch <= n_lkp:
            got, found = store.lookup(w.lookups[lkp_off:lkp_off + args.batch])
            lkp_done += args.batch
            lkp_off += args.batch
    # drain remaining lookups
    while lkp_off + args.batch <= n_lkp:
        store.lookup(w.lookups[lkp_off:lkp_off + args.batch])
        lkp_done += args.batch
        lkp_off += args.batch
    dt = time.perf_counter() - t0

    total = ins_done + lkp_done
    print(f"done in {dt:.2f}s: {total/dt:,.0f} ops/s "
          f"({ins_done/dt:,.0f} ins/s + {lkp_done/dt:,.0f} lkp/s)")
    print(f"store: {store.n_levels} levels, ~{store.n_live:,} entries")

    # verification pass
    sample = np.random.default_rng(1).choice(n_ins, 2000, replace=False)
    got, found = store.lookup(w.keys[sample])
    # duplicate keys in the stream: newest value wins — verify via dict
    truth = {}
    for k, v in zip(w.keys.tolist(), w.vals.tolist()):
        truth[k] = v
    expect = np.asarray([truth[k] for k in w.keys[sample].tolist()])
    assert found.all() and (got == expect).all()
    print("verification: 2,000 sampled keys all correct (newest-wins)")


if __name__ == "__main__":
    main()
