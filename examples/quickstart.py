"""Quickstart: the Skiplist-Based LSM Tree as a JAX key-value engine.

Run:  PYTHONPATH=src python examples/quickstart.py

Every section asserts its output, so this file doubles as a smoke test
(CI runs it on every push). The engine API lives in `repro.engine`;
`repro.core.slsm` is only a back-compat facade.
"""
import numpy as np

from repro.configs.slsm_paper import paper_params
from repro.engine import SLSM

# The paper's tuned baseline (Section 3), scaled to laptop size:
# mu=512 -> 64, R=50 -> 8, Rn=800 -> 256, D=20 -> 4, eps=1e-3 kept.
# Add backend="pallas" to dispatch the hot primitives to the TPU kernels.
params = paper_params(R=8, Rn=256, D=4, mu=64, max_levels=3)
store = SLSM(params)

rng = np.random.default_rng(0)
keys = rng.choice(2**24, size=50_000, replace=False).astype(np.int32)
vals = rng.integers(0, 2**20, size=keys.shape).astype(np.int32)

print(f"inserting {len(keys):,} keys "
      f"(R={params.R}, Rn={params.Rn}, eps={params.eps}, "
      f"D={params.D}, m={params.m}, mu={params.mu}) ...")
store.insert(keys, vals)
assert store.n_levels >= 1 and store.n_live >= len(keys) // 2
print(f"  -> {store.n_levels} disk levels, ~{store.n_live:,} stored entries, "
      f"merges: {dict(store.stats)}")

# batched point lookups: all 1,000 queries in ONE fused device dispatch
# (Bloom + min/max gated, fence-pointer page search — paper 2.3/2.4/2.7)
got, found = store.lookup_many(keys[:1000])
assert found.all() and (got == vals[:1000]).all()
print("lookup_many of 1,000 present keys: all found, all correct")

absent = (keys[:1000].astype(np.int64) + 2**25).astype(np.int32)
_, found = store.lookup_many(absent)
assert not found.any()  # Bloom FPs are filtered by the exact key match
print("lookup_many of 1,000 absent keys: none found")

# deletes are weight -1 records (paper 2.8's tombstones recast as Z-set
# retractions, DESIGN.md §13); merges annihilate matched insert/delete
# pairs without ever touching their payloads
store.delete(keys[:10])
_, found = store.lookup(keys[:10])
assert not found.any()
print("deleted 10 keys: lookups now miss")

# range query (paper 2.9): newest-wins, deleted keys elided, key-sorted
lo, hi = 2**20, 2**20 + 2**16
rk, rv = store.range(lo, hi)
expect = np.sort(keys[(keys >= lo) & (keys < hi)])
expect = expect[~np.isin(expect, keys[:10])]
assert (rk == expect).all()
kv = dict(zip(keys.tolist(), vals.tolist()))  # keys are drawn unique
assert all(kv[k] == v for k, v in zip(rk.tolist(), rv.tolist()))
print(f"range [{lo}, {hi}): {len(rk)} results, key-sorted, values verified")

# batched aggregates (DESIGN.md §13): count/sum over a key range ride
# the fence-pruned scan machinery without materializing the rows
cnt, total = store.count(lo, hi), store.sum(lo, hi)
assert cnt == len(rk)
assert total == int(rv.astype(np.int32).sum(dtype=np.int32))  # int32 wraparound
print(f"count/sum over [{lo}, {hi}): {cnt} rows, sum {total}")
print("quickstart OK")
