"""Quickstart: the Skiplist-Based LSM Tree as a JAX key-value engine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.slsm_paper import paper_params
from repro.core import SLSM

# The paper's tuned baseline (Section 3), scaled to laptop size:
# mu=512 -> 64, R=50 -> 8, Rn=800 -> 256, D=20 -> 4, eps=1e-3 kept.
params = paper_params(R=8, Rn=256, D=4, mu=64, max_levels=3)
store = SLSM(params)

rng = np.random.default_rng(0)
keys = rng.choice(2**24, size=50_000, replace=False).astype(np.int32)
vals = rng.integers(0, 2**20, size=keys.shape).astype(np.int32)

print(f"inserting {len(keys):,} keys "
      f"(R={params.R}, Rn={params.Rn}, eps={params.eps}, "
      f"D={params.D}, m={params.m}, mu={params.mu}) ...")
store.insert(keys, vals)
print(f"  -> {store.n_levels} disk levels, ~{store.n_live:,} stored entries")

# point lookups (batched, jit-compiled; Bloom + min/max gated)
got, found = store.lookup(keys[:1000])
assert found.all() and (got == vals[:1000]).all()
print("lookup of 1,000 present keys: all found, all correct")

absent = (keys[:1000].astype(np.int64) + 2**25).astype(np.int32)
_, found = store.lookup(absent)
print(f"lookup of 1,000 absent keys: {found.sum()} false positives")

# deletes are tombstones (paper 2.8)
store.delete(keys[:10])
_, found = store.lookup(keys[:10])
assert not found.any()
print("deleted 10 keys: lookups now miss")

# range query (paper 2.9): newest-wins, tombstones dropped, key-sorted
lo, hi = 2**20, 2**20 + 2**16
rk, rv = store.range(lo, hi)
expect = np.sort(keys[(keys >= lo) & (keys < hi)])
expect = expect[~np.isin(expect, keys[:10])]
assert (rk == expect).all()
print(f"range [{lo}, {hi}): {len(rk)} results, key-sorted, verified")
print("quickstart OK")
