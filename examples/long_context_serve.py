"""Serve a small LM with an sLSM-tiered KV cache — the paper's technique
applied to long-context decode.

Generates with (a) a dense cache and (b) the tiered cache (hot window +
summary-gated cold blocks), compares outputs, and prints tier statistics
— the token-level analogue of "Bloom filter skips the run".

Run:  PYTHONPATH=src python examples/long_context_serve.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import generate

cfg = get_config("deepseek-7b").smoke()          # tiny same-family model
params = lm.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

prompt_len, gen_steps = 96, 24
prompt = {"tokens": jnp.asarray(
    rng.integers(0, cfg.vocab, (2, prompt_len)), jnp.int32)}

print(f"model: {cfg.name} (smoke, {lm.param_count(params):,} params)")
print(f"prompt {prompt_len} tokens; generating {gen_steps} tokens\n")

dense_toks, _ = generate(cfg, params, prompt, steps=gen_steps, kind="dense")
lsm_toks, caches = generate(cfg, params, prompt, steps=gen_steps,
                            kind="lsm", max_len=prompt_len + gen_steps + 64)

agree = (np.asarray(dense_toks) == np.asarray(lsm_toks)).mean()
nb = int(caches["n_blocks"].reshape(-1)[0])
hot = int(caches["hot_len"].reshape(-1)[0])
total_ctx = prompt_len + gen_steps
attended = hot + min(cfg.lsm_topk, nb) * cfg.lsm_block

print(f"dense vs tiered token agreement: {agree:.1%}")
print(f"tiered cache: {nb} cold blocks x {cfg.lsm_block} tokens "
      f"+ {hot} hot tokens")
print(f"per-step attention reads: {attended}/{total_ctx} tokens "
      f"({attended/total_ctx:.0%}) — the rest are filtered out by block "
      f"summaries, exactly as Bloom misses skip runs")
print("\nAt 524,288-token context (long_500k cell) the same math reads "
      f"{cfg.lsm_hot_window + 16*1024:,}/524,288 tokens = 3.9% — "
      "what makes the cell lowerable for attention archs.")
