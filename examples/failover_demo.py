"""Self-healing replication demo: automatic failover, fencing, rejoin.

Run:  PYTHONPATH=src python examples/failover_demo.py

The deposed-leader story (DESIGN.md §15) end to end, on an injected
fake clock so every step is deterministic — no sleeps, no flake. Every
section asserts its output, so this file doubles as a smoke test (CI
runs it on every push).
"""
import tempfile
from pathlib import Path

import numpy as np

from repro.configs.slsm_paper import paper_params
from repro.engine import SLSM, Durability
from repro.engine import replication as R


class Clock:
    """Injectable monotonic time: the demo decides when leases expire."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def probe(drv):
    v, f = drv.lookup_many(np.arange(0, 400, dtype=np.int32))
    return np.asarray(v), np.asarray(f)


clock = Clock()
root = Path(tempfile.mkdtemp(prefix="failover_demo_"))
params = paper_params(R=4, Rn=64, D=2, mu=32, max_levels=3)

# -- a leased cluster: one leader, two auto-promote followers ----------
leader = R.Leader(
    SLSM(params, durability=Durability(root / "leader",
                                       snapshot_every_bytes=1 << 30)),
    lease_s=2.0, clock=clock)
rng = np.random.default_rng(7)
keys = rng.choice(400, size=300, replace=False).astype(np.int32)
leader.drv.insert(keys, keys * 3 + 1)

fols = [leader.add_follower(root / f"f{i}", auto_promote=True, clock=clock)
        for i in range(2)]
for _ in range(3):
    leader.pump()                       # ship + heartbeat (arms leases)
    for f in fols:
        f.pump()
leader.pump()                           # drain the final acks
assert all(f.lease_deadline is not None for f in fols)
print(f"cluster up: leader + {len(fols)} followers, leases armed "
      f"(lease_s={leader.lease_s}, acked seqno {fols[0].last_seqno})")

# -- the partition: heartbeats stop, the clock runs on -----------------
clock.t += 3.0 * leader.lease_s         # leader never pumps again...
for f in fols:
    f.pump()                            # ...so the lease detector fires
new_lead = fols[0].new_leader           # successor rule: best ack,
assert new_lead is not None             #   lowest id — exactly one wins
assert fols[1].new_leader is None and not fols[1].promoted
print(f"lease expired: follower 0 auto-promoted to epoch "
      f"{int(new_lead.drv.durability.writer.epoch)}; follower 1 stood down")

# -- the deposed leader doesn't know yet: it writes into the fence -----
leader.drv.insert(np.array([7, 11], np.int32), np.array([1, 2], np.int32))
leader.pump()                           # ships at the stale epoch
new_lead.pump()                         # the fence answers, epoch bumped
leader.pump()                           # ack(epoch > mine) -> depose
assert leader.deposed and leader.drv.fenced
try:
    leader.drv.insert(np.array([1], np.int32), np.array([1], np.int32))
    raise AssertionError("a fenced engine must reject writes")
except RuntimeError as e:
    assert "fenced" in str(e)
print("partition healed: old leader fenced itself on the bumped-epoch "
      "ack (writes raise, its unacked tail died with the old epoch)")

# -- rejoin: the deposed node re-enters as a bootstrapped follower -----
rejoined = new_lead.add_follower(root / "rejoined")
new_lead.drv.insert(np.arange(350, 380, dtype=np.int32),
                    np.arange(350, 380, dtype=np.int32) * 5)
R.converge(new_lead, rejoined)
(nv, nf), (rv, rf) = probe(new_lead.drv), probe(rejoined.drv)
assert np.array_equal(nv, rv) and np.array_equal(nf, rf)
print(f"rejoined: the deposed node serves reads bitwise-equal to the "
      f"new leader at seqno {rejoined.last_seqno}")

print("OK: automatic failover -> fence -> rejoin, all answer-exact")
