"""End-to-end LM training driver with atomic, hash-verified checkpoints.

Trains a small model (default ~10M params, CPU-feasible) for a few hundred
steps on the synthetic sharded TokenStream, checkpointing through the
`repro.checkpoint` facade — the same snapshot codec the sLSM durability
layer uses (repro.engine.wal, DESIGN.md §12).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
(Use --d-model 512 --layers 12 for a ~100M-param run on real hardware.)
"""
import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import TokenStream
from repro.models import lm
from repro.train import adamw_init, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/slsm_train_ckpt")
    args = ap.parse_args()

    cfg = replace(get_config("deepseek-7b"),
                  n_layers=args.layers, d_model=args.d_model,
                  n_heads=max(4, args.d_model // 32),
                  n_kv=max(2, args.d_model // 64),
                  d_ff=args.d_model * 4, vocab=8192, dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    print(f"training {cfg.name}-derived model: "
          f"{lm.param_count(params):,} params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, base_lr=1e-3, warmup=20,
                                      total_steps=args.steps))
    stream = iter(TokenStream(cfg.vocab, args.batch, args.seq, seed=0))
    mgr = CheckpointManager(args.ckpt_dir + "/full", keep_last=2)

    t0 = time.perf_counter()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, m = step_fn(params, opt, batch)
        if step % 20 == 0 or step == 1:
            dt = time.perf_counter() - t0
            tok_s = step * args.batch * args.seq / dt
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  {tok_s:,.0f} tok/s")
        if step % args.ckpt_every == 0:
            path = mgr.save(step, params, blocking=False)  # atomic full
            print(f"  ckpt @ {step}: async save -> {path}")
    mgr.wait()

    # restart drill: restore the latest full checkpoint, verify
    restored, at = mgr.restore(params)
    print(f"restore drill: loaded step {at}")
    diff = max(float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max())
               for a, b in zip(jax.tree_util.tree_leaves(params),
                               jax.tree_util.tree_leaves(restored)))
    print(f"restore drill: max |param diff| = {diff:.2e} (exact bitwise "
          f"restore expected: {'OK' if diff == 0 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
