"""Skiplist-reference hypothesis property (paper 2.2) — module degrades
to a skip when hypothesis is not installed."""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.skiplist_ref import SkipListRef


@settings(max_examples=15, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(seed=st.integers(0, 10**6),
       items=st.lists(st.tuples(st.integers(0, 500), st.integers(0, 99)),
                      min_size=1, max_size=120))
def test_skiplist_ref_is_an_ordered_map(seed, items):
    sl = SkipListRef(seed=seed)
    d = {}
    for k, v in items:
        sl.insert(k, v)
        d[k] = v
    assert sl.items() == sorted(d.items())
    for k, v in d.items():
        assert sl.lookup(k) == v
    assert sl.lookup(10**7) is None
