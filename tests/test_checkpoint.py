"""Fault tolerance: atomic checkpoints, corruption detection, LSM
incremental store, straggler policy, elastic mesh factoring."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, LSMCheckpointStore
from repro.distributed.elastic import StragglerMonitor, factor_devices


def _tree(rng, scale=1.0):
    return {"w": jnp.asarray(rng.normal(size=(64, 32)) * scale, jnp.float32),
            "b": jnp.asarray(rng.normal(size=(32,)) * scale, jnp.float32),
            "nested": {"m": jnp.asarray(rng.normal(size=(8, 8)),
                                        jnp.bfloat16)}}


def test_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = _tree(rng)
    mgr.save(10, tree)
    got, step = mgr.restore(tree)
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_last_and_latest(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3):
        mgr.save(s, _tree(rng, s))
    assert mgr.latest_step() == 3
    assert sorted(d for d in os.listdir(tmp_path)) == ["step_2", "step_3"]


def test_corruption_detected(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    path = mgr.save(5, tree)
    # flip bytes in one leaf
    leaf = os.path.join(path, "leaf_0.npy")
    with open(leaf, "r+b") as f:
        f.seek(200)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(IOError, match="corruption"):
        mgr.restore(tree)


def test_partial_save_invisible(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(1, tree)
    # simulate a crashed save: tmp dir left behind
    os.makedirs(os.path.join(tmp_path, "step_9.tmp-999"), exist_ok=True)
    assert mgr.latest_step() == 1
    # a new manager garbage-collects the debris
    CheckpointManager(str(tmp_path))
    assert not any(".tmp" in d for d in os.listdir(tmp_path))


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(7, tree, blocking=False)
    mgr.wait()
    got, step = mgr.restore(tree)
    assert step == 7


def test_lsm_incremental_store(tmp_path, rng):
    store = LSMCheckpointStore(str(tmp_path))
    # several 64 KiB chunks so deltas are visible; `b` sits in the tail chunk
    tree = {"w": jnp.asarray(rng.normal(size=(90000,)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    s1 = store.save_delta(tree)
    assert s1["written_chunks"] == s1["total_chunks"]  # first save: all
    # small update: one leaf changes -> few chunks rewritten
    tree2 = dict(tree, b=tree["b"] + 1)
    s2 = store.save_delta(tree2)
    assert 0 < s2["written_chunks"] < s2["total_chunks"]
    got = store.restore(tree)
    np.testing.assert_array_equal(np.asarray(got["b"]),
                                  np.asarray(tree2["b"]))
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree2["w"]))
    # unchanged save writes nothing (pure dedup)
    s3 = store.save_delta(tree2)
    assert s3["written_chunks"] == 0


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, min_samples=4)
    for _ in range(10):
        assert mon.record(0, 1.0) == "ok"
    assert mon.record(7, 5.0) == "skip"
    assert mon.record(7, 5.0) == "skip"
    assert mon.record(7, 5.0) == "quarantine"
    assert mon.healthy_hosts([0, 7]) == [0]


def test_elastic_mesh_factoring():
    assert factor_devices(512, 16) == (32, 16)
    assert factor_devices(256, 16) == (16, 16)
    assert factor_devices(8, 4) == (2, 4)
    assert factor_devices(6, 4) == (2, 3)      # TP degrades gracefully
    assert factor_devices(7, 4) == (7, 1)      # prime counts still work
    for n in (8, 48, 96, 384, 512):
        d, m = factor_devices(n)
        assert d * m == n
