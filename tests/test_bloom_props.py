"""Bloom filter hypothesis properties (paper 2.3) — module degrades to a
skip when hypothesis is not installed."""
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.bloom import bloom_build, bloom_probe


@settings(max_examples=30, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(keys=st.lists(st.integers(-2**31, 2**31 - 1), min_size=1,
                     max_size=200, unique=True),
       seed=st.integers(0, 1000))
def test_no_false_negatives(keys, seed):
    del seed
    ks = jnp.asarray(np.asarray(keys, np.int32))
    words = max(8, len(keys))
    filt = bloom_build(ks, jnp.ones(ks.shape, bool), words, k=7)
    assert bool(bloom_probe(filt, ks, k=7).all())
