"""Hypothesis property tests over arbitrary op interleavings (paper
semantics: newest-wins, tombstones, range, cascaded merges) — module
degrades to a skip when hypothesis is not installed. Deterministic
randomized-schedule equivalents live in test_engine.py."""
import pytest

pytest.importorskip("hypothesis")

import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import SLSM
from repro.core.oracle import DictOracle
from test_slsm_core import TINY, _check_lookups

ops = st.lists(
    st.tuples(st.sampled_from(["insert", "delete", "lookup", "range"]),
              st.integers(0, 60)),
    min_size=4, max_size=25)


@settings(max_examples=25, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(ops=ops, seed=st.integers(0, 2**31 - 1))
def test_property_vs_oracle(ops, seed):
    rng = np.random.default_rng(seed)
    t, o = SLSM(TINY), DictOracle()
    for op, span in ops:
        if op == "insert":
            ks = rng.integers(0, 80, size=max(1, span)).astype(np.int32)
            vs = rng.integers(-99, 99, size=ks.shape).astype(np.int32)
            try:
                t.insert(ks, vs)
            except RuntimeError:
                return  # declared capacity exhaustion (tiny config) — legal
            o.insert(ks, vs)
        elif op == "delete":
            ks = rng.integers(0, 80, size=max(1, span // 4 + 1)).astype(np.int32)
            try:
                t.delete(ks)
            except RuntimeError:
                return
            o.delete(ks)
        elif op == "lookup":
            qs = rng.integers(-5, 90, size=16).astype(np.int32)
            _check_lookups(t, o, qs)
        else:
            lo = int(rng.integers(-5, 60))
            hi = lo + span
            k1, v1 = t.range(lo, hi)
            k2, v2 = o.range(lo, hi)
            np.testing.assert_array_equal(k1, k2)
            np.testing.assert_array_equal(v1, v2)
    _check_lookups(t, o, np.arange(-5, 90, dtype=np.int32))
