"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bloom import bloom_build
from repro.core.params import KEY_EMPTY
from repro.core.runs import build_fences
from repro.kernels.bloom_probe import bloom_probe_op, bloom_probe_ref
from repro.kernels.fence_lookup import fence_lookup_op, fence_lookup_ref
from repro.kernels.lsm_attention import (decode_attention_op,
                                         decode_attention_ref)
from repro.kernels.lsm_attention.ops import lsm_decode_attention_op


@pytest.mark.parametrize("n,words,k,q", [
    (100, 64, 5, 64), (4000, 2048, 10, 1024), (64, 8, 2, 2048),
])
def test_bloom_probe_sweep(rng, n, words, k, q):
    keys = rng.choice(2**22, size=n, replace=False).astype(np.int32)
    filt = bloom_build(jnp.asarray(keys), jnp.ones(n, bool), words, k)
    n_present = min(n, q // 2)
    qs = jnp.asarray(np.concatenate([
        keys[:n_present], rng.integers(2**22, 2**23, q - n_present)
    ]).astype(np.int32))
    got = np.asarray(bloom_probe_op(filt, qs, k))
    want = np.asarray(bloom_probe_ref(filt, qs, k)).astype(bool)
    np.testing.assert_array_equal(got, want)
    assert got[:n_present].all()  # no false negatives


@pytest.mark.parametrize("cap,mu,nq", [(512, 64, 300), (2048, 256, 700),
                                       (1024, 1024, 128)])
def test_fence_lookup_sweep(rng, cap, mu, nq):
    n_valid = int(rng.integers(cap // 2, cap + 1))
    keys = np.full(cap, KEY_EMPTY, np.int32)
    keys[:n_valid] = np.sort(
        rng.choice(2**22, n_valid, replace=False)).astype(np.int32)
    fences = build_fences(jnp.asarray(keys), mu, cap // mu)
    qs = jnp.asarray(np.concatenate([
        keys[: nq // 2], rng.integers(0, 2**22, nq - nq // 2)
    ]).astype(np.int32))
    got = fence_lookup_op(qs, fences, jnp.asarray(keys), n_valid, mu)
    want = fence_lookup_ref(qs, fences, jnp.asarray(keys), n_valid, mu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,h,kv,dh,l,dtype", [
    (1, 4, 4, 64, 512, jnp.float32),
    (2, 8, 2, 64, 1024, jnp.float32),
    (2, 4, 1, 128, 512, jnp.bfloat16),
])
def test_decode_attention_sweep(rng, b, h, kv, dh, l, dtype):
    q = jnp.asarray(rng.normal(size=(b, h, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, l, kv, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, l, kv, dh)), dtype)
    lens = jnp.asarray(rng.integers(1, l + 1, b), jnp.int32)
    got = decode_attention_op(q, k, v, lens, dh ** -0.5)
    want = decode_attention_ref(q, k, v, lens, dh ** -0.5)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_lsm_attention_exact_when_all_blocks_selected(rng):
    b, h, kv, dh, l = 2, 8, 2, 64, 1024
    w, nb, mu = 512, 4, 128
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, l, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, l, kv, dh)), jnp.float32)
    blk_k = k[:, w:].reshape(b, nb, mu, kv, dh)
    blk_v = v[:, w:].reshape(b, nb, mu, kv, dh)
    got = lsm_decode_attention_op(
        q, k[:, :w], v[:, :w], jnp.full((b,), w, jnp.int32),
        blk_k, blk_v, blk_k.mean(axis=2), jnp.full((b,), nb, jnp.int32),
        nb, dh ** -0.5)
    want = decode_attention_ref(q, k, v, jnp.full((b,), l, jnp.int32),
                                dh ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_lsm_attention_selects_relevant_block(rng):
    """A block whose keys align with q must be chosen over noise blocks —
    the Bloom-style skip keeps what matters."""
    b, h, kv, dh = 1, 2, 1, 32
    w, nb, mu, topk = 64, 8, 32, 2
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32) * 3
    hot_k = jnp.asarray(rng.normal(size=(b, w, kv, dh)), jnp.float32) * 0.01
    hot_v = jnp.zeros((b, w, kv, dh), jnp.float32)
    blk_k = jnp.asarray(rng.normal(size=(b, nb, mu, kv, dh)), jnp.float32) * 0.01
    blk_v = jnp.zeros((b, nb, mu, kv, dh), jnp.float32)
    target = 5
    qmean = q.mean(axis=1)  # (b, dh)
    blk_k = blk_k.at[:, target].add(qmean[:, None, None, :])
    blk_v = blk_v.at[:, target].set(1.0)
    out = lsm_decode_attention_op(
        q, hot_k, hot_v, jnp.full((b,), w, jnp.int32),
        blk_k, blk_v, blk_k.mean(axis=2), jnp.full((b,), nb, jnp.int32),
        topk, dh ** -0.5)
    # most attention mass should land on the planted block (value 1.0)
    assert float(out.mean()) > 0.5
