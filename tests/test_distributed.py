"""Distributed runtime tests.

In-process: compression math, levels RNG, sharding-rule shapes.
Subprocess (8 forced host devices — kept out of this process so other
tests see 1 device): pjit train step on a (2,4) mesh, GPipe pipeline
vs sequential reference, elastic reshard.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compress import (compress_roundtrip, compression_ratio,
                                        ef_compress_grads, init_residual)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------- in-process ------------------------------------------------

def test_int8_roundtrip_error_small(rng):
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    y = compress_roundtrip(x)
    rel = float(jnp.abs(x - y).max() / jnp.abs(x).max())
    assert rel < 0.02  # 1/127 per-block quantization error


def test_error_feedback_invariant(rng):
    """sum(applied) + residual_T == sum(grads) exactly (fp32)."""
    grads = {"w": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}
    residual = init_residual(grads)
    total_applied = jnp.zeros((300,), jnp.float32)
    total_g = jnp.zeros((300,), jnp.float32)
    for i in range(5):
        g = {"w": grads["w"] * (i + 1) * 0.1}
        applied, residual = ef_compress_grads(g, residual)
        total_applied += applied["w"]
        total_g += g["w"]
    np.testing.assert_allclose(np.asarray(total_applied + residual["w"]),
                               np.asarray(total_g), rtol=1e-5, atol=1e-5)


def test_compression_ratio_under_half():
    params = {"w": jnp.zeros((4096, 512), jnp.bfloat16)}
    assert compression_ratio(params) < 0.55


def test_sharding_rules_cover_all_archs():
    """Every arch's param/batch/cache trees produce valid specs (rank
    matches, axes exist) on an abstract 16x16 mesh."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import all_arch_ids, get_config
    from repro.distributed import sharding as SH
    from repro.models import lm

    try:  # jax >= 0.5 signature: (axis_sizes, axis_names)
        mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:  # jax 0.4.x signature: ((name, size), ...)
        mesh = jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
    for arch in all_arch_ids():
        cfg = get_config(arch)
        params = jax.eval_shape(
            lambda cfg=cfg: lm.init_params(cfg, jax.random.PRNGKey(0)))
        specs = SH.param_pspecs(cfg, params, mesh)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(params),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(spec) <= len(leaf.shape), (arch, path)
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                size = 16
                assert leaf.shape[i] % size == 0, (arch, path, spec,
                                                   leaf.shape)


# ---------------- subprocess (8 host devices) -------------------------------

def test_pjit_train_step_8dev():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import lm
        from repro.train import make_train_step, adamw_init
        from repro.distributed import sharding as SH
        from repro.launch.mesh import make_host_mesh

        assert jax.device_count() == 8
        cfg = get_config('deepseek-7b').smoke()
        mesh = make_host_mesh(data=2, model=4)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        batch = {'tokens': jnp.zeros((4, 32), jnp.int32) + 3,
                 'labels': jnp.ones((4, 32), jnp.int32)}
        with mesh:
            p_ns = SH.named(mesh, SH.param_pspecs(cfg, params, mesh))
            o_ns = SH.named(mesh, SH.zero1_pspecs(cfg, opt, mesh))
            b_ns = SH.named(mesh, SH.batch_pspecs(cfg, batch, mesh))
            params = jax.device_put(params, p_ns)
            opt = jax.device_put(opt, o_ns)
            batch = jax.device_put(batch, b_ns)
            step = jax.jit(make_train_step(cfg),
                           in_shardings=(p_ns, o_ns, b_ns),
                           out_shardings=(p_ns, o_ns, None))
            params2, opt2, m = step(params, opt, batch)
        loss = float(m['loss'])
        assert np.isfinite(loss), loss
        # distributed result == single-device result
        cfg2 = cfg
        params_h = jax.device_get(params2)
        print('LOSS', loss)
    """)
    assert "LOSS" in out


def test_pjit_matches_single_device():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import lm
        from repro.distributed import sharding as SH
        from repro.launch.mesh import make_host_mesh

        cfg = get_config('qwen3-moe-30b-a3b').smoke()
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        batch = {'tokens': jnp.zeros((4, 32), jnp.int32) + 5}
        ref, _ = lm.logits_full(cfg, params, batch)   # 1-device reference

        mesh = make_host_mesh(data=2, model=4)
        with mesh:
            p_ns = SH.named(mesh, SH.param_pspecs(cfg, params, mesh))
            b_ns = SH.named(mesh, SH.batch_pspecs(cfg, batch, mesh))
            pp = jax.device_put(params, p_ns)
            bb = jax.device_put(batch, b_ns)
            f = jax.jit(lambda p, b: lm.logits_full(cfg, p, b)[0],
                        in_shardings=(p_ns, b_ns))
            got = f(pp, bb)
        err = float(jnp.abs(got - ref).max())
        assert err < 2e-4, err
        print('SPMD-MATCH', err)
    """)
    assert "SPMD-MATCH" in out


def test_gpipe_pipeline_matches_sequential():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import (gpipe_forward,
                                                split_layers_into_stages)
        mesh = jax.make_mesh((8,), ('pipe',))
        L, D = 16, 32
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (L, D, D)) * (D ** -0.5)
        params = {'w': w}

        def layer(p, x):
            return jnp.tanh(x @ p)

        def stage_fn(stage_params, x):
            def body(x, wl):
                return layer(wl, x), None
            x, _ = jax.lax.scan(body, x, stage_params['w'])
            return x

        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, D))  # 4 micro
        # sequential reference
        ref = x
        def body(x, wl):
            return layer(wl, x), None
        ref = jnp.stack([jax.lax.scan(body, xb, w)[0] for xb in x])
        stages = split_layers_into_stages(params, 8)
        got = gpipe_forward(stage_fn, stages, x, mesh)
        err = float(jnp.abs(got - ref).max())
        assert err < 1e-5, err
        print('PIPE-MATCH', err)
    """)
    assert "PIPE-MATCH" in out


def test_lsm_stats_merge_matches_dense_path():
    """§Perf iter 4: the shard_map'd compute-at-data cold attention must
    produce the same logits as the single-device gather path."""
    out = _run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from dataclasses import replace
        from repro.configs import get_config
        from repro.models import lm
        from repro.serving import lsm_from_dense
        from repro.distributed import runtime as RT
        from repro.distributed import sharding as SH
        from repro.launch.mesh import make_host_mesh

        cfg = replace(get_config('deepseek-7b').smoke(), n_kv=2, n_heads=4)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        b, s = 1, 128
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)
        _, dense = lm.prefill_step(cfg, params, {'tokens': toks[:, :s]})
        lsm = lsm_from_dense(cfg, dense, s + 16)

        ref, _ = lm.decode_step(cfg, params, toks[:, s], lsm, kind='lsm')

        mesh = make_host_mesh(data=4, model=2)
        RT.set_axes(('data',), 'model', mesh)
        with mesh:
            p_ns = SH.named(mesh, SH.param_pspecs(cfg, params, mesh))
            c_ns = SH.named(mesh, SH.cache_pspecs(cfg, lsm, mesh))
            pp = jax.device_put(params, p_ns)
            cc = jax.device_put(lsm, c_ns)
            f = jax.jit(lambda p, t, c: lm.decode_step(cfg, p, t, c,
                                                       kind='lsm')[0],
                        in_shardings=(p_ns, None, c_ns))
            got = f(pp, toks[:, s], cc)
        RT.clear()
        err = float(jnp.abs(got - ref).max())
        assert err < 2e-3, err
        print('STATS-MERGE-MATCH', err)
    """)
    assert "STATS-MERGE-MATCH" in out


def test_elastic_reshard_roundtrip():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.elastic import make_elastic_mesh, reshard
        tree = {'w': np.arange(64, dtype=np.float32).reshape(8, 8)}
        specs = {'w': P('data', 'model')}
        m1 = make_elastic_mesh(8, prefer_model=4)   # 2x4
        d1 = reshard(tree, m1, specs)
        m2 = make_elastic_mesh(4, prefer_model=2)   # 2x2 (shrunk fleet)
        d2 = reshard(jax.device_get(d1), m2, specs)
        np.testing.assert_array_equal(np.asarray(jax.device_get(d2)['w']),
                                      tree['w'])
        print('RESHARD-OK')
    """)
    assert "RESHARD-OK" in out


def test_straggler_monitor():
    from repro.distributed.elastic import StragglerMonitor
    mon = StragglerMonitor(threshold=2.0, min_samples=4)
    for _ in range(10):
        assert mon.record(0, 1.0) == "ok"
    assert mon.record(7, 5.0) == "skip"
    assert mon.record(7, 5.0) == "skip"
    assert mon.record(7, 5.0) == "quarantine"
    assert mon.healthy_hosts([0, 7]) == [0]


def test_elastic_mesh_factoring():
    from repro.distributed.elastic import factor_devices
    assert factor_devices(512, 16) == (32, 16)
    assert factor_devices(256, 16) == (16, 16)
    assert factor_devices(8, 4) == (2, 4)
    assert factor_devices(6, 4) == (2, 3)      # TP degrades gracefully
    assert factor_devices(7, 4) == (7, 1)      # prime counts still work
    for n in (8, 48, 96, 384, 512):
        d, m = factor_devices(n)
        assert d * m == n
