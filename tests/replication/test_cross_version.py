"""WAL format-2 cross-version replication (ISSUE 9 satellite): a
follower replaying a legacy format-1 ``REC_WRITE`` stream (reserved
TOMBSTONE value = delete) converges bitwise with one replaying the
equivalent weighted ``REC_WRITE2`` stream — the promise that a
format-2 follower can trail a not-yet-upgraded format-1 leader.

The equivalence is exact by construction: `wal.decode_write` maps a
legacy TOMBSTONE hit to the weighted ``(val 0, wt −1)`` record, which
is byte-for-byte what the modern driver logs for a delete.
"""
import struct

import numpy as np

from repl_harness import (apply_ops, assert_same_answers, make_leader,
                          probe_answers, write_stream)

from repro.core.params import TOMBSTONE
from repro.engine import replication as R
from repro.engine import wal as WAL


def _legacy_frames(ops, first_seqno, epoch=0):
    """Hand-encode an op stream as format-1 REC_WRITE frames (n u32 +
    keys int32[n] + vals int32[n]; TOMBSTONE value = delete)."""
    frames, seq = [], first_seqno
    for kind, keys, vals in ops:
        k = np.ascontiguousarray(np.asarray(keys, np.int32).reshape(-1))
        if kind == "insert":
            v = np.ascontiguousarray(np.asarray(vals, np.int32))
        else:
            v = np.full(k.size, TOMBSTONE, np.int32)
        payload = struct.pack("<I", k.size) + k.tobytes() + v.tobytes()
        frames.append(WAL.encode_record(seq, WAL.REC_WRITE, payload,
                                        epoch))
        seq += 1
    return frames


def test_legacy_write_stream_matches_write2(tmp_path):
    """Two followers of the same genesis: one trails the live WRITE2
    leader, one ingests the hand-encoded legacy stream for the same
    ops — their answers (and durable watermarks) are bitwise equal."""
    drv, leader = make_leader(tmp_path / "leader")
    cur = leader.bootstrap(tmp_path / "legacy")   # fresh: MAGIC + META
    fol2 = leader.add_follower(tmp_path / "w2")
    fol1 = R.Follower(tmp_path / "legacy")        # transport-free ingest
    ops = write_stream(n_ops=8)
    apply_ops(drv, ops)
    R.converge(leader, fol2)
    fol1.ingest(_legacy_frames(ops, cur.next_seqno))
    assert fol1.last_seqno == fol2.last_seqno
    assert fol1.stats()["rejected"] == 0
    assert_same_answers(probe_answers(fol1.drv), probe_answers(fol2.drv))
    # the legacy replica log decodes to the same weighted chunks
    recs1 = [r for r in WAL.read_wal(tmp_path / "legacy" / "wal.log")[0]
             if r.kind in WAL.WRITE_KINDS]
    recs2 = [r for r in WAL.read_wal(tmp_path / "w2" / "wal.log")[0]
             if r.kind in WAL.WRITE_KINDS]
    assert [r.kind for r in recs1] == [WAL.REC_WRITE] * len(ops)
    assert [r.kind for r in recs2] == [WAL.REC_WRITE2] * len(ops)
    for a, b in zip(recs1, recs2):
        ka, va, wa = WAL.decode_write(a.payload, a.kind)
        kb, vb, wb = WAL.decode_write(b.payload, b.kind)
        np.testing.assert_array_equal(ka, kb)
        np.testing.assert_array_equal(va, vb)
        np.testing.assert_array_equal(wa, wb)


def test_mixed_format_stream_applies_in_order(tmp_path):
    """A mid-stream format upgrade (legacy frames then WRITE2 frames on
    one connection) applies seamlessly: seqnos stay consecutive, and
    the replica matches an engine fed the full op stream."""
    drv, leader = make_leader(tmp_path / "leader")
    cur = leader.bootstrap(tmp_path / "mixed")
    fol = R.Follower(tmp_path / "mixed")
    ops = write_stream(n_ops=8)
    legacy = _legacy_frames(ops[:4], cur.next_seqno)
    seq = cur.next_seqno + 4
    modern = []
    for kind, keys, vals in ops[4:]:
        k = np.asarray(keys, np.int32).reshape(-1)
        if kind == "insert":
            v, w = np.asarray(vals, np.int32), np.ones_like(k)
        else:
            v, w = np.zeros_like(k), np.full_like(k, -1)
        modern.append(WAL.encode_record(seq, WAL.REC_WRITE2,
                                        WAL.encode_write(k, v, w)))
        seq += 1
    applied = fol.ingest(legacy + modern)
    assert applied == len(ops)
    # restore of the mixed-format replica dir replays both formats
    fol.drv.durability.close()
    from repro.engine import SLSM
    back = SLSM.restore(tmp_path / "mixed")
    apply_ops(drv, ops)
    assert_same_answers(probe_answers(back), probe_answers(drv))
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))
