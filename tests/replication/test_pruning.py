"""WAL pruning × snapshot GC interplay (DESIGN.md §15, the prune-race
fault cell).

The claims under test:

  * **floor safety** — `Leader.prune` truncates sealed segments only at
    or below min(newest snapshot watermark, every attached follower's
    ack — dead handles included). A straggling (even partitioned)
    follower therefore *cannot* lose the tail it still needs: its next
    frames are always readable from the retained chain.
  * **snapshot+tail bootstrap** — after pruning, `bootstrap` still
    produces a correct follower (the early segments are gone, but the
    snapshot covers exactly what was pruned: prune never passes the
    snapshot watermark).
  * **prune race** — a cursor that *does* fall below the floor (only
    possible for a handle attached after pruning already ran) is
    detected by the tailer's ``pruned_gap`` and flagged
    ``needs_bootstrap`` instead of shipping a gapped stream.
  * the **property**: under a randomized interleaving of writes, rolls,
    partial follower pumping, snapshots, and prunes, the retained chain
    always serves every attached follower's next frame and stays
    seqno-consecutive — on both drivers × both backends.
"""
import random

import numpy as np
import pytest

from repl_harness import (BACKENDS, DRIVERS, apply_ops,
                          assert_same_answers, make_engine, probe_answers,
                          small_params, write_stream)

from repro.engine import replication as R
from repro.engine import wal as WAL


def make_segmented_leader(tmp_path, driver="single", backend="jnp",
                          segment_bytes=256):
    """A durable leader whose WAL rolls aggressively (tiny segments —
    every couple of records seals a file, so pruning has prey)."""
    p = small_params(backend)
    dur = WAL.Durability(tmp_path / "leader", snapshot_every_bytes=1 << 30,
                         segment_bytes=segment_bytes)
    drv = make_engine(driver, p, durability=dur)
    return drv, R.Leader(drv)


def chain_first_seqno(directory) -> int:
    """Seqno of the first record in the retained chain."""
    recs, _ = WAL.read_wal_chain(directory)
    return recs[0].seqno if recs else -1


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", DRIVERS)
def test_prune_floors_at_lagging_follower_ack(tmp_path, driver, backend):
    """A pre-snapshot-attached follower lags mid-stream; snapshotting
    at the tip must NOT let prune delete the segments between the
    follower's ack and the snapshot watermark — the follower still
    converges bitwise from the retained chain."""
    drv, leader = make_segmented_leader(tmp_path, driver, backend)
    ops = write_stream(n_ops=16)
    fol = leader.add_follower(tmp_path / "fol")
    apply_ops(drv, ops, upto=6)
    for _ in range(3):                  # follower acks the early prefix
        leader.pump()
        fol.pump()
    leader.pump()
    acked = leader.handles[0].acked_seqno
    assert acked >= 1
    apply_ops(drv, ops[6:])             # the leader runs far ahead...
    drv.snapshot()                      # ...and snapshots at the tip
    assert drv.durability.prune_floor() > acked
    leader.prune()
    # floor safety: everything past the follower's ack is retained
    assert chain_first_seqno(tmp_path / "leader") <= acked + 1
    frames = WAL.chain_frames(tmp_path / "leader", acked + 1)
    seqs = [WAL.check_frame(f).seqno for f in frames]
    assert seqs == list(range(acked + 1, seqs[-1] + 1))
    R.converge(leader, fol)
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))
    # once the follower has acked everything, the floor lifts and the
    # pre-watermark segments actually go
    pruned = leader.prune()
    assert pruned >= 1, "full ack + snapshot must release segments"
    assert drv.durability.stats()["wal_pruned_bytes"] > 0


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", DRIVERS)
def test_bootstrap_after_prune_is_snapshot_plus_tail(tmp_path, driver,
                                                     backend):
    """With no followers holding the floor down, prune cuts to the
    snapshot watermark; a *new* follower bootstrap then rides the
    snapshot + retained tail and still answers bitwise."""
    drv, leader = make_segmented_leader(tmp_path, driver, backend)
    ops = write_stream(n_ops=16)
    apply_ops(drv, ops, upto=10)
    drv.snapshot()
    apply_ops(drv, ops[10:])
    pruned = leader.prune()
    assert pruned >= 1, "tiny segments + mid-stream snapshot must prune"
    assert chain_first_seqno(tmp_path / "leader") > 0, \
        "genesis segments must be gone"
    fol = leader.add_follower(tmp_path / "fol")
    R.converge(leader, fol)
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))
    prom = fol.promote()
    assert_same_answers(probe_answers(prom), probe_answers(drv))


def test_prune_without_snapshot_is_inert(tmp_path):
    """No snapshot -> floor -1 -> nothing may be deleted, however many
    sealed segments exist."""
    drv, leader = make_segmented_leader(tmp_path)
    apply_ops(drv, write_stream(n_ops=12))
    assert drv.durability.stats()["wal_segments"] >= 2
    assert leader.prune() == 0
    assert drv.durability.stats()["wal_pruned_bytes"] == 0
    assert chain_first_seqno(tmp_path / "leader") == 0


def test_stale_cursor_after_prune_flags_bootstrap(tmp_path):
    """The prune race: a handle attached at a genesis cursor AFTER
    pruning already ran hits ``pruned_gap`` and is flagged
    ``needs_bootstrap`` (dead, never shipped a gapped stream); the
    correct path — a fresh `add_follower` bootstrap — converges."""
    drv, leader = make_segmented_leader(tmp_path)
    ops = write_stream(n_ops=14)
    apply_ops(drv, ops, upto=10)
    drv.snapshot()
    apply_ops(drv, ops[10:])
    assert leader.prune() >= 1
    link = R.QueueLink()
    h = leader.attach(link.leader, R.Cursor(len(WAL.MAGIC), 1, 0))
    leader.ship()
    assert h.needs_bootstrap and h.dead
    assert leader.counters["pruned_cursors"] >= 1
    assert not link.frames, "a gapped stream must never be shipped"
    leader.detach(h)                    # the flagged handle's only exit
    fol = leader.add_follower(tmp_path / "fol")
    R.converge(leader, fol)
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))


def test_dead_handle_past_grace_stops_pinning_prune(tmp_path):
    """REVIEW regression: a permanently gone follower (its handle is
    dead but never detached) must not block WAL pruning forever.
    Within ``dead_grace_s`` its frozen ack floors the prune (it may
    still `reattach` and resume); past the grace `prune` auto-detaches
    it, the floor lifts to the snapshot watermark, and a returning
    replica re-enters via a fresh bootstrap."""
    clock = [100.0]
    p = small_params("jnp")
    dur = WAL.Durability(tmp_path / "leader", snapshot_every_bytes=1 << 30,
                         segment_bytes=256)
    drv = make_engine("single", p, durability=dur)
    leader = R.Leader(drv, lease_s=2.0, clock=lambda: clock[0])
    ops = write_stream(n_ops=16)
    fol = leader.add_follower(tmp_path / "fol")
    apply_ops(drv, ops, upto=6)
    for _ in range(3):
        leader.pump()
        fol.pump()
    leader.pump()
    acked = leader.handles[0].acked_seqno
    assert acked >= 1
    # the follower dies for good: sever its end; the next ship fails
    # the send and marks the handle dead (never detached)
    leader.handles[0].end.close()
    apply_ops(drv, ops[6:14])
    leader.pump()
    assert leader.handles[0].dead
    drv.snapshot()
    apply_ops(drv, ops[14:])            # a live tail past the watermark
    assert drv.durability.prune_floor() > acked
    # within the grace the dead ack still floors: the tail it would
    # need on reattach is retained
    leader.prune()
    assert chain_first_seqno(tmp_path / "leader") <= acked + 1
    assert leader.handles and leader.counters["expired_handles"] == 0
    # past the grace the handle is auto-detached and the floor lifts
    clock[0] += leader.dead_grace_s + 1.0
    assert leader.prune() >= 1
    assert not leader.handles, "the expired handle must be detached"
    assert leader.counters["expired_handles"] == 1
    assert chain_first_seqno(tmp_path / "leader") > acked + 1, \
        "the dead ack must stop pinning the floor"
    # the returning replica's path is a fresh bootstrap, which still
    # converges bitwise off the snapshot + retained tail
    fol2 = leader.add_follower(tmp_path / "fol2")
    R.converge(leader, fol2)
    assert_same_answers(probe_answers(fol2.drv), probe_answers(drv))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", DRIVERS)
def test_prune_race_property(tmp_path, driver, backend):
    """Randomized interleaving of writes / partial pumping / snapshots
    / prunes: after every prune, the retained chain (a) starts at or
    below the attached follower's next frame, (b) is seqno-consecutive
    to the tip, and (c) the follower ends bitwise-converged."""
    rng = random.Random(hash((driver, backend)) & 0xFFFF)
    drv, leader = make_segmented_leader(tmp_path, driver, backend)
    ops = write_stream(n_ops=20)
    fol = leader.add_follower(tmp_path / "fol")
    i = 0
    while i < len(ops):
        step = rng.randint(1, 3)
        apply_ops(drv, ops[i:i + step])
        i += step
        if rng.random() < 0.6:          # partial pumping: follower lags
            leader.pump()
            if rng.random() < 0.7:
                fol.pump()
            leader.pump()
        if rng.random() < 0.4:
            drv.snapshot()
        leader.prune()
        acked = leader.handles[0].acked_seqno
        first = chain_first_seqno(tmp_path / "leader")
        assert first <= acked + 1, \
            f"pruned past the follower's ack ({first} > {acked + 1})"
        recs, _ = WAL.read_wal_chain(tmp_path / "leader")
        seqs = [r.seqno for r in recs]
        if seqs:
            assert seqs == list(range(seqs[0], seqs[0] + len(seqs))), \
                "retained chain must stay seqno-consecutive"
        else:
            # an empty chain is legal exactly when nothing is owed:
            # the follower acked the tip and the snapshot covers it
            assert acked >= drv.durability.writer.last_seqno
    R.converge(leader, fol)
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))
    st = drv.durability.stats()
    assert st["wal_rolls"] >= 2, "the property run must actually roll"
