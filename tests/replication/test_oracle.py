"""Replication oracle suite (ISSUE 9 satellite): a seeded interleaved
leader-writes / follower-reads sweep checked against `DictOracle`
(mirroring ``tests/test_zset_props.py``), on both drivers × both
backends.

The claims:

  * **read-your-writes on the leader**: every write is visible to the
    very next leader read (log-before-ack is the driver boundary's
    group commit; replication never weakens it);
  * **prefix consistency on the follower**: a mid-stream follower read
    equals a `DictOracle` fed exactly the follower's durable write
    prefix — never a torn or interpolated state;
  * **convergence**: after `converge()`, follower answers are bitwise
    the leader's (and the oracle's).
"""
import numpy as np
import pytest

from repl_harness import (BACKENDS, DRIVERS, KEY_SPACE,
                          assert_same_answers, durable_write_ops,
                          leader_with_follower, probe_answers)

from repro.core.oracle import DictOracle
from repro.engine import replication as R


def _op_stream(rng, n_ops, op_size=32):
    """Seeded mixed stream (inserts with overwrites + slab deletes)."""
    ops = []
    for i in range(n_ops):
        keys = rng.integers(0, KEY_SPACE, op_size).astype(np.int32)
        if i % 4 == 3:
            ops.append(("delete", keys[:op_size // 3], None))
        else:
            vals = rng.integers(0, 1 << 20, op_size).astype(np.int32)
            ops.append(("insert", keys, vals))
    return ops


def _oracle_upto(ops, j):
    """A DictOracle fed ops[:j]."""
    o = DictOracle()
    for kind, keys, vals in ops[:j]:
        if kind == "insert":
            o.insert(keys, vals)
        else:
            o.delete(keys)
    return o


def _assert_matches_oracle(drv, oracle, probe):
    vals, found = drv.lookup_many(probe)
    want_v, want_f = oracle.lookup(probe)
    np.testing.assert_array_equal(np.asarray(found), want_f)
    np.testing.assert_array_equal(np.asarray(vals)[np.asarray(found)],
                                  want_v[want_f])


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", DRIVERS)
def test_interleaved_sweep_vs_dict_oracle(tmp_path, driver, backend):
    rng = np.random.default_rng(7)
    ops = _op_stream(rng, n_ops=12)
    drv, leader, fol, _ = leader_with_follower(
        tmp_path, driver, backend, ops=ops, n_prefix=0)
    probe = np.arange(0, KEY_SPACE, 7, dtype=np.int32)
    for i, (kind, keys, vals) in enumerate(ops):
        if kind == "insert":
            drv.insert(keys, vals)
        else:
            drv.delete(keys)
        # read-your-writes on the leader: this op's keys answer from
        # the full prefix immediately
        _assert_matches_oracle(drv, _oracle_upto(ops, i + 1), keys)
        if i % 3 == 2:
            leader.pump()
            fol.pump()
            # follower serves a consistent durable prefix — exactly its
            # WAL's write-record count, never a partial window
            j = durable_write_ops(fol.drv.durability.wal_path)
            assert j <= i + 1
            _assert_matches_oracle(fol.drv, _oracle_upto(ops, j), probe)
    rounds = R.converge(leader, fol)
    assert rounds >= 1 and leader.stats()["follower_lag_records"] == 0
    # converged: follower is bitwise the leader, both match the oracle
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))
    _assert_matches_oracle(fol.drv, _oracle_upto(ops, len(ops)), probe)


@pytest.mark.parametrize("driver", DRIVERS)
def test_follower_reads_are_batched_paths(tmp_path, driver):
    """Followers serve the batched read paths (`lookup_many`,
    `range_many`, `aggregate_many`) at their applied watermark."""
    drv, leader, fol, ops = leader_with_follower(tmp_path, driver,
                                                 n_prefix=8)
    R.converge(leader, fol)
    o = _oracle_upto(ops, durable_write_ops(fol.drv.durability.wal_path))
    lo, hi = 100, 1800
    k, v = fol.drv.range(lo, hi)
    wk, wv = o.range(lo, hi)
    np.testing.assert_array_equal(np.asarray(k), wk)
    np.testing.assert_array_equal(np.asarray(v), wv)
    bounds = np.array([[0, 500], [100, 1800]], np.int32)
    keys_b, vals_b, counts, _ = fol.drv.range_many(bounds)
    for lane, (blo, bhi) in enumerate(bounds):
        wk, wv = o.range(int(blo), int(bhi))
        n = int(counts[lane])
        np.testing.assert_array_equal(np.asarray(keys_b[lane])[:n], wk)
        np.testing.assert_array_equal(np.asarray(vals_b[lane])[:n], wv)
    cnt, tot, _trunc = fol.drv.aggregate_many(
        [(int(blo), int(bhi)) for blo, bhi in bounds])
    for lane, (blo, bhi) in enumerate(bounds):
        want_c, want_s = o.aggregate(int(blo), int(bhi))
        assert (int(cnt[lane]), int(tot[lane])) == (want_c, want_s)


def test_lag_telemetry_tracks_unshipped_tail(tmp_path):
    """`follower_lag_records`/`_bytes` rise with the unshipped durable
    tail and fall to exactly 0 on convergence."""
    drv, leader, fol, ops = leader_with_follower(tmp_path, n_prefix=0)
    st0 = leader.stats()
    assert st0["followers"] == 1 and st0["follower_lag_records"] == 0
    from repl_harness import apply_ops
    apply_ops(drv, ops, upto=6)
    st = leader.stats()
    assert st["follower_lag_records"] >= 6          # one record per op
    assert st["follower_lag_bytes"] > 0
    R.converge(leader, fol)
    st2 = leader.stats()
    assert st2["follower_lag_records"] == 0
    assert st2["follower_lag_bytes"] == 0
    assert st2["shipped_records"] >= 6
    fst = fol.stats()
    assert fst["applied_seqno"] == st2["last_seqno"]
    assert fst["duplicates"] == fst["rejected"] == 0


def test_hypothesis_interleaving_converges(tmp_path_factory):
    """Hypothesis variant (importorskip-gated): arbitrary interleavings
    of writes, pumps, and wire perturbations still converge to the
    DictOracle answer."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=15, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(st.lists(st.tuples(st.sampled_from(["ins", "del", "pump"]),
                                  st.integers(0, 2 ** 32 - 1)),
                        min_size=1, max_size=12),
               st.randoms(use_true_random=False))
    def run(script, wire_rng):
        tmp = tmp_path_factory.mktemp("hyp")
        drv, leader, fol, _ = leader_with_follower(tmp, "single", "jnp")
        oracle = DictOracle()
        for step, seed in script:
            rng = np.random.default_rng(seed)
            keys = rng.integers(0, 500, 16).astype(np.int32)
            if step == "ins":
                vals = rng.integers(0, 1 << 20, 16).astype(np.int32)
                drv.insert(keys, vals)
                oracle.insert(keys, vals)
            elif step == "del":
                drv.delete(keys[:5])
                oracle.delete(keys[:5])
            else:
                leader.pump()
                if wire_rng.random() < 0.5 and fol.link.frames:
                    fol.link.frames.rotate(1)       # reorder in flight
                fol.pump()
        R.converge(leader, fol)
        probe = np.arange(0, 500, 3, dtype=np.int32)
        _assert_matches_oracle(fol.drv, oracle, probe)
        _assert_matches_oracle(drv, oracle, probe)

    run()
