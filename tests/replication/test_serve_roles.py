"""Replication roles in `repro.serve` (DESIGN.md §14): a leader server
ships durable frames in its pump seams (after windows, in idle gaps),
a follower server applies the stream in the same seams, serves the
batched read paths eventually-consistently, and rejects write submits
at intake. Read-your-writes holds through the serving layer on the
leader (log-before-ack: the WAL record is durable before the window
replies)."""
import numpy as np
import pytest

from repl_harness import (assert_same_answers, leader_with_follower,
                          probe_answers)

from repro.engine import replication as R
from repro.serve import AsyncServer, Server, WindowPolicy


def test_follower_server_rejects_writes(tmp_path):
    """Write submits bounce at intake (nothing poisons the window);
    reads serve at the applied watermark."""
    drv, leader, fol, ops = leader_with_follower(tmp_path, n_prefix=4)
    R.converge(leader, fol)
    srv = Server(fol.drv, role="follower")
    with pytest.raises(ValueError, match="read-only"):
        srv.submit("c", "insert", np.int32([2]), np.int32([1]))
    with pytest.raises(ValueError, match="read-only"):
        srv.submit("c", "delete", np.int32([2]))
    assert srv.pending == 0
    probe = np.int32([0, 3, 6, 9])
    t = srv.submit("c", "lookup", probe)
    srv.pump(force=True)
    assert t.done
    want_v, want_f = fol.drv.lookup_many(probe)
    np.testing.assert_array_equal(np.asarray(t.result[0]),
                                  np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(t.result[1]),
                                  np.asarray(want_f))
    st = srv.stats()
    assert st["role"] == "follower"
    assert st["replication"]["role"] == "follower"
    assert AsyncServer(srv).role == "follower"
    with pytest.raises(ValueError):
        Server(fol.drv, role="observer")


def test_leader_server_read_your_writes_and_ships(tmp_path):
    """A lookup submitted after an insert sees it in the very same
    window (the tape's hazard order = submission order, and the WAL
    record is durable before the reply); the pump's replication hook
    ships the window to the follower without extra machinery."""
    drv, leader, fol, _ = leader_with_follower(tmp_path)
    srv = Server(drv, role="leader", window=WindowPolicy(max_ops=64))
    keys = np.int32([10, 20, 30])
    vals = keys * 3
    srv.submit("w", "insert", keys, vals)
    t = srv.submit("w", "lookup", keys)
    srv.pump(force=True)
    assert t.done
    np.testing.assert_array_equal(np.asarray(t.result[1]),
                                  [True, True, True])
    np.testing.assert_array_equal(np.asarray(t.result[0]), vals)
    st = srv.stats()
    assert st["role"] == "leader"
    assert st["replication"]["followers"] == 1
    assert st["replication"]["shipped_records"] >= 1
    # idle pumps on both sides converge the follower
    for _ in range(4):
        srv.pump()
        fol.pump()
    assert leader.stats()["follower_lag_records"] == 0
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))


def test_leader_and_follower_servers_end_to_end(tmp_path):
    """Two servers over one replication pair: writes land on the
    leader server, idle pumps carry them across, and the follower
    server answers them — eventual consistency through `repro.serve`
    alone (no direct engine calls)."""
    drv, leader, fol, _ = leader_with_follower(tmp_path)
    lsrv = Server(drv, role="leader")
    fsrv = Server(fol.drv, role="follower")
    keys = np.int32([2, 4, 6])
    vals = np.int32([20, 40, 60])
    lsrv.submit("w", "insert", keys, vals)
    lsrv.pump(force=True)               # serve + ship
    fsrv.pump()                         # idle gap: apply the stream
    t = fsrv.submit("r", "lookup", keys)
    fsrv.pump(force=True)
    assert t.done
    np.testing.assert_array_equal(np.asarray(t.result[1]),
                                  [True, True, True])
    np.testing.assert_array_equal(np.asarray(t.result[0]), vals)
    assert fsrv.stats()["replication"]["applied_records"] >= 1
