"""Replication roles in `repro.serve` (DESIGN.md §14): a leader server
ships durable frames in its pump seams (after windows, in idle gaps),
a follower server applies the stream in the same seams, serves the
batched read paths eventually-consistently, and rejects write submits
at intake. Read-your-writes holds through the serving layer on the
leader (log-before-ack: the WAL record is durable before the window
replies). Quorum ack mode (§15) rides the same seams: write tickets
are held until k followers confirm, released one pump after the acks
arrive (the eager advertising heartbeat), and — the REVIEW
regression — *fail* with a typed `QuorumAckError` on deposition,
quorum timeout, or drain, instead of leaving clients hanging."""
import asyncio

import numpy as np
import pytest

from repl_harness import (assert_same_answers, leader_with_follower,
                          make_engine, probe_answers, small_params)

from repro.engine import replication as R
from repro.engine import wal as WAL
from repro.serve import AsyncServer, QuorumAckError, Server, WindowPolicy


def quorum_server(tmp_path, *, quorum_timeout_s=30.0, clock=None):
    """A quorum-mode (k=1) serving leader with one bootstrapped,
    never-yet-pumped follower; optional injected clock drives both the
    lease machinery and the server's hold timeouts."""
    p = small_params("jnp")
    dur = WAL.Durability(tmp_path / "leader", snapshot_every_bytes=1 << 30)
    drv = make_engine("single", p, durability=dur)
    kw = {} if clock is None else {"clock": clock}
    leader = R.Leader(drv, ack_mode="quorum", quorum=1, **kw)
    fol = leader.add_follower(tmp_path / "fol")
    srv = Server(drv, role="leader", quorum_timeout_s=quorum_timeout_s,
                 **kw)
    return drv, leader, fol, srv


def test_follower_server_rejects_writes(tmp_path):
    """Write submits bounce at intake (nothing poisons the window);
    reads serve at the applied watermark."""
    drv, leader, fol, ops = leader_with_follower(tmp_path, n_prefix=4)
    R.converge(leader, fol)
    srv = Server(fol.drv, role="follower")
    with pytest.raises(ValueError, match="read-only"):
        srv.submit("c", "insert", np.int32([2]), np.int32([1]))
    with pytest.raises(ValueError, match="read-only"):
        srv.submit("c", "delete", np.int32([2]))
    assert srv.pending == 0
    probe = np.int32([0, 3, 6, 9])
    t = srv.submit("c", "lookup", probe)
    srv.pump(force=True)
    assert t.done
    want_v, want_f = fol.drv.lookup_many(probe)
    np.testing.assert_array_equal(np.asarray(t.result[0]),
                                  np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(t.result[1]),
                                  np.asarray(want_f))
    st = srv.stats()
    assert st["role"] == "follower"
    assert st["replication"]["role"] == "follower"
    assert AsyncServer(srv).role == "follower"
    with pytest.raises(ValueError):
        Server(fol.drv, role="observer")


def test_leader_server_read_your_writes_and_ships(tmp_path):
    """A lookup submitted after an insert sees it in the very same
    window (the tape's hazard order = submission order, and the WAL
    record is durable before the reply); the pump's replication hook
    ships the window to the follower without extra machinery."""
    drv, leader, fol, _ = leader_with_follower(tmp_path)
    srv = Server(drv, role="leader", window=WindowPolicy(max_ops=64))
    keys = np.int32([10, 20, 30])
    vals = keys * 3
    srv.submit("w", "insert", keys, vals)
    t = srv.submit("w", "lookup", keys)
    srv.pump(force=True)
    assert t.done
    np.testing.assert_array_equal(np.asarray(t.result[1]),
                                  [True, True, True])
    np.testing.assert_array_equal(np.asarray(t.result[0]), vals)
    st = srv.stats()
    assert st["role"] == "leader"
    assert st["replication"]["followers"] == 1
    assert st["replication"]["shipped_records"] >= 1
    # idle pumps on both sides converge the follower
    for _ in range(4):
        srv.pump()
        fol.pump()
    assert leader.stats()["follower_lag_records"] == 0
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))


def test_leader_and_follower_servers_end_to_end(tmp_path):
    """Two servers over one replication pair: writes land on the
    leader server, idle pumps carry them across, and the follower
    server answers them — eventual consistency through `repro.serve`
    alone (no direct engine calls)."""
    drv, leader, fol, _ = leader_with_follower(tmp_path)
    lsrv = Server(drv, role="leader")
    fsrv = Server(fol.drv, role="follower")
    keys = np.int32([2, 4, 6])
    vals = np.int32([20, 40, 60])
    lsrv.submit("w", "insert", keys, vals)
    lsrv.pump(force=True)               # serve + ship
    fsrv.pump()                         # idle gap: apply the stream
    t = fsrv.submit("r", "lookup", keys)
    fsrv.pump(force=True)
    assert t.done
    np.testing.assert_array_equal(np.asarray(t.result[1]),
                                  [True, True, True])
    np.testing.assert_array_equal(np.asarray(t.result[0]), vals)
    assert fsrv.stats()["replication"]["applied_records"] >= 1


def test_quorum_release_end_to_end(tmp_path):
    """The happy path: a held write releases one pump after the
    follower's ack arrives — the eager advertising heartbeat makes the
    quorum watermark (advertised acks only) catch up immediately
    instead of waiting out the heartbeat cadence."""
    drv, leader, fol, srv = quorum_server(tmp_path)
    t = srv.submit("w", "insert", np.int32([1, 2]), np.int32([10, 20]))
    srv.pump(force=True)
    assert not t.done and srv.stats()["unacked_writes"] == 1, \
        "the write is executed + durable but held for the quorum"
    fol.pump()                          # apply + ack
    srv.pump()                          # drain ack, advertise, release
    assert t.done and t.error is None
    assert srv.counters["quorum_releases"] == 1
    assert srv.stats()["unacked_windows"] == 0


def test_quorum_held_writes_fail_instead_of_hanging(tmp_path):
    """REVIEW regression: held tickets must never strand a client.
    (a) drain fails whatever its bounded release attempt cannot clear;
    (b) a quorum unreachable past ``quorum_timeout_s`` fails the hold;
    (c) deposition fails every hold immediately."""
    clock = [0.0]
    drv, leader, fol, srv = quorum_server(
        tmp_path, quorum_timeout_s=5.0, clock=lambda: clock[0])
    # (a) drain: the follower is never pumped, so no ack can arrive
    ta = srv.submit("w", "insert", np.int32([1]), np.int32([10]))
    srv.pump(force=True)
    assert not ta.done
    srv.drain()
    assert ta.done and isinstance(ta.error, QuorumAckError)
    assert ta.result is None
    # (b) timeout: a fresh hold expires once the clock passes the bound
    tb = srv.submit("w", "insert", np.int32([2]), np.int32([20]))
    srv.pump(force=True)
    clock[0] += 10.0
    srv.pump()
    assert tb.done and isinstance(tb.error, QuorumAckError)
    assert srv.stats()["unacked_windows"] == 0
    # (c) deposition: an automatic failover deposed this leader — every
    # held write fails now (its fate rides on the successor's stream)
    tc = srv.submit("w", "insert", np.int32([3]), np.int32([30]))
    srv.pump(force=True)
    leader.deposed = True
    drv.demote()
    srv.pump()
    assert tc.done and isinstance(tc.error, QuorumAckError)
    assert srv.counters["quorum_failed"] == 3
    assert srv.stats()["role"] == "follower", "deposed: the role flips"


def test_async_quorum_fail_raises_not_hangs(tmp_path):
    """The front-end face of the regression: an awaited quorum write
    whose ack becomes impossible must raise `QuorumAckError` in the
    awaiting client instead of hanging its future forever."""
    drv, leader, fol, srv = quorum_server(tmp_path, quorum_timeout_s=0.2)

    async def run():
        async with AsyncServer(srv) as asrv:
            with pytest.raises(QuorumAckError):
                await asrv.submit("w", "insert", np.int32([5]),
                                  np.int32([50]))

    asyncio.run(run())
    assert srv.counters["quorum_failed"] >= 1
