"""Shared machinery for the replication suite (DESIGN.md §14).

Extends the crash-point harness (``tests/durability/harness.py`` — the
deterministic op stream, the tiny geometry, the bitwise answer probes)
with replication wiring: build a durable leader, bootstrap + attach an
in-process follower over a `QueueLink` (the inspectable wire the fault
tests mutate), and the two oracles the suite's claims reduce to:

  * the **convergence oracle**: after `converge`, a follower answers
    bitwise like the leader (and like a `DictOracle` fed the same
    stream);
  * the **failover oracle**: a promoted follower answers bitwise like
    a fresh engine fed exactly the follower's durable WRITE prefix —
    the acked prefix, since a follower acks only synced frames.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                       / "durability"))

from harness import (BACKENDS, DRIVERS, KEY_SPACE, apply_ops,  # noqa: F401,E402
                     assert_same_answers, durable_write_ops, make_engine,
                     probe_answers, small_params, write_stream)

from repro.engine import replication as R  # noqa: E402
from repro.engine import wal as WAL        # noqa: E402


def make_leader(durdir, driver="single", backend="jnp", adaptive=False,
                fsync=False):
    """One durable engine + its `Leader` (tiny geometry, no snapshot
    threshold — tests snapshot explicitly when they want one)."""
    p = small_params(backend, adaptive)
    dur = WAL.Durability(durdir, fsync=fsync, snapshot_every_bytes=1 << 30)
    drv = make_engine(driver, p, durability=dur)
    return drv, R.Leader(drv)


def leader_with_follower(tmp_path, driver="single", backend="jnp",
                         adaptive=False, n_prefix=0, snapshot=False,
                         ops=None):
    """The standard fixture: a leader that has already absorbed
    ``ops[:n_prefix]`` (optionally snapshotting after), plus one
    freshly bootstrapped QueueLink follower. Returns
    ``(drv, leader, follower, ops)``."""
    drv, leader = make_leader(tmp_path / "leader", driver, backend, adaptive)
    if ops is None:
        ops = write_stream(n_ops=12)
    apply_ops(drv, ops, upto=n_prefix)
    if snapshot:
        drv.snapshot()
    fol = leader.add_follower(tmp_path / "follower")
    return drv, leader, fol, ops


def acked_prefix_answers(follower, driver, backend, adaptive=False,
                         ops=None, leader_dir=None):
    """The failover oracle's answers: a fresh *non-durable* engine fed
    exactly the write-op prefix that is durable in the follower
    (= the acked prefix: followers ack only after group commit).

    With `leader_dir` the prefix length is counted from the *leader's*
    log at the follower's applied watermark — required when the
    follower was bootstrapped from a snapshot (its own WAL then holds
    only the tail records, but its state holds the snapshot's too)."""
    if leader_dir is not None:
        wm = follower.last_seqno
        j = sum(1 for r in WAL.read_wal(Path(leader_dir) / "wal.log")[0]
                if r.kind in WAL.WRITE_KINDS and r.seqno <= wm)
    else:
        j = durable_write_ops(follower.drv.durability.wal_path)
    oracle = make_engine(driver, small_params(backend, adaptive))
    apply_ops(oracle, ops, upto=j)
    return probe_answers(oracle), j


def pump_rounds(leader, follower, rounds=3):
    """A bounded number of pump turns (no convergence requirement —
    the fault tests drive the wire in between)."""
    for _ in range(rounds):
        leader.pump()
        follower.pump()
    leader.pump()
