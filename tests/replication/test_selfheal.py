"""Self-healing fault cells (DESIGN.md §15, the ISSUE 10 headline).

Four new fault cells over the PR-9 suite, each answer-exact across
both drivers × both backends:

  * **lease expiry** — the leader goes silent; the deterministic
    successor (highest acked watermark, lowest follower id) promotes
    *automatically* on its expired lease, answers bitwise at its acked
    prefix, and the losing follower stands down awaiting the new
    stream;
  * **live deposed leader** — the old leader is partitioned, not dead:
    it keeps writing until the promoted successor's bumped-epoch fence
    ack reaches it, at which point it fences itself (writes raise,
    ship is inert) and can rejoin as a bootstrapped follower;
  * **quorum loss** — with ``ack_mode="quorum"`` the commit watermark
    collapses to -1 the moment fewer than k followers are live, and
    every seqno at or below any previously returned watermark is
    already durable on a promotable follower (RPO 0);
  * **bounded reorder buffer** — a pathological reorder stream
    overflows the pending buffer; the shed suffix costs one immediate
    gap-signalled retransmit round, never divergence.

Leases run on an injected fake clock, so every cell is deterministic —
no sleeps, no wall-clock flake.
"""
import numpy as np
import pytest

from repl_harness import (BACKENDS, DRIVERS, acked_prefix_answers,
                          apply_ops, assert_same_answers, make_engine,
                          probe_answers, small_params, write_stream)

from repro.engine import replication as R
from repro.engine import wal as WAL


class FakeClock:
    """Injected monotonic time: leases expire when the test says so."""

    def __init__(self, t: float = 100.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_lease_cluster(tmp_path, driver, backend, n_followers=2,
                       lease_s=2.0, ack_mode="leader", quorum=1,
                       n_prefix=8, ops=None):
    """A leader with lease heartbeats on a fake clock plus
    ``n_followers`` auto-promote followers, fully converged and acked
    on ``ops[:n_prefix]`` (heartbeats delivered, leases armed)."""
    clock = FakeClock()
    p = small_params(backend)
    dur = WAL.Durability(tmp_path / "leader", snapshot_every_bytes=1 << 30)
    drv = make_engine(driver, p, durability=dur)
    leader = R.Leader(drv, ack_mode=ack_mode, quorum=quorum,
                      lease_s=lease_s, clock=clock)
    if ops is None:
        ops = write_stream(n_ops=12)
    apply_ops(drv, ops, upto=n_prefix)
    fols = [leader.add_follower(tmp_path / f"f{i}", auto_promote=True,
                                clock=clock)
            for i in range(n_followers)]
    for _ in range(3):
        leader.pump()
        for f in fols:
            f.pump()
    leader.pump()                       # drain the final acks
    for f in fols:
        assert f.lease_deadline is not None, "lease must be armed"
        assert f.fid is not None
    return clock, drv, leader, fols, ops


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", DRIVERS)
def test_lease_expiry_auto_promotes_successor(tmp_path, driver, backend):
    """The leader goes silent past the lease: exactly the deterministic
    successor promotes itself, answer-exact at its acked prefix; the
    loser counts the expiry and stands down; the cluster re-forms
    around the new leader and keeps converging bitwise."""
    clock, drv, leader, fols, ops = make_lease_cluster(
        tmp_path, driver, backend)
    # partition: the leader's pump never runs again; the clock runs on
    clock.advance(3.0 * leader.lease_s)
    for f in fols:
        f.pump()
    # both acked the same watermark -> lowest fid wins
    assert fols[0].new_leader is not None
    assert fols[1].new_leader is None and not fols[1].promoted
    assert fols[0].counters["auto_promotions"] == 1
    for f in fols:
        assert f.counters["lease_expiries"] == 1
    new_lead = fols[0].new_leader
    want, j = acked_prefix_answers(fols[0], driver, backend, ops=ops)
    assert j == len(ops[:8])
    assert_same_answers(probe_answers(new_lead.drv), want)
    # the losing follower rejoins the new leader's stream and converges
    link = R.QueueLink()
    new_lead.attach(link.leader,
                    R.Cursor(0, fols[1].last_seqno + 1,
                             int(new_lead.drv.durability.writer.epoch)))
    fols[1].reattach(link.follower)
    apply_ops(new_lead.drv, ops[8:])
    R.converge(new_lead, fols[1])
    assert_same_answers(probe_answers(fols[1].drv),
                        probe_answers(new_lead.drv))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", DRIVERS)
def test_live_deposed_leader_fences_and_rejoins(tmp_path, driver, backend):
    """The partitioned old leader is still alive and writing: the
    successor's fence ack (bumped epoch on the adopted end) deposes it
    — its engine fences (writes raise), ship goes inert — and its
    replacement data path is a fresh bootstrap from the new leader,
    bitwise equal to the new leader's answers."""
    clock, drv, leader, fols, ops = make_lease_cluster(
        tmp_path, driver, backend, n_followers=1)
    clock.advance(3.0 * leader.lease_s)
    fols[0].pump()
    new_lead = fols[0].new_leader
    assert new_lead is not None
    assert new_lead.fence_ends, "promote(lead=True) must adopt the old end"
    # the deposed leader doesn't know yet: it takes one more write...
    apply_ops(drv, ops[8:9])
    leader.pump()                       # ships into the fence
    new_lead.pump()                     # fence answers at epoch 1
    leader.pump()                       # ack epoch > mine -> fence self
    assert leader.deposed
    assert drv.fenced
    assert new_lead.counters["fence_acks"] >= 1
    with pytest.raises(RuntimeError, match="fenced"):
        k = np.array([7], np.int32)
        drv.insert(k, k)
    assert leader.ship() == 0
    # the unacked post-partition write died with the old epoch: the new
    # leader answers exactly the acked prefix
    want, j = acked_prefix_answers(fols[0], driver, backend, ops=ops)
    assert j == 8
    assert_same_answers(probe_answers(new_lead.drv), want)
    # rejoin: the deposed node re-enters as a bootstrapped follower of
    # the new leader and serves reads bitwise-equal to it
    rejoined = new_lead.add_follower(tmp_path / "rejoined")
    apply_ops(new_lead.drv, ops[9:])
    R.converge(new_lead, rejoined)
    assert_same_answers(probe_answers(rejoined.drv),
                        probe_answers(new_lead.drv))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", DRIVERS)
def test_quorum_loss_blocks_commit_watermark(tmp_path, driver, backend):
    """``ack_mode="quorum"``: the commit watermark is the k-th highest
    live ack; losing a follower below quorum collapses it to -1 (no
    new client acks), and everything at or below the last good
    watermark is already durable on a promotable follower — RPO 0."""
    clock, drv, leader, fols, ops = make_lease_cluster(
        tmp_path, driver, backend, ack_mode="quorum", quorum=2)
    q = leader.quorum_seqno()
    assert q == drv.durability.writer.last_seqno, \
        "both followers acked: the quorum watermark is the durable tip"
    # sever one follower's transport: the next ship marks it dead
    leader.handles[1].end.close()
    apply_ops(drv, ops[8:])
    leader.pump()
    assert leader.handles[1].dead
    assert leader.quorum_seqno() == -1, "below quorum: nothing commits"
    # zero RPO: every record the old watermark ever covered is durable
    # on the surviving follower, which promotes answer-exact there
    fols[0].pump()
    assert fols[0].last_seqno >= q
    prom = fols[0].promote()
    want, _ = acked_prefix_answers(fols[0], driver, backend, ops=ops)
    assert_same_answers(probe_answers(prom), want)


@pytest.mark.parametrize("driver", DRIVERS)
def test_fresh_live_watermarks_elect_exactly_one(tmp_path, driver):
    """The split-brain regression: frames ship every pump but rosters
    only every heartbeat cadence, so a leader that dies right after
    shipping leaves EVERY caught-up follower's live watermark ahead of
    every rostered ack. The successor rule must evaluate roster values
    only — identical input on every follower, one winner — because
    mixing in the live watermark would let each follower see itself as
    best and elect multiple equal-epoch leaders at once."""
    clock, drv, leader, fols, ops = make_lease_cluster(
        tmp_path, driver, "jnp")
    # post-heartbeat traffic: ship + apply + ack runs, but the frozen
    # clock throttles the heartbeat cadence — no roster refresh
    hbs = leader.counters["heartbeats"]
    apply_ops(drv, ops[8:])
    for _ in range(2):
        leader.pump()
        for f in fols:
            f.pump()
    leader.pump()
    assert leader.counters["heartbeats"] == hbs, "no roster refresh"
    for f in fols:
        assert f.last_seqno > max(a for _, a in f.roster), \
            "the regression's setup: live watermarks ahead of the roster"
    clock.advance(3.0 * leader.lease_s)
    for f in fols:
        f.pump()
    assert fols[0].new_leader is not None, "the rostered winner promotes"
    assert fols[1].new_leader is None and not fols[1].promoted, \
        "a fresher LIVE watermark must not out-elect the shared roster"
    assert fols[1].counters["standdowns"] == 1
    assert sum(f.counters["auto_promotions"] for f in fols) == 1


def test_auto_promotion_preserves_quorum_mode(tmp_path):
    """REVIEW regression: heartbeats advertise ack mode + quorum, and
    `promote(lead=True)` passes them through — a zero-RPO cluster must
    not silently revert to leader acks after its first automatic
    failover. The fresh leader has no followers yet, so its commit
    watermark is -1: nothing is client-acked until quorum re-forms."""
    clock, drv, leader, fols, ops = make_lease_cluster(
        tmp_path, "single", "jnp", ack_mode="quorum", quorum=2)
    assert all(f.stats()["leader_ack_mode"] == "quorum" for f in fols)
    clock.advance(3.0 * leader.lease_s)
    for f in fols:
        f.pump()
    new_lead = fols[0].new_leader
    assert new_lead is not None
    assert new_lead.ack_mode == "quorum" and new_lead.quorum == 2, \
        "automatic failover must inherit the quorum ack contract"
    assert new_lead.quorum_seqno() == -1, \
        "no re-attached followers yet: nothing may be client-acked"


def test_standdown_fallback_promotes_next_rank(tmp_path):
    """REVIEW regression: a loser that stands down must re-arm a
    fallback lease, not disarm — if the designated successor died in
    the same failure (its stream never arrives), the second
    consecutive expiry peels one rank and promotes the next-ranked
    follower instead of leaving the cluster leaderless forever."""
    clock, drv, leader, fols, ops = make_lease_cluster(
        tmp_path, "single", "jnp")
    clock.advance(3.0 * leader.lease_s)
    fols[1].pump()                      # rank 1; rank 0 died too
    assert fols[1].new_leader is None and not fols[1].promoted
    assert fols[1].lease_deadline is not None, \
        "stand-down must re-arm a fallback lease, not disarm"
    clock.advance(2.0 * leader.lease_s)
    fols[1].pump()                      # second expiry: rank 1 promotes
    assert fols[1].new_leader is not None
    assert fols[1].counters["lease_expiries"] == 2
    assert fols[1].counters["auto_promotions"] == 1


def test_slow_apply_does_not_spuriously_promote(tmp_path):
    """The anti-flap rule: a pump that dwells in `ingest` longer than
    the lease (a cold follower compiling apply shapes) must NOT promote
    when the live leader's heartbeats kept arriving during the dwell —
    the detector drains control traffic again after apply, so only a
    leader that actually went silent expires the lease."""
    clock, drv, leader, fols, ops = make_lease_cluster(
        tmp_path, "single", "jnp", n_followers=1)
    fol = fols[0]

    class SlowIngestEnd:
        """The follower's end, with ingest dwell: receiving frames
        burns a whole lease of clock time, during which the (live)
        leader lands one more heartbeat in the inbox."""

        def __init__(self, end):
            self.end = end

        def recv_frames(self):
            frames = self.end.recv_frames()
            clock.advance(2.0 * leader.lease_s)   # the slow apply...
            leader._last_hb = None                # cadence due again
            leader._heartbeat()                   # ...heartbeat lands
            return frames

        def __getattr__(self, name):
            return getattr(self.end, name)

    fol.end = SlowIngestEnd(fol.end)
    apply_ops(drv, ops[8:])
    leader.pump()
    fol.pump()                          # dwell > lease inside this pump
    assert fol.new_leader is None and not fol.promoted, \
        "a heartbeating leader must never be declared dead"
    assert fol.counters["lease_expiries"] == 0
    fol.end = fol.end.end               # unwrap; converge normally
    R.converge(leader, fol)
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))


def test_pending_overflow_pathological_reorder(tmp_path):
    """A worst-case reorder stream (first frame dropped, the rest
    delivered highest-first, buffer capped far below the stream) sheds
    frames with ``pending_overflow`` and an *immediate* gap ack; the
    leader's retransmit heals everything to bitwise convergence."""
    p = small_params("jnp")
    dur = WAL.Durability(tmp_path / "leader", snapshot_every_bytes=1 << 30)
    drv = make_engine("single", p, durability=dur)
    leader = R.Leader(drv)
    ops = write_stream(n_ops=12)
    fol = leader.add_follower(tmp_path / "fol", pending_max=3)
    apply_ops(drv, ops)
    leader.ship()
    wire = fol.link.frames
    assert len(wire) >= 8
    dropped = wire.popleft()            # the chain head never arrives
    frames = sorted(wire, key=lambda f: WAL.check_frame(f).seqno,
                    reverse=True)
    wire.clear()
    wire.extend(frames)
    fol.pump()
    st = fol.stats()
    assert st["pending_overflow"] >= 1, "cap must shed the reorder burst"
    assert st["reorder_buffered"] <= 3, "buffer must stay bounded"
    assert st["gap_signals"] >= 1, "overflow must gap-ack immediately"
    assert fol.counters["applied_records"] == 0, \
        "nothing applies before the chain head arrives"
    del dropped
    R.converge(leader, fol)
    assert leader.stats()["per_follower"][0]["retransmits"] >= 1
    assert fol.stats()["reorder_buffered"] == 0
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))
