"""Fault-injection replication suite (the ISSUE 9 headline proof).

Every test drives the transport seam directly — `follower.link.frames`
is the in-flight wire — injects a fault (lost suffix = leader SIGKILL,
torn stream tail, duplicated/reordered delivery, CRC flip, severed
socket, mid-RETUNE cut), then proves one of two claims:

  * **failover answer-exactness**: after `promote()`, the follower
    answers bitwise like a fresh engine fed exactly its durable acked
    prefix of the op stream — never a torn window, never an un-acked
    suffix (on both drivers × both backends);
  * **no poisoning**: a rejected frame (CRC flip, drop) only costs a
    gap-signalled retransmit — the stream still converges bitwise.
"""
import random

import numpy as np
import pytest

from repl_harness import (BACKENDS, DRIVERS, acked_prefix_answers,
                          apply_ops, assert_same_answers,
                          leader_with_follower, make_leader,
                          probe_answers, write_stream)

from repro.engine import SLSM
from repro.engine import replication as R
from repro.engine import wal as WAL

FAULTS = ("sigkill", "torn_tail", "dup_reorder", "crc_flip")


def _inject(fault, wire, rng):
    """Mutate the in-flight frame deque in place."""
    if fault == "sigkill":
        # the leader died mid-send: an arbitrary suffix never arrives
        for _ in range(max(1, len(wire) // 2)):
            wire.pop()
    elif fault == "torn_tail":
        # the last frame arrives cut mid-record (torn stream tail)
        last = wire.pop()
        wire.append(last[:max(1, len(last) // 2)])
    elif fault == "dup_reorder":
        frames = list(wire) * 2
        rng.shuffle(frames)
        wire.clear()
        wire.extend(frames)
    elif fault == "crc_flip":
        i = len(wire) // 2
        b = bytearray(wire[i])
        b[len(b) // 2] ^= 0x40
        wire[i] = bytes(b)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", DRIVERS)
@pytest.mark.parametrize("fault", FAULTS)
def test_failover_answer_exact_under_fault(tmp_path, fault, driver,
                                           backend):
    """Promote after each injected fault: the promoted follower is
    bitwise a fresh engine fed its durable acked prefix, and it takes
    writes immediately (epoch bumped, logging re-enabled)."""
    drv, leader, fol, ops = leader_with_follower(
        tmp_path, driver, backend, n_prefix=4, snapshot=True)
    apply_ops(drv, ops[4:])
    leader.ship()                       # the whole durable tail in flight
    wire = fol.link.frames
    assert len(wire) >= len(ops) - 4
    rng = random.Random(sum(map(ord, fault + driver + backend)))
    _inject(fault, wire, rng)
    fol.pump()
    prom = fol.promote()
    want, j = acked_prefix_answers(fol, driver, backend, ops=ops,
                                   leader_dir=tmp_path / "leader")
    assert j >= 4, "bootstrap prefix must be durable on the follower"
    if fault in ("sigkill", "torn_tail", "crc_flip"):
        assert j < len(ops), f"{fault} failed to cut the stream"
    assert_same_answers(probe_answers(prom), want)
    # the promoted node is a writable leader: epoch bumped, writes land
    assert prom.durability.writer.epoch == 1
    keys = np.array([11, 12, 13], np.int32)
    prom.insert(keys, keys * 10)
    v, f = prom.lookup_many(keys)
    assert bool(np.all(np.asarray(f)))
    np.testing.assert_array_equal(np.asarray(v), keys * 10)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", DRIVERS)
def test_failover_on_mid_retune_cut(tmp_path, driver, backend):
    """Cut the stream right after — and torn inside — an in-flight
    RETUNE record: the tuner switch is answer-invariant and replays
    (or tears away) cleanly, so promotion stays oracle-exact."""
    drv, leader = make_leader(tmp_path / "leader", driver, backend,
                              adaptive=True)
    ops = write_stream(n_ops=6)
    apply_ops(drv, ops, upto=4)
    fols = [leader.add_follower(tmp_path / f"f{i}") for i in range(2)]
    # read-heavy phase rolls the tuner; the decision binds (and logs)
    # at the next write boundary (scheduler invariant)
    probe = np.arange(0, 4000, 2, dtype=np.int32)
    for _ in range(12):
        drv.lookup_many(probe)
    apply_ops(drv, ops[4:])
    assert drv.stats["retunes"] >= 1, "stream failed to provoke a retune"
    leader.ship()
    for mode, fol in zip(("after", "torn"), fols):
        wire = fol.link.frames
        idx = next((i for i, fr in enumerate(wire)
                    if WAL.check_frame(fr).kind == WAL.REC_RETUNE), None)
        assert idx is not None, "no RETUNE frame reached the wire"
        while len(wire) > idx + 1:
            wire.pop()
        if mode == "torn":
            torn = wire.pop()
            wire.append(torn[:len(torn) // 2])
        fol.pump()
        prom = fol.promote()
        want, j = acked_prefix_answers(fol, driver, backend,
                                       adaptive=True, ops=ops,
                                       leader_dir=tmp_path / "leader")
        assert j >= 4
        assert_same_answers(probe_answers(prom), want)


def test_crc_flip_rejected_without_poisoning(tmp_path):
    """A corrupted frame is dropped and gap-signalled; the leader's
    rewind/retransmit heals the stream to bitwise convergence — the
    flip never reaches the replica WAL or its state."""
    drv, leader, fol, ops = leader_with_follower(tmp_path, n_prefix=0)
    apply_ops(drv, ops)
    leader.ship()
    wire = fol.link.frames
    i = len(wire) // 2
    b = bytearray(wire[i])
    b[-1] ^= 0x01
    wire[i] = bytes(b)
    R.converge(leader, fol)
    fst, lst = fol.stats(), leader.stats()
    assert fst["rejected"] >= 1
    assert fst["gap_signals"] >= 1
    assert lst["per_follower"][0]["retransmits"] >= 1
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))
    # the replica WAL holds only well-formed leader frames
    recs, good = WAL.read_wal(fol.drv.durability.wal_path)
    assert good == fol.drv.durability.writer.size
    assert all(WAL.check_frame(WAL.encode_record(
        r.seqno, r.kind, r.payload, r.epoch)) for r in recs)


def test_dropped_frame_heals_by_retransmit(tmp_path):
    """Silent loss of a mid-stream frame (not just a suffix): the
    reorder buffer holds the successors, the gap ack rewinds the
    leader, and the stream converges."""
    drv, leader, fol, ops = leader_with_follower(tmp_path, n_prefix=0)
    apply_ops(drv, ops)
    leader.ship()
    wire = fol.link.frames
    del wire[len(wire) // 2]
    fol.pump()
    assert fol.stats()["reorder_buffered"] >= 1
    R.converge(leader, fol)
    assert fol.stats()["reorder_buffered"] == 0
    assert leader.stats()["per_follower"][0]["retransmits"] >= 1
    assert fol.stats()["duplicates"] >= 1   # retransmit overlap dropped
    assert_same_answers(probe_answers(fol.drv), probe_answers(drv))


@pytest.mark.parametrize("driver", DRIVERS)
def test_socket_partition_then_promote(tmp_path, driver):
    """The localhost-socket transport under a hard partition: the
    leader end dies abruptly mid-stream; the follower keeps serving,
    then promotes answer-exact at its acked prefix."""
    drv, leader = make_leader(tmp_path / "leader", driver)
    ops = write_stream(n_ops=12)
    apply_ops(drv, ops, upto=6)
    cursor = leader.bootstrap(tmp_path / "fol")
    lis = R.SocketListener()
    lend = R.connect(lis.host, lis.port)
    fend = lis.accept()
    lis.close()
    leader.attach(lend, cursor)
    fol = R.Follower(tmp_path / "fol", fend, driver=driver)
    apply_ops(drv, ops[6:])
    for _ in range(50):
        leader.pump()
        fol.pump()
        if fol.last_seqno >= 8:         # mid-stream: partial tail applied
            break
    assert fol.last_seqno >= 6
    lend.close()                        # partition: leader side gone
    fol.pump()                          # must not raise on a dead link
    prom = fol.promote()
    want, j = acked_prefix_answers(fol, driver, "jnp", ops=ops,
                                   leader_dir=tmp_path / "leader")
    assert j >= 6
    assert_same_answers(probe_answers(prom), want)


def test_second_failover_continues_epoch_chain(tmp_path):
    """Failover chains: promoted follower leads its own follower; a
    second promotion bumps the epoch again, and a plain `restore` of
    the twice-promoted directory round-trips bitwise."""
    drv, leader, fol, ops = leader_with_follower(tmp_path, n_prefix=6)
    R.converge(leader, fol)
    prom = fol.promote()
    assert prom.durability.writer.epoch == 1
    apply_ops(prom, ops[6:])
    leader2 = R.Leader(prom)
    fol2 = leader2.add_follower(tmp_path / "f2")
    R.converge(leader2, fol2)
    assert_same_answers(probe_answers(fol2.drv), probe_answers(prom))
    prom2 = fol2.promote()
    assert prom2.durability.writer.epoch == 2
    assert_same_answers(probe_answers(prom2), probe_answers(prom))
    # a post-failover write materializes epoch 2 in the log; a plain
    # restore of the twice-promoted directory then round-trips bitwise
    # (an unwritten bump is in-memory only — by design, the epoch is
    # persisted by the records it stamps, not by a side file)
    keys = np.array([21, 22], np.int32)
    prom2.insert(keys, keys * 100)
    prom2.durability.close()
    back = SLSM.restore(tmp_path / "f2")
    assert back.durability.writer.epoch == 2
    assert_same_answers(probe_answers(back), probe_answers(prom2))
