"""Path setup for the replication suite: the shared machinery lives in
``repl_harness.py`` (named distinctly from the durability suite's
``harness.py`` — both test directories land on sys.path in a full
run, and the replication harness itself imports the durability one)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
