"""Merge threading analogue (paper 2.10.2): JAX async dispatch lets the
host keep answering lookups while a merge executes.

On real TPUs the merge computation runs on-device while the host thread
enqueues more work; here we verify the *semantics* — a merge dispatched
but not yet consumed does not block or corrupt concurrent lookups — and
benchmarks/fig12 measures the tail-latency effect.
"""
import numpy as np

from repro.core import SLSM, SLSMParams
from repro.core.oracle import DictOracle
from repro.core.slsm import lookup_batch
import jax.numpy as jnp


def test_lookup_correct_while_merge_in_flight():
    p = SLSMParams(R=2, Rn=64, eps=0.01, D=2, m=1.0, mu=32, max_levels=3,
                   max_range=512)
    t, o = SLSM(p), DictOracle()
    rng = np.random.default_rng(0)
    ks = rng.integers(0, 5000, 2000).astype(np.int32)
    vs = rng.integers(0, 100, 2000).astype(np.int32)

    # interleave inserts (which dispatch merges asynchronously) with
    # lookups issued immediately — no block_until_ready in between
    for i in range(0, 2000, 200):
        t.insert(ks[i:i + 200], vs[i:i + 200])
        o.insert(ks[i:i + 200], vs[i:i + 200])
        qs = jnp.asarray(ks[max(0, i - 300):i + 200][:128])
        vals, found = lookup_batch(t.p, t.state, qs)  # async dispatch
        ref_v, ref_f = o.lookup(np.asarray(qs))
        np.testing.assert_array_equal(np.asarray(found), ref_f)
        np.testing.assert_array_equal(np.asarray(vals)[ref_f], ref_v[ref_f])


def test_state_snapshot_isolation():
    """The engine's merge ops donate their input buffers — the exact
    analogue of the paper's merge thread 'taking ownership of the runs to
    merge'. A reader that wants a stable pre-merge view therefore takes an
    explicit snapshot copy (cheap: the buffer is O(R*Rn + levels)), and
    that snapshot stays queryable and consistent across later merges."""
    import jax
    p = SLSMParams(R=2, Rn=32, eps=0.01, D=2, m=1.0, mu=16, max_levels=3,
                   max_range=512)
    t = SLSM(p)
    ks = np.arange(200, dtype=np.int32)
    t.insert(ks[:100], ks[:100])
    snapshot = jax.tree.map(jnp.array, t.state)  # explicit copy
    t.insert(ks[100:], ks[100:])      # triggers seals/merges (donates)
    vals, found = lookup_batch(t.p, snapshot, jnp.asarray(ks[:100]))
    assert np.asarray(found).all()
    np.testing.assert_array_equal(np.asarray(vals), ks[:100])
    # and the live state sees everything
    vals, found = t.lookup(ks)
    assert found.all()
