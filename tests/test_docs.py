"""Docs-stack integrity (ISSUE 4 satellites).

Mirrors the CI docs job (tools/docs_lint.py): the public API of
`repro.engine` and `repro.bench` must be fully docstringed, the repo's
markdown docs must have no broken relative links or anchors, and the
README must actually carry the tuning guide + trajectory-table blocks
this PR introduced.
"""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import docs_lint  # noqa: E402


def test_public_api_docstrings_complete():
    assert docs_lint.lint_docstrings() == []


def test_markdown_links_resolve():
    assert docs_lint.lint_links(ROOT) == []


def test_architecture_doc_exists_and_readme_links_it():
    arch = ROOT / "docs" / "ARCHITECTURE.md"
    assert arch.exists(), "docs/ARCHITECTURE.md is part of the docs stack"
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme


def test_readme_has_tuning_guide_and_bench_table():
    readme = (ROOT / "README.md").read_text()
    assert "## Tuning guide" in readme
    assert docs_lint and "<!-- BENCH_TABLE_START -->" in readme
    assert "<!-- BENCH_TABLE_END -->" in readme


def test_design_has_tuner_section():
    design = (ROOT / "DESIGN.md").read_text()
    assert "§9" in design and "tuner" in design.lower()


def test_report_renders_committed_trajectory():
    sys.path.insert(0, str(ROOT))
    from benchmarks.report import load_docs, render_table
    docs = load_docs(ROOT)
    assert docs, "committed BENCH_*.json files form the trajectory"
    table = render_table(docs)
    assert table.count("\n") >= len(docs)
    assert "shifting" in table
