"""Merge scheduler tests: pacing semantics, drain-barrier equivalence,
program warm-up, and the stall-telemetry counters.

The load-bearing property (ISSUE 3's acceptance bar): a budgeted engine
must answer every lookup/range *identically* to a synchronous engine fed
the same ops — mid-backlog (reads are exact because pending-merge runs
stay visible until their step retires them) and after the drain()
barrier — on both drivers and both backends.
"""
import numpy as np
import pytest

from repro.core import SLSMParams
from repro.core.oracle import DictOracle
from repro.engine import (SLSM, LevelingPolicy, MergeScheduler, Occupancy,
                          ShardedSLSM, backlog_cost, pending_steps,
                          step_cost)
from repro.engine.compaction import TieringPolicy
from repro.engine.scheduler import COMPACT, FLUSH, SEAL, SPILL, occupancy_of

SMALL = dict(R=2, Rn=8, eps=0.02, D=2, m=1.0, mu=4, max_levels=3,
             max_range=512, cand_factor=16)


def _params(budget, **over):
    return SLSMParams(**{**SMALL, **over, "merge_budget": budget})


def _drive(t, o, seed, rounds=10, key_space=250):
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        n = int(rng.integers(1, 40))
        ks = rng.integers(0, key_space, n).astype(np.int32)
        vs = rng.integers(-50, 50, n).astype(np.int32)
        t.insert(ks, vs)
        o.insert(ks, vs)
        dels = rng.integers(0, key_space, int(rng.integers(1, 8))).astype(
            np.int32)
        t.delete(dels)
        o.delete(dels)
    return np.arange(-4, key_space + 4, dtype=np.int32)


# -- pending-step planner ---------------------------------------------------

def test_pending_steps_deepest_first_and_costed():
    p = _params(1)
    pol = TieringPolicy()
    occ = Occupancy(stage_count=p.Rn, run_count=p.R,
                    level_runs=(p.D, p.D, p.D))
    steps = pending_steps(p, pol, occ)
    assert [s.kind for s in steps] == [COMPACT, SPILL, SPILL, FLUSH, SEAL]
    assert [s.level for s in steps][:3] == [2, 1, 0]
    # per-step device-op cost: geometric in depth, seal cheapest
    costs = {(s.kind, s.level): s.cost for s in steps}
    assert costs[(SEAL, -1)] == p.Rn
    assert costs[(COMPACT, 2)] > costs[(SPILL, 1)] > costs[(SPILL, 0)]
    assert backlog_cost(steps) == sum(s.cost for s in steps)
    assert not pending_steps(p, pol, Occupancy(0, 0, (0, 0, 0)))


def test_step_cost_matches_level_geometry():
    p = _params(0)
    assert step_cost(FLUSH, -1, p) == p.runs_merged * p.Rn
    assert step_cost(SPILL, 0, p) == p.disk_runs_merged * p.level_cap(0)
    assert step_cost(COMPACT, p.max_levels - 1, p) == (
        p.D * p.level_cap(p.max_levels - 1))


def test_negative_merge_budget_rejected():
    with pytest.raises(ValueError, match="merge_budget"):
        _params(-1)


# -- drain-barrier equivalence (the acceptance property) --------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("budget", [1, 2])
def test_budgeted_slsm_matches_sync_and_oracle(backend, budget):
    """Budgeted vs synchronous single tree, same op stream: lookups and
    ranges must be bit-identical mid-backlog and after drain()."""
    sync, o = SLSM(_params(0, backend=backend)), DictOracle()
    paced = SLSM(_params(budget, backend=backend))
    rng = np.random.default_rng(17)
    for _ in range(8):
        n = int(rng.integers(1, 40))
        ks = rng.integers(0, 250, n).astype(np.int32)
        vs = rng.integers(-50, 50, n).astype(np.int32)
        for t in (sync, paced):
            t.insert(ks, vs)
        o.insert(ks, vs)
        dels = rng.integers(0, 250, 4).astype(np.int32)
        for t in (sync, paced):
            t.delete(dels)
        o.delete(dels)
        # mid-backlog: reads are exact with merges still pending
        qs = np.arange(-4, 254, dtype=np.int32)
        vp, fp = paced.lookup(qs)
        vo, fo = o.lookup(qs)
        np.testing.assert_array_equal(fp, fo)
        np.testing.assert_array_equal(vp[fp], vo[fo])
    paced.drain()
    assert not paced.scheduler.backlog
    qs = np.arange(-4, 254, dtype=np.int32)
    vs_, fs = sync.lookup(qs)
    vp, fp = paced.lookup(qs)
    np.testing.assert_array_equal(fs, fp)
    np.testing.assert_array_equal(vs_, vp)
    ks_, ws = sync.range(0, 250)
    kp, wp = paced.range(0, 250)
    np.testing.assert_array_equal(ks_, kp)
    np.testing.assert_array_equal(ws, wp)
    # merges actually happened (the schedule differs; totals agree
    # wherever the policy makes them inevitable)
    assert paced.stats["flushes"] > 0 and paced.stats["spills"] > 0


@pytest.mark.parametrize("budget", [1, 2])
def test_budgeted_sharded_matches_sync_and_oracle(budget):
    sync, o = ShardedSLSM(_params(0), n_shards=4), DictOracle()
    paced = ShardedSLSM(_params(budget), n_shards=4)
    rng = np.random.default_rng(23)
    for _ in range(6):
        n = int(rng.integers(1, 120))
        ks = rng.integers(0, 500, n).astype(np.int32)
        vs = rng.integers(-50, 50, n).astype(np.int32)
        for t in (sync, paced):
            t.insert(ks, vs)
        o.insert(ks, vs)
        dels = rng.integers(0, 500, 8).astype(np.int32)
        for t in (sync, paced):
            t.delete(dels)
        o.delete(dels)
        qs = np.arange(-4, 504, dtype=np.int32)
        vp, fp = paced.lookup(qs)
        vo, fo = o.lookup(qs)
        np.testing.assert_array_equal(fp, fo)
        np.testing.assert_array_equal(vp[fp], vo[fo])
    paced.drain()
    qs = np.arange(-4, 504, dtype=np.int32)
    vs_, fs = sync.lookup(qs)
    vp, fp = paced.lookup(qs)
    np.testing.assert_array_equal(fs, fp)
    np.testing.assert_array_equal(vs_, vp)
    ks_, ws = sync.range(0, 500)
    kp, wp = paced.range(0, 500)
    np.testing.assert_array_equal(ks_, kp)
    np.testing.assert_array_equal(ws, wp)
    assert paced.stats["flushes"] > 0


def test_budgeted_leveling_policy_keeps_invariant():
    """Pacing must never violate the policy's occupancy bound: a step runs
    only when its destination can accept the output run."""
    p = SLSMParams(R=2, Rn=8, eps=0.05, D=2, m=1.0, mu=4, max_levels=4,
                   max_range=512, merge_budget=1)
    t, o = SLSM(p, policy=LevelingPolicy()), DictOracle()
    qs = _drive(t, o, seed=3)
    t.drain()
    v1, f1 = t.lookup(qs)
    v2, f2 = o.lookup(qs)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(v1[f1], v2[f2])
    for lv in t.state.levels:
        assert int(lv.n_runs) <= 2


# -- pacing + telemetry ------------------------------------------------------

def test_backlog_peak_recorded_and_drain_clears():
    t, o = SLSM(_params(1)), DictOracle()
    _drive(t, o, seed=5)
    assert t.stats["backlog_peak"] >= 1
    t.drain()
    assert not t.scheduler.backlog
    s, o2 = ShardedSLSM(_params(1), n_shards=2), DictOracle()
    _drive(s, o2, seed=5, key_space=400)
    assert s.stats["backlog_peak"] >= 1
    s.drain()
    assert all(not pending_steps(s.p, s.policy, occ)
               for occ in s._occupancies())


def test_sync_mode_is_default_and_drain_is_noop_shaped():
    t = SLSM(SLSMParams(**SMALL))
    assert t.p.merge_budget == 0
    o = DictOracle()
    qs = _drive(t, o, seed=9)
    before = t.lookup(qs)
    t.drain()   # legal in sync mode: retires whatever the legacy cascade
    after = t.lookup(qs)   # left resident; results must not change
    np.testing.assert_array_equal(before[0], after[0])
    np.testing.assert_array_equal(before[1], after[1])


# -- program warm-up ---------------------------------------------------------

@pytest.mark.parametrize("engine", ["single", "sharded"])
def test_warm_precompiles_without_changing_results(engine):
    if engine == "single":
        warmed, cold = SLSM(_params(1)), SLSM(_params(1))
    else:
        warmed = ShardedSLSM(_params(1), n_shards=2)
        cold = ShardedSLSM(_params(1), n_shards=2)
    warmed.warm()
    # warm() must not touch live state
    assert warmed.n_live == 0
    rng = np.random.default_rng(2)
    ks = rng.integers(0, 300, 200).astype(np.int32)
    vs = rng.integers(0, 100, 200).astype(np.int32)
    warmed.insert(ks, vs)
    cold.insert(ks, vs)
    qs = np.arange(0, 300, dtype=np.int32)
    vw, fw = warmed.lookup(qs)
    vc, fc = cold.lookup(qs)
    np.testing.assert_array_equal(fw, fc)
    np.testing.assert_array_equal(vw, vc)


def test_scheduler_backlog_property_reflects_occupancy():
    t = SLSM(_params(1))
    assert isinstance(t.scheduler, MergeScheduler)
    assert t.scheduler.backlog == []
    t.insert(np.arange(100, dtype=np.int32),
             np.arange(100, dtype=np.int32))
    # whatever is pending must be consistent with the planner
    assert t.scheduler.backlog == pending_steps(
        t.p, t.policy, occupancy_of(t.state))
